"""Compare VC-ASGD against every baseline scheme the paper discusses —
Downpour, DC-ASGD, persistent-replica EASGD, synchronous BSP, plus the
compressed sparse-frame variant — under an aggressive preemption regime.
All schemes run through the same typed Lease/Coordinator protocol
(repro.protocol); only the assimilation algorithm differs.  Reproduces
the paper's §IV-C argument: the cluster-paradigm schemes degrade or stall
when clients die; VC-ASGD doesn't.

  PYTHONPATH=src python examples/asgd_comparison.py           # full demo
  PYTHONPATH=src python examples/asgd_comparison.py --smoke   # fast-gate size
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.baselines import (CompressedVCASGD, DCASGD, Downpour,
                                  EASGDPersistent, SyncBSP, VCASGD)
from repro.core.simulator import SimConfig, run_simulation
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for the fast test gate")
    args = ap.parse_args(argv)

    task = MLPTask()
    data = make_classification_data(n_train=800 if args.smoke else 3000,
                                    n_val=200 if args.smoke else 800)
    n_shards = 8 if args.smoke else 15

    def cfg():
        return SimConfig(n_param_servers=3, n_clients=5, tasks_per_client=2,
                         n_shards=n_shards,
                         max_epochs=2 if args.smoke else 6, local_steps=2,
                         preemptible=True, mean_lifetime_s=1200.0, seed=3)

    schemes = {
        "vc-asgd(0.95)": VCASGD(0.95),
        "vc-asgd(var)": VCASGD(var_alpha()),
        "vc-asgd(0.999)~easgd": VCASGD(0.999),   # §IV-C equivalence
        "vc-asgd-compressed": CompressedVCASGD(0.95, density=0.05),
        "downpour": Downpour(server_lr=0.5),
        "dc-asgd": DCASGD(server_lr=0.5, lam=0.05),
        "easgd-persistent": EASGDPersistent(beta=0.05),
        "sync-bsp": SyncBSP(n_shards),
    }
    print(f"{'scheme':>22} {'hours':>7} {'final acc':>10} "
          f"{'preempt':>8} {'reassigned':>10} {'wire MB':>8}")
    for name, scheme in schemes.items():
        res = run_simulation(task, data, scheme, cfg())
        print(f"{name:>22} {res.wall_time_s / 3600:>7.2f} "
              f"{res.final_accuracy:>10.3f} {res.preemptions:>8} "
              f"{res.reassignments:>10} {res.wire.bytes_sent / 1e6:>8.1f}")
    print("\nNote how alpha=0.999 (the EASGD-equivalent moving rate) trains "
          "far slower in the\nVC regime — exactly the paper's Fig. 4 "
          "observation — how the barriered BSP\nround time stretches under "
          "preemption while VC-ASGD shrugs it off, and how\nthe compressed "
          "variant ships a fraction of the bytes (sparse wire frames).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
