"""Compare VC-ASGD against every baseline scheme the paper discusses —
Downpour, DC-ASGD, persistent-replica EASGD, synchronous BSP — under an
aggressive preemption regime.  Reproduces the paper's §IV-C argument: the
cluster-paradigm schemes degrade or stall when clients die; VC-ASGD doesn't.

  PYTHONPATH=src python examples/asgd_comparison.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.baselines import (DCASGD, Downpour, EASGDPersistent, SyncBSP,
                                  VCASGD)
from repro.core.simulator import SimConfig, run_simulation
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha


def main():
    task = MLPTask()
    data = make_classification_data(n_train=3000, n_val=800)

    def cfg():
        return SimConfig(n_param_servers=3, n_clients=5, tasks_per_client=2,
                         n_shards=15, max_epochs=6, local_steps=2,
                         preemptible=True, mean_lifetime_s=1200.0, seed=3)

    schemes = {
        "vc-asgd(0.95)": VCASGD(0.95),
        "vc-asgd(var)": VCASGD(var_alpha()),
        "vc-asgd(0.999)~easgd": VCASGD(0.999),   # §IV-C equivalence
        "downpour": Downpour(server_lr=0.5),
        "dc-asgd": DCASGD(server_lr=0.5, lam=0.05),
        "easgd-persistent": EASGDPersistent(beta=0.05),
        "sync-bsp": SyncBSP(15),
    }
    print(f"{'scheme':>22} {'hours':>7} {'final acc':>10} "
          f"{'preempt':>8} {'reassigned':>10}")
    for name, scheme in schemes.items():
        res = run_simulation(task, data, scheme, cfg())
        print(f"{name:>22} {res.wall_time_s / 3600:>7.2f} "
              f"{res.final_accuracy:>10.3f} {res.preemptions:>8} "
              f"{res.reassignments:>10}")
    print("\nNote how alpha=0.999 (the EASGD-equivalent moving rate) trains "
          "far slower in the\nVC regime — exactly the paper's Fig. 4 "
          "observation — and how the barriered BSP\nround time stretches "
          "under preemption while VC-ASGD shrugs it off.")


if __name__ == "__main__":
    main()
