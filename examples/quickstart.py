"""Quickstart: the paper's system in 60 lines.

Builds a tiny LM, splits the job into VC subtasks, trains it with VC-ASGD
assimilation through the discrete-event simulator (heterogeneous preemptible
clients, eventual-consistency parameter store, every handout an explicit
protocol Lease driven through the Coordinator), and prints the
accuracy-vs-time trace — the Fig. 2 experience at laptop scale.

  PYTHONPATH=src python examples/quickstart.py            # full demo
  PYTHONPATH=src python examples/quickstart.py --smoke    # fast-gate size
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.baselines import VCASGD
from repro.core.simulator import SimConfig, run_simulation
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for the fast test gate")
    args = ap.parse_args(argv)

    task = MLPTask()
    data = make_classification_data(n_train=800 if args.smoke else 4000,
                                    n_val=200 if args.smoke else 800)

    cfg = SimConfig(
        n_param_servers=3,        # Pn
        n_clients=5,              # Cn — heterogeneous fleet (Table I types)
        tasks_per_client=2,       # Tn
        n_shards=8 if args.smoke else 25,   # the work generator's data split
        max_epochs=2 if args.smoke else 10,
        preemptible=True,         # clients get killed mid-flight...
        mean_lifetime_s=2400.0,   # ...every ~40 simulated minutes
        consistency="eventual",   # Redis-style parameter store
        seed=0,
    )
    scheme = VCASGD(alpha=var_alpha())      # the paper's alpha_e = e/(e+1)

    print(f"[quickstart] {cfg.n_shards} subtasks x {cfg.max_epochs} epochs "
          f"on {cfg.n_clients} preemptible clients, {cfg.n_param_servers} "
          f"parameter servers")
    res = run_simulation(task, data, scheme, cfg)

    print(f"{'epoch':>6} {'sim hours':>10} {'val acc':>8} {'spread':>7}")
    for p in res.points:
        print(f"{p.epoch:>6} {p.t_complete / 3600:>10.2f} "
              f"{p.acc_mean:>8.3f} ±{p.acc_std:.3f}")
    print(f"\n[quickstart] final accuracy {res.final_accuracy:.3f} | "
          f"preemptions {res.preemptions} | subtask reassignments "
          f"{res.reassignments} | lost store updates "
          f"{res.store_stats.lost_updates}")
    print(f"[quickstart] the wire (real encoded frames): "
          f"{res.wire.frames_sent} sent / {res.wire.frames_recv} delivered "
          f"/ {res.wire.frames_dropped} dropped, "
          f"{res.wire.bytes_sent / 1e6:.1f} MB total")
    print("[quickstart] training survived every failure — that is the paper.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
