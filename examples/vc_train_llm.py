"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the pod-scale VC-ASGD runtime (islands + Eq. 2 assimilation +
checkpoint/restart + a mid-run simulated island preemption).

This is the deliverable-(b) end-to-end example. On this CPU container it
runs a genuinely ~100M-param model — expect ~1-2s/round after compile with
the default flags; shrink --d-model for a faster demo.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/vc_train_llm.py --rounds 60
"""
import argparse
import os
import sys
import time
from pathlib import Path

if "--xla-devices" in sys.argv or "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                          # noqa: E402
import jax.numpy as jnp             # noqa: E402
import numpy as np                  # noqa: E402

from repro.checkpoint import CheckpointManager          # noqa: E402
from repro.core.vc_asgd import var_alpha                # noqa: E402
from repro.data import make_batch_for                   # noqa: E402
from repro.models.common import BlockSpec, ModelConfig, uniform_groups  # noqa: E402
from repro.models.registry import build_model           # noqa: E402
from repro.optim import Adam, cosine_schedule           # noqa: E402
from repro.runtime.sharding import MeshPlan             # noqa: E402
from repro.runtime.vc_runtime import make_vc_round      # noqa: E402


def hundred_m_config(d_model: int) -> ModelConfig:
    """~100M params at d_model=640: 10L, ff 2560, 32k vocab."""
    return ModelConfig(
        arch="demo-100m", family="dense", d_model=d_model,
        n_heads=d_model // 80, n_kv_heads=max(1, d_model // 160),
        d_ff=d_model * 4, vocab_size=32768,
        layer_groups=uniform_groups(10, BlockSpec()),
        norm="rmsnorm", mlp_act="swiglu", max_seq=2048,
        attn_q_block=256, attn_kv_block=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--islands", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--preempt-round", type=int, default=25)
    ap.add_argument("--ckpt", default="/tmp/vc_llm_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config(args.d_model)
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.param_specs()))
    print(f"[llm] {cfg.describe()}  ({n_params / 1e6:.1f}M params)")

    n_dev = len(jax.devices())
    tp = 2 if n_dev >= 4 else 1
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((args.islands, max(1, n_dev // (args.islands * tp)),
                             tp), ("pod", "data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=cosine_schedule(3e-4, warmup=20,
                                  total=args.rounds * args.local_steps))
    vc_round = jax.jit(make_vc_round(model, plan, args.islands,
                                     args.local_steps, opt))
    alpha_fn = var_alpha()
    ckpt = CheckpointManager(args.ckpt, keep=2)
    key = jax.random.PRNGKey(0)

    with mesh:
        server = model.init(key)
        islands = jax.tree.map(
            lambda s: jnp.broadcast_to(s[None], (args.islands, *s.shape)),
            server)
        opts = jax.vmap(opt.init)(islands)
        t_start = time.time()
        for rnd in range(args.rounds):
            bs = []
            for p in range(args.islands):
                steps = [make_batch_for(cfg, args.batch, args.seq,
                                        seed=rnd * 97 + p * 13 + s)
                         for s in range(args.local_steps)]
                bs.append(jax.tree.map(lambda *x: jnp.stack(x), *steps))
            batches = jax.tree.map(lambda *x: jnp.stack(x), *bs)
            surv = np.ones((args.islands,), bool)
            if rnd == args.preempt_round:
                surv[0] = False
                print(f"[llm] round {rnd}: island 0 preempted -> masked")
            server, islands, opts, m = vc_round(
                server, islands, opts, batches,
                jnp.asarray(alpha_fn(rnd + 1), jnp.float32),
                jnp.asarray(surv))
            if rnd % 5 == 0 or rnd == args.rounds - 1:
                print(f"[llm] round {rnd:3d} loss={float(m['loss']):.4f} "
                      f"({time.time() - t_start:.0f}s)")
            if rnd % 20 == 19:
                ckpt.save(rnd + 1, server, {"round": rnd + 1})
        ckpt.wait()
    print(f"[llm] done in {time.time() - t_start:.0f}s; "
          f"server checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
