"""Fleet-scale event-loop benchmark (``--only fleet``).

Runs the registry's fleet scenarios (1k/10k clients; 100k with --full)
and reports events/sec + wall-clock into ``results/BENCH_fleet.json``,
plus the aggregation-tier comparison: the same 10k fleet behind 32 edge
aggregators (``fleet_10k_tier``), claiming the hub's upstream frame count
shrinks by at least half the fan-in versus flat.

``PRE_PR`` holds the measured wall times of the SAME scenario configs on
the pre-refactor event loop (per-event O(n_clients) preemption sweep,
O(inflight) deadline scans, O(P log P) pending sorts, per-client held-
bytes delta ledger).  The refactor is bit-identical — result
fingerprints and therefore event counts match exactly — so
``speedup = pre_wall / post_wall`` compares the same work item for item.
"""
from __future__ import annotations

import time

# Measured on this container against the pre-refactor loop (commit
# 3613318 lineage), scenario configs identical to the registry's.  The
# result fingerprints (sim wall clock, accuracy, wire/handout bytes,
# preemption counts) were verified byte-identical pre vs post.
PRE_PR = {
    "fleet_1k": {
        "bench_wall_s": 14.2,
        "sim_wall_time_s": 1418.15450995263,
        "results_assimilated": 4000,
        "preemptions": 71,
        "wire_bytes_sent": 265019356,
        "handout_bytes": 133675356,
    },
    "fleet_10k": {
        "bench_wall_s": 125.89,
        "sim_wall_time_s": 464.58762821787604,
        "results_assimilated": 12000,
        "preemptions": 220,
        "wire_bytes_sent": 795320756,
        "handout_bytes": 401255920,
    },
}

# CI-noise headroom for the throughput floor: the gate fails only if the
# measured events/sec drops below baseline * FLOOR_FRACTION.
FLOOR_FRACTION = 0.25


def _run(name: str) -> dict:
    from repro.scenarios.registry import get

    sc = get(name)
    t0 = time.perf_counter()
    res = sc.run()
    wall = time.perf_counter() - t0
    return {
        "bench_wall_s": round(wall, 3),
        "events_processed": res.events_processed,
        "events_per_sec": round(res.events_processed / max(wall, 1e-9), 1),
        "sim_wall_time_s": res.wall_time_s,
        "epochs_done": res.epochs_done,
        "results_assimilated": res.results_assimilated,
        "preemptions": res.preemptions,
        "reassignments": res.reassignments,
        "final_accuracy": res.final_accuracy,
        "wire_bytes_sent": int(res.wire.bytes_sent),
        "handout_frames": res.handout_frames,
        "handout_bytes": int(res.handout_bytes),
        # result frames the HUB transport carried upward (frames_sent
        # minus download-leg handouts): per-client payloads when flat,
        # merged KIND_AGG frames behind an aggregation tier
        "upstream_frames": int(res.wire.frames_sent) - res.handout_frames,
        "aggregators": res.aggregators,
        "agg_flushes": res.agg_flushes,
    }


def bench_fleet(quick: bool = True) -> dict:
    names = ["fleet_1k", "fleet_10k"] + ([] if quick else ["fleet_100k"])
    out: dict = {"_pre_pr": PRE_PR}
    claims = {}
    for name in names:
        entry = _run(name)
        pre = PRE_PR.get(name)
        if pre is not None:
            # identical traces -> identical event counts, so the pre-PR
            # events/sec is the (post-measured) count over the pre wall
            entry["pre_pr_bench_wall_s"] = pre["bench_wall_s"]
            entry["pre_pr_events_per_sec"] = round(
                entry["events_processed"] / pre["bench_wall_s"], 1)
            entry["speedup"] = round(
                pre["bench_wall_s"] / max(entry["bench_wall_s"], 1e-9), 1)
            fp_ok = all(
                entry[k] == pre[k]
                for k in ("sim_wall_time_s", "results_assimilated",
                          "preemptions", "wire_bytes_sent", "handout_bytes"))
            entry["fingerprint_matches_pre_pr"] = fp_ok
            claims[f"{name}_fingerprint_identical"] = fp_ok
        out[name] = entry
    if "fleet_10k" in out:
        claims["10k_speedup_ge_10x"] = out["fleet_10k"]["speedup"] >= 10.0
        # ---- aggregation tier: same 10k fleet behind 32 edges ----------
        # the hub sees ONE merged frame per flush window instead of one
        # frame per client result; the reduction should be on the order
        # of the fan-in (10000/32 = 312.5 clients per aggregator)
        tier = _run("fleet_10k_tier")
        flat_up = out["fleet_10k"]["upstream_frames"]
        fan_in = 10000 / 32
        tier["upstream_reduction_x"] = round(
            flat_up / max(tier["upstream_frames"], 1), 1)
        tier["upstream_bytes_reduction_x"] = round(
            out["fleet_10k"]["wire_bytes_sent"]
            / max(tier["wire_bytes_sent"], 1), 1)
        out["fleet_10k_tier"] = tier
        claims["10k_tier_all_results_assimilated"] = (
            tier["results_assimilated"]
            == out["fleet_10k"]["results_assimilated"])
        claims["10k_tier_upstream_reduction_ge_half_fan_in"] = (
            tier["upstream_reduction_x"] >= 0.5 * fan_in)
    if "fleet_100k" in out:
        claims["100k_single_digit_minutes"] = (
            out["fleet_100k"]["bench_wall_s"] < 600.0)
    out["_claims"] = claims
    return out


def smoke_events_per_sec() -> float:
    """events/sec of the tiny CI smoke scenario — the --check floor."""
    return _run("fleet_smoke")["events_per_sec"]
