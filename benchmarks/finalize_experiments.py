"""Inject the roofline table + perf-iteration log into EXPERIMENTS.md from
results/dryrun.json (idempotent — replaces the marked sections)."""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks.roofline_report import (fmt_s, load, markdown_table,
                                        model_flops, row)
from repro.configs import ARCHS
from repro.configs.shapes import SHAPES

ROOT = Path(__file__).resolve().parents[1]


def perf_rows(data):
    """Collect tagged (hillclimb) runs paired with their baselines."""
    out = []
    for key, res in data.items():
        r = row(res)
        if not r or not r["tag"]:
            continue
        base_key = "|".join(key.split("|")[:3])
        base = row(data.get(base_key, {})) or {}
        out.append((base_key, r["tag"], base, r))
    return out


def perf_markdown(data) -> str:
    lines = ["| cell | variant | compute | memory | collective | dominant "
             "| peak GiB | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    seen_base = set()
    for base_key, tag, base, r in sorted(perf_rows(data)):
        if base and base_key not in seen_base:
            seen_base.add(base_key)
            lines.append(
                f"| {base_key.replace('|single', '')} | baseline | "
                f"{fmt_s(base['compute_s'])} | {fmt_s(base['memory_s'])} | "
                f"{fmt_s(base['collective_s'])} | {base['dominant']} | "
                f"{base['peak_gib']:.1f} | {base['roofline_frac']:.2%} |")
        lines.append(
            f"| {base_key.replace('|single', '')} | **{tag}** | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | {r['dominant']} | "
            f"{r['peak_gib']:.1f} | {r['roofline_frac']:.2%} |")
    return "\n".join(lines) + "\n"


def main():
    data = load()
    rows = [r for r in (row(v) for v in data.values()) if r]
    rows.sort(key=lambda r: (ARCHS.index(r["arch"]),
                             list(SHAPES).index(r["cell"]), r["mesh"]))
    table = markdown_table(rows)
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
                "<!-- ROOFLINE_TABLE -->\n" + table + "\n", md,
                flags=re.S) if "<!-- ROOFLINE_TABLE -->" in md else md
    if "<!-- PERF_TABLE -->" in md:
        md = re.sub(r"<!-- PERF_TABLE -->.*?(?=\n### |\n## |\Z)",
                    "<!-- PERF_TABLE -->\n" + perf_markdown(data) + "\n", md,
                    flags=re.S)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated:",
          sum(1 for r in rows if r["mesh"] == "16x16" and not r["tag"]),
          "baseline cells,", len(perf_rows(data)), "tagged runs")


if __name__ == "__main__":
    main()
