"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled), so wall-time here benchmarks the *oracle*
(pure-jnp, XLA-compiled) path — the apples-to-apples number for the CSV —
and separately validates that the Pallas path agrees numerically.  On a TPU
the same harness times the Mosaic kernels.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as K
from repro.kernels import ref as R


def _time(fn: Callable, *args, iters: int = 5) -> float:
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn_j(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def bench_kernels() -> Dict[str, Dict]:
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    out = {}

    # vc-asgd lerp over a 16M-param tensor: HBM-pass throughput
    n = 1 << 24
    s = jax.random.normal(ks[0], (n,), jnp.float32)
    c = jax.random.normal(ks[1], (n,), jnp.float32)
    us = _time(lambda a, b: R.vc_asgd_lerp(a, b, 0.95), s, c)
    gbps = 3 * n * 4 / (us * 1e-6) / 1e9                # 2 reads + 1 write
    out["vc_asgd_lerp_16M"] = {"us_per_call": round(us, 1),
                               "derived": f"{gbps:.1f}GB/s"}

    q = jax.random.normal(ks[2], (1, 8, 1024, 64), jnp.float32) * 0.3
    k = jax.random.normal(ks[3], (1, 2, 1024, 64), jnp.float32) * 0.3
    v = jax.random.normal(ks[4], (1, 2, 1024, 64), jnp.float32)
    us = _time(lambda a, b, c_: R.attention(a, b, c_, causal=True), q, k, v)
    fl = 2 * 2 * 8 * 1024 * 1024 * 64 / 2               # causal half
    out["attention_1k"] = {"us_per_call": round(us, 1),
                           "derived": f"{fl / (us * 1e-6) / 1e9:.1f}GFLOP/s"}

    r_ = jax.random.normal(ks[5], (2, 4, 128, 64)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[6], (2, 4, 128, 64))) * 0.5 + 0.4
    u = jax.random.normal(ks[7], (4, 64)) * 0.2
    us = _time(lambda a, b, c_, d, e: R.wkv6(a, b, c_, d, e),
               r_, r_, r_, w, u)
    out["wkv6_T128"] = {"us_per_call": round(us, 1), "derived": "-"}

    x = jax.random.normal(ks[0], (1 << 22,))
    us = _time(lambda a: R.quantize_int8(a)[0], x)
    out["quantize_int8_4M"] = {"us_per_call": round(us, 1),
                               "derived":
                               f"{x.size * 4 / (us * 1e-6) / 1e9:.1f}GB/s"}

    # numerical agreement of the Pallas path (small shapes, interpret mode)
    sp = jax.random.normal(ks[0], (4096,))
    cp = jax.random.normal(ks[1], (4096,))
    err = float(jnp.max(jnp.abs(K.fused_lerp(sp, cp, 0.9)
                                - R.vc_asgd_lerp(sp, cp, 0.9))))
    out["pallas_vs_ref_lerp"] = {"us_per_call": 0.0,
                                 "derived": f"maxerr={err:.1e}"}
    return out
