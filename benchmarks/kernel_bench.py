"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled), so wall-time here benchmarks the *oracle*
(pure-jnp, XLA-compiled) path — the apples-to-apples number for the CSV —
and separately validates that the Pallas path agrees numerically.  On a TPU
the same harness times the Mosaic kernels.

Suites with a fused-launch story also emit a numeric ``_launches`` dict
(pallas_call counts per path) — ``benchmarks/run.py --check`` gates those
against the committed baseline (results/BASELINE_launches.json).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as K
from repro.kernels import ref as R


def _time(fn: Callable, *args, iters: int = 5) -> float:
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn_j(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def bench_kernels() -> Dict[str, Dict]:
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    out = {}

    # vc-asgd lerp over a 16M-param tensor: HBM-pass throughput
    n = 1 << 24
    s = jax.random.normal(ks[0], (n,), jnp.float32)
    c = jax.random.normal(ks[1], (n,), jnp.float32)
    us = _time(lambda a, b: R.vc_asgd_lerp(a, b, 0.95), s, c)
    gbps = 3 * n * 4 / (us * 1e-6) / 1e9                # 2 reads + 1 write
    out["vc_asgd_lerp_16M"] = {"us_per_call": round(us, 1),
                               "derived": f"{gbps:.1f}GB/s"}

    q = jax.random.normal(ks[2], (1, 8, 1024, 64), jnp.float32) * 0.3
    k = jax.random.normal(ks[3], (1, 2, 1024, 64), jnp.float32) * 0.3
    v = jax.random.normal(ks[4], (1, 2, 1024, 64), jnp.float32)
    us = _time(lambda a, b, c_: R.attention(a, b, c_, causal=True), q, k, v)
    fl = 2 * 2 * 8 * 1024 * 1024 * 64 / 2               # causal half
    out["attention_1k"] = {"us_per_call": round(us, 1),
                           "derived": f"{fl / (us * 1e-6) / 1e9:.1f}GFLOP/s"}

    r_ = jax.random.normal(ks[5], (2, 4, 128, 64)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[6], (2, 4, 128, 64))) * 0.5 + 0.4
    u = jax.random.normal(ks[7], (4, 64)) * 0.2
    us = _time(lambda a, b, c_, d, e: R.wkv6(a, b, c_, d, e),
               r_, r_, r_, w, u)
    out["wkv6_T128"] = {"us_per_call": round(us, 1), "derived": "-"}

    x = jax.random.normal(ks[0], (1 << 22,))
    us = _time(lambda a: R.quantize_int8(a)[0], x)
    out["quantize_int8_4M"] = {"us_per_call": round(us, 1),
                               "derived":
                               f"{x.size * 4 / (us * 1e-6) / 1e9:.1f}GB/s"}

    # numerical agreement of the Pallas path (small shapes, interpret mode)
    sp = jax.random.normal(ks[0], (4096,))
    cp = jax.random.normal(ks[1], (4096,))
    err = float(jnp.max(jnp.abs(K.fused_lerp(sp, cp, 0.9)
                                - R.vc_asgd_lerp(sp, cp, 0.9))))
    out["pallas_vs_ref_lerp"] = {"us_per_call": 0.0,
                                 "derived": f"maxerr={err:.1e}"}
    return out


def bench_flat_assimilate(*, n_clients: int = 4, write_json: bool = True
                          ) -> Dict[str, Dict]:
    """flat_vs_treemap: the FlatParams bus (core/flat.py) against the
    per-leaf tree walk it replaced.

    (a) Eq. 2 assimilation — n sequential per-leaf tree.map lerp folds vs
        ONE fused pass over the stacked [n_clients, N] flat buffer;
    (b) compressed assimilation — the per-leaf × per-island top-k loop
        (compressed_assimilate_per_leaf) vs ONE global top-k per island on
        the flat bus;
    (c) launch-count evidence that the fused Pallas path is a single
        ``pallas_call`` for the whole multi-leaf model.

    Writes results/BENCH_flat_assimilate.json so the perf trajectory of the
    flat path is recorded from this PR onward.
    """
    from repro.core import flat as F
    from repro.core import vc_asgd as V
    from repro.kernels import vc_asgd_update as VK
    from repro.runtime.vc_runtime import (compressed_assimilate,
                                          compressed_assimilate_per_leaf)

    key = jax.random.PRNGKey(0)
    # multi-leaf model, heterogeneous leaf sizes (~2.1M params over 24 leaves)
    sizes = [(256, 256), (1024, 64), (64,), (512, 512), (128, 1024), (1024,)]
    tree = {}
    for rep in range(4):
        for i, shp in enumerate(sizes):
            k2 = jax.random.fold_in(key, rep * 16 + i)
            tree[f"layer{rep}/p{i}"] = jax.random.normal(k2, shp, jnp.float32)
    n_leaves = len(jax.tree.leaves(tree))
    n_params = sum(x.size for x in jax.tree.leaves(tree))
    clients = [jax.tree.map(
        lambda x, c=c: x + 0.01 * jax.random.normal(
            jax.random.fold_in(key, 1000 + c), x.shape), tree)
        for c in range(n_clients)]
    alpha = 0.9

    fp = F.flatten(tree)
    cbuf = jnp.stack([F.flatten_like(c, fp.spec) for c in clients])

    # (a) Eq. 2: per-leaf folds vs one flat pass (both XLA-jitted; on this
    # CPU container the Pallas path runs interpret-mode, so the jnp flat
    # form is the apples-to-apples timing — see module docstring)
    def per_leaf(s, cs):
        folded = s
        for c in cs:
            folded = V.vc_asgd_update(folded, c, alpha)
        return folded

    us_tree = _time(per_leaf, tree, clients, iters=20)
    us_flat = _time(lambda s, cb: V.assimilate_many_flat(s, cb, alpha),
                    fp, cbuf, iters=20)

    # (c) launch counts through the Pallas entry points (trace-time)
    VK.reset_launch_count()
    V.assimilate_many_flat(fp, cbuf, alpha, use_kernel=True)
    launches_flat = VK.launch_count()
    VK.reset_launch_count()
    for c in clients:
        V.vc_asgd_update(tree, c, alpha, use_kernel=True)
    launches_per_leaf = VK.launch_count()

    # (b) compressed assimilation: per-leaf × per-island loop vs flat global
    # (both jitted + warmed via _time, like (a) — a cold eager call would
    # mostly measure tracing the 24x4 per-leaf top-k graphs)
    islands = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    surv = jnp.ones((n_clients,), bool)

    us_comp_leaf = _time(
        lambda t, i: compressed_assimilate_per_leaf(t, i, alpha, surv,
                                                    density=0.05)[0],
        tree, islands, iters=3)
    us_comp_flat = _time(
        lambda t, i: compressed_assimilate(t, i, alpha, surv,
                                           density=0.05)[0],
        tree, islands, iters=3)

    out = {
        # no commas in derived: run.py prints name,us_per_call,derived CSV
        "model": {"us_per_call": 0.0,
                  "derived": f"{n_leaves} leaves / {int(n_params)} params / "
                             f"{n_clients} clients / padded={fp.spec.padded}"},
        "assimilate_treemap": {"us_per_call": round(us_tree, 1),
                               "derived": f"{n_leaves * n_clients} lerps"},
        "assimilate_flat": {"us_per_call": round(us_flat, 1),
                            "derived":
                            f"speedup={us_tree / max(us_flat, 1e-9):.2f}x"},
        "pallas_launches": {"us_per_call": 0.0,
                            "derived": f"flat={launches_flat} "
                                       f"per_leaf={launches_per_leaf}"},
        "compressed_per_leaf": {"us_per_call": round(us_comp_leaf, 1),
                                "derived":
                                f"{n_leaves}x{n_clients} topk calls"},
        "compressed_flat": {"us_per_call": round(us_comp_flat, 1),
                            "derived": f"speedup="
                            f"{us_comp_leaf / max(us_comp_flat, 1e-9):.2f}x"},
        "_launches": {"flat": launches_flat, "per_leaf": launches_per_leaf},
    }
    if write_json:
        results = Path(__file__).resolve().parents[1] / "results"
        results.mkdir(exist_ok=True)
        (results / "BENCH_flat_assimilate.json").write_text(
            json.dumps(out, indent=1))
    return out


def bench_flat_adam(*, write_json: bool = True) -> Dict[str, Dict]:
    """flat_vs_treemap for the OPTIMIZER: Adam with m/v as lanes of the
    FlatParams bus (Adam.update_flat) against the per-leaf tree.map path
    (Adam.update) it mirrors bit-for-bit.

    (a) wall-clock of one whole-model Adam step, both XLA-jitted (on this
        CPU container the Pallas path runs interpret-mode, so the jnp flat
        form is the apples-to-apples timing);
    (b) launch-count evidence that the fused Pallas path
        (kernels/vc_asgd_update.py::adam_update_flat) performs the whole
        multi-leaf update in a SINGLE ``pallas_call``;
    (c) one-pass checkpoint size/shape of the (params | m | v) record
        (checkpoint/store.py::save_train_checkpoint).

    Writes results/BENCH_flat_adam.json — the perf trajectory of the flat
    optimizer path is recorded from PR 2 onward.
    """
    import tempfile

    from repro.checkpoint import save_train_checkpoint
    from repro.core import flat as F
    from repro.kernels import vc_asgd_update as VK
    from repro.optim import Adam

    key = jax.random.PRNGKey(0)
    # same ~2.1M-param / 24-leaf model as bench_flat_assimilate
    sizes = [(256, 256), (1024, 64), (64,), (512, 512), (128, 1024), (1024,)]
    tree = {}
    for rep in range(4):
        for i, shp in enumerate(sizes):
            k2 = jax.random.fold_in(key, rep * 16 + i)
            tree[f"layer{rep}/p{i}"] = jax.random.normal(k2, shp, jnp.float32)
    n_leaves = len(jax.tree.leaves(tree))
    n_params = sum(x.size for x in jax.tree.leaves(tree))
    grads = jax.tree.map(
        lambda x: 0.01 * jax.random.normal(jax.random.fold_in(key, 999),
                                           x.shape), tree)

    opt = Adam(lr=1e-3, weight_decay=0.01)
    state_t = opt.init(tree)
    fp = F.flatten(tree)
    fos = opt.init_flat(fp)
    gbuf = F.flatten_like(grads, fp.spec)

    # (a) one Adam step: per-leaf tree walk vs one flat pass (both jitted)
    us_tree = _time(lambda g, s, p: opt.update(g, s, p)[0],
                    grads, state_t, tree, iters=20)
    us_flat = _time(lambda g, s, p: opt.update_flat(g, s, p)[0],
                    gbuf, fos, fp, iters=20)

    # (b) launch counts through the fused Pallas path (trace-time)
    VK.reset_launch_count()
    opt.update_flat(gbuf, fos, fp, use_kernel=True)
    launches_flat = VK.launch_count()

    # (c) the one-pass train record: (params | m | v) as one contiguous blob
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "train.msgpack"
        t0 = time.perf_counter()
        save_train_checkpoint(path, fp, fos)
        us_ckpt = (time.perf_counter() - t0) * 1e6
        ckpt_bytes = path.stat().st_size

    out = {
        # no commas in derived: run.py prints name,us_per_call,derived CSV
        "model": {"us_per_call": 0.0,
                  "derived": f"{n_leaves} leaves / {int(n_params)} params / "
                             f"padded={fp.spec.padded}"},
        "adam_treemap": {"us_per_call": round(us_tree, 1),
                         "derived": f"{n_leaves} leaf walks x3 trees"},
        "adam_flat": {"us_per_call": round(us_flat, 1),
                      "derived":
                      f"speedup={us_tree / max(us_flat, 1e-9):.2f}x"},
        "pallas_launches": {"us_per_call": 0.0,
                            "derived": f"flat={launches_flat} "
                                       f"(vs {n_leaves} per-leaf)"},
        "train_ckpt_one_pass": {"us_per_call": round(us_ckpt, 1),
                                "derived": f"{ckpt_bytes} bytes single "
                                           f"record (params|m|v)"},
        "_launches": {"flat": launches_flat},
    }
    if write_json:
        results = Path(__file__).resolve().parents[1] / "results"
        results.mkdir(exist_ok=True)
        (results / "BENCH_flat_adam.json").write_text(
            json.dumps(out, indent=1))
    return out


# pre-PR wall-clock of the compressed_flat assimilation on this container
# (the committed results/BENCH_flat_assimilate.json before the blocked
# top-k landed) — the denominator of the compression suite's speedup row
_PRE_BLOCKED_TOPK_US = 801836.2


def bench_compression(*, write_json: bool = True) -> Dict[str, Dict]:
    """The compression hot path end to end on the bench-scale bus
    (~2.1M params, density 0.05):

    (a) blocked top-k selection (core/compression.py::select_topk) and the
        full compress_flat pass (select + quantize + error feedback);
    (b) the fused wire encode leg — encode_sparse packs the frame body in
        ONE device buffer / ONE host transfer — plus decode and the dense
        decompress;
    (c) launch counts of the blocked Pallas pipeline (stats + exact-k emit
        + pack), gated by ``run.py --check`` like the other suites.

    Writes results/BENCH_compression.json.
    """
    from repro.core import compression as C
    from repro.kernels import vc_asgd_update as VK
    from repro.runtime.vc_runtime import compressed_assimilate
    from repro.transfer import wire

    key = jax.random.PRNGKey(0)
    n_logical = 2101504                  # bench-model logical params
    n_padded = 2105344                   # BLOCK=256-padded bus length
    density = 0.05
    k = max(1, int(n_logical * density))
    delta = 0.02 * jax.random.normal(key, (n_padded,), jnp.float32)
    residual = 0.002 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (n_padded,), jnp.float32)

    us_select = _time(lambda d: C.select_topk(d, k), delta, iters=5)
    us_compress = _time(
        lambda d, r: C.compress_flat(d, density=density, logical_n=n_logical,
                                     residual=r)[1],
        delta, residual, iters=5)

    payload, _ = C.compress_flat(delta, density=density, logical_n=n_logical,
                                 residual=residual)
    jax.block_until_ready(payload.values)

    frame = wire.encode_sparse(payload)          # warm the jitted pack
    t0 = time.perf_counter()
    for _ in range(10):
        frame = wire.encode_sparse(payload)
    us_encode = (time.perf_counter() - t0) / 10 * 1e6
    t0 = time.perf_counter()
    for _ in range(10):
        wire.decode(frame)
    us_decode = (time.perf_counter() - t0) / 10 * 1e6

    us_decompress = _time(
        lambda v, s, i: C.decompress_flat(
            C.CompressedDelta(v, s, i, (n_padded,), density, 256)),
        payload.values, payload.scales, payload.indices, iters=5)

    # (c) launch counts of the Pallas pipeline (trace-time, interpret mode)
    small = 0.02 * jax.random.normal(jax.random.fold_in(key, 2),
                                     (C._MIN_FAST_N,), jnp.float32)
    VK.reset_launch_count()
    K.blocked_topk_sparsify(small, int(C._MIN_FAST_N * density))
    launches_topk = VK.launch_count()
    VK.reset_launch_count()
    K.fused_quantize_pack(payload.values.astype(jnp.float32)[:4096],
                          payload.indices[:4096])
    launches_qpack = VK.launch_count()
    VK.reset_launch_count()
    K.fused_pack_body(payload.values[:4096], payload.scales[:16],
                      payload.indices[:4096])
    launches_pack = VK.launch_count()

    # end-to-end compressed assimilation on the SAME 24-leaf/4-island model
    # bench_flat_assimilate times — apples-to-apples with the committed
    # pre-PR wall-clock
    sizes = [(256, 256), (1024, 64), (64,), (512, 512), (128, 1024), (1024,)]
    tree = {}
    for rep in range(4):
        for i, shp in enumerate(sizes):
            k2 = jax.random.fold_in(key, rep * 16 + i)
            tree[f"layer{rep}/p{i}"] = jax.random.normal(k2, shp, jnp.float32)
    clients = [jax.tree.map(
        lambda x, c=c: x + 0.01 * jax.random.normal(
            jax.random.fold_in(key, 1000 + c), x.shape), tree)
        for c in range(4)]
    islands = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    surv = jnp.ones((4,), bool)
    us_total = _time(
        lambda t, i: compressed_assimilate(t, i, 0.9, surv, density=0.05)[0],
        tree, islands, iters=3)

    out = {
        # no commas in derived: run.py prints name,us_per_call,derived CSV
        "model": {"us_per_call": 0.0,
                  "derived": f"n={n_logical} padded={n_padded} k={k} "
                             f"density={density}"},
        "select_topk": {"us_per_call": round(us_select, 1),
                        "derived": "blocked exact top-k (sampled bracket)"},
        "compress_flat": {"us_per_call": round(us_compress, 1),
                          "derived": "select+quantize+error-feedback"},
        "encode_sparse": {"us_per_call": round(us_encode, 1),
                          "derived": f"{len(frame)} bytes one-transfer body"},
        "decode": {"us_per_call": round(us_decode, 1),
                   "derived": "validate+split frame"},
        "decompress_flat": {"us_per_call": round(us_decompress, 1),
                            "derived": "dequant+scatter to dense"},
        "compressed_vs_pre_pr": {
            "us_per_call": round(us_total, 1),
            "derived": f"speedup={_PRE_BLOCKED_TOPK_US / max(us_total, 1e-9):.2f}x"
                       f" vs pre-blocked-topk {_PRE_BLOCKED_TOPK_US:.0f}us"},
        "pallas_launches": {"us_per_call": 0.0,
                            "derived": f"blocked_topk={launches_topk} "
                                       f"quantize_pack={launches_qpack} "
                                       f"pack_body={launches_pack}"},
        "_launches": {"blocked_topk": launches_topk,
                      "quantize_pack": launches_qpack,
                      "pack_body": launches_pack},
    }
    if write_json:
        results = Path(__file__).resolve().parents[1] / "results"
        results.mkdir(exist_ok=True)
        (results / "BENCH_compression.json").write_text(
            json.dumps(out, indent=1))
    return out


def _bench_sharded_flat_impl(n_shards: int) -> Dict[str, Dict]:
    """Runs inside a process whose host platform has >= n_shards devices."""
    from repro.core import flat as F
    from repro.core import vc_asgd as V
    from repro.kernels import vc_asgd_update as VK
    from repro.launch.mesh import make_pod_mesh
    from repro.runtime import sharding as S

    key = jax.random.PRNGKey(0)
    # same ~2.1M-param / 24-leaf model as the other flat suites
    sizes = [(256, 256), (1024, 64), (64,), (512, 512), (128, 1024), (1024,)]
    tree = {}
    for rep in range(4):
        for i, shp in enumerate(sizes):
            k2 = jax.random.fold_in(key, rep * 16 + i)
            tree[f"layer{rep}/p{i}"] = jax.random.normal(k2, shp, jnp.float32)
    n_leaves = len(jax.tree.leaves(tree))
    n_clients = 4
    alpha = 0.9

    mesh = make_pod_mesh(n_shards)
    fp = F.flatten_sharded(tree, n_shards)
    clients = jnp.stack([fp.buf + 0.01 * (c + 1) for c in range(n_clients)])
    w = V.assimilation_weights(n_clients, alpha)

    # (a) flatten: single-host layout vs sharded layout (same leaf packing,
    # shard-aware tail) — both XLA-jitted
    us_flat_single = _time(lambda t: F.flatten(t).buf, tree, iters=20)
    us_flat_shard = _time(lambda t: F.flatten_sharded(t, n_shards).buf,
                          tree, iters=20)

    # (b) Eq. 2 assimilation: single-host fold vs per-shard shard_map
    us_assim_single = _time(
        lambda s, c: V.assimilate_many_flat(s, c, alpha), fp, clients,
        iters=20)
    us_assim_shard = _time(
        lambda sb, c: S.sharded_assimilate_flat(sb, c, w, mesh, "pod"),
        fp.buf, clients, iters=20)

    # (c) launch counts (trace-time): the sharded kernel route is STILL one
    # pallas_call for the whole model — shard_map partitions the one
    # launch, it does not multiply it
    VK.reset_launch_count()
    V.assimilate_many_flat(fp, clients, alpha, use_kernel=True)
    launches_single = VK.launch_count()
    VK.reset_launch_count()
    S.sharded_assimilate_flat(fp.buf, clients, w, mesh, "pod",
                              use_kernel=True)
    launches_shard = VK.launch_count()
    VK.reset_launch_count()
    per_leaf_clients = [F.unflatten(fp.with_buf(clients[c]))
                        for c in range(n_clients)]
    folded = tree
    for c in per_leaf_clients:
        folded = V.vc_asgd_update(folded, c, alpha, use_kernel=True)
    launches_per_leaf = VK.launch_count()

    return {
        # no commas in derived: run.py prints name,us_per_call,derived CSV
        "model": {"us_per_call": 0.0,
                  "derived": f"{n_leaves} leaves / {n_shards} shards x "
                             f"{fp.spec.shard_len} elems / "
                             f"{jax.local_device_count()} devices"},
        "flatten_single": {"us_per_call": round(us_flat_single, 1),
                           "derived": f"padded={F.flatten(tree).spec.padded}"},
        "flatten_sharded": {"us_per_call": round(us_flat_shard, 1),
                            "derived": f"padded={fp.spec.padded}"},
        "assimilate_single": {"us_per_call": round(us_assim_single, 1),
                              "derived": f"{n_clients} clients"},
        "assimilate_sharded": {"us_per_call": round(us_assim_shard, 1),
                               "derived": f"speedup={us_assim_single / max(us_assim_shard, 1e-9):.2f}x"},
        "pallas_launches": {"us_per_call": 0.0,
                            "derived": f"sharded={launches_shard} "
                                       f"single={launches_single} "
                                       f"per_leaf={launches_per_leaf}"},
        "_launches": {"sharded": launches_shard, "single": launches_single,
                      "per_leaf": launches_per_leaf},
    }


def bench_sharded_flat(*, n_shards: int = 4, write_json: bool = True
                       ) -> Dict[str, Dict]:
    """ShardedFlat: the partitioned bus (core/flat.py ShardedTreeSpec +
    runtime/sharding.py shard_map ops) against the single-host flat path —
    flatten/assimilate wall-clock and pallas launch counts on the CPU pod
    mesh.  The main process keeps one device (dry-run rules), so the
    measurement re-execs itself with xla_force_host_platform_device_count
    when needed.  Writes results/BENCH_sharded_flat.json."""
    if jax.local_device_count() >= n_shards:
        out = _bench_sharded_flat_impl(n_shards)
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count="
                              f"{n_shards}").strip()
        src = Path(__file__).resolve().parents[1] / "src"
        env["PYTHONPATH"] = f"{src}{os.pathsep}" + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.kernel_bench",
             "--emit-sharded-flat", str(n_shards)],
            capture_output=True, text=True, env=env,
            cwd=Path(__file__).resolve().parents[1], timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(f"sharded_flat subprocess failed:\n"
                               f"{proc.stderr[-3000:]}")
        out = json.loads(proc.stdout)
    if write_json:
        results = Path(__file__).resolve().parents[1] / "results"
        results.mkdir(exist_ok=True)
        (results / "BENCH_sharded_flat.json").write_text(
            json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    # subprocess entry for bench_sharded_flat's multi-device re-exec
    if len(sys.argv) >= 3 and sys.argv[1] == "--emit-sharded-flat":
        print(json.dumps(_bench_sharded_flat_impl(int(sys.argv[2]))))
    else:
        raise SystemExit("usage: kernel_bench.py --emit-sharded-flat N")
