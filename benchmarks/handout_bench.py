"""Content-addressed handout serving benchmark (``--only handout``).

Runs the registry's subscriber scenarios (10k flash-crowd / lagged
readers; 100k and 1M with --full) and reports the read-path economics
into ``results/BENCH_handout.json``: bytes SERVED to clients+subscribers
versus unique bytes ENCODED by the cache (the dedup ratio — "encode
once, serve millions"), plus the p50/p99 handout latency through the
modeled serve frontends.

Claims pinned here:

* ``flash_10k_dedup_ge_50x`` — the 10k flash-crowd scenario serves at
  least 50x more bytes than it encodes (the ISSUE acceptance bar).
* ``bf16_bytes_halved`` — the SAME flash-crowd run with
  ``handout_dtype="bfloat16"`` ships at most ~0.55x the f32 bytes
  (headers keep it from being exactly 0.5x).
* ``p99_reported`` — every subscriber scenario reports a finite p99.

``smoke_unique_to_served()`` is the --check hook: the dedup ratio of
the tiny ``handout_smoke`` scenario, gated in ``benchmarks/run.py``
against the baseline floor (results/BASELINE_launches.json) so a cache
regression that silently re-encodes per subscriber fails CI.
"""
from __future__ import annotations

import time

# CI-noise headroom for the dedup floor: the measured smoke dedup ratio
# is deterministic (same seed, same trace), but leave slack for config
# drift so the gate flags order-of-magnitude regressions, not jitter.
DEDUP_FLOOR_FRACTION = 0.5

# bf16 halves the payload; the 68-byte header per frame keeps the
# measured ratio a touch above 0.5.
BF16_BYTES_RATIO_MAX = 0.55


def _run(name: str, **overrides) -> dict:
    from repro.scenarios.registry import get

    sc = get(name)
    t0 = time.perf_counter()
    res = sc.run(**overrides)
    wall = time.perf_counter() - t0
    return {
        "bench_wall_s": round(wall, 3),
        "events_processed": res.events_processed,
        "events_per_sec": round(res.events_processed / max(wall, 1e-9), 1),
        "sim_wall_time_s": res.wall_time_s,
        "epochs_done": res.epochs_done,
        "results_assimilated": res.results_assimilated,
        "subscribers": res.subscribers,
        "sub_pulls": res.sub_pulls,
        "sub_frames_served": res.sub_frames_served,
        "sub_bytes_served": int(res.sub_bytes_served),
        "handout_bytes_served": int(res.handout_bytes_served),
        "handout_unique_bytes_encoded": int(res.handout_unique_bytes_encoded),
        "handout_dedup_ratio": round(res.handout_dedup_ratio, 1),
        "sub_latency_p50_s": round(res.sub_latency_p50_s, 6),
        "sub_latency_p99_s": round(res.sub_latency_p99_s, 6),
    }


def bench_handout(quick: bool = True) -> dict:
    names = ["handout_flash_10k", "handout_lagged_10k"]
    if not quick:
        names += ["handout_flash_100k", "handout_flash_1m"]
    out: dict = {}
    for name in names:
        out[name] = _run(name)
    # satellite: bf16 dense download frames — same flash crowd, half the
    # bytes on BOTH the served and unique-encoded side (f32 masters,
    # bf16-exact reconstruction; tests/test_handout.py pins exactness)
    bf16 = _run("handout_flash_10k", handout_dtype="bfloat16")
    out["handout_flash_10k_bf16"] = bf16
    f32 = out["handout_flash_10k"]
    bf16["bytes_vs_f32"] = round(
        bf16["handout_bytes_served"] / max(f32["handout_bytes_served"], 1), 3)
    claims = {
        "flash_10k_dedup_ge_50x": f32["handout_dedup_ratio"] >= 50.0,
        "lagged_10k_dedup_ge_10x":
            out["handout_lagged_10k"]["handout_dedup_ratio"] >= 10.0,
        "bf16_bytes_halved": bf16["bytes_vs_f32"] <= BF16_BYTES_RATIO_MAX,
        "p99_reported": all(
            out[n]["sub_latency_p99_s"] > 0.0 for n in names),
    }
    if "handout_flash_1m" in out:
        claims["flash_1m_dedup_ge_1000x"] = (
            out["handout_flash_1m"]["handout_dedup_ratio"] >= 1000.0)
    out["_claims"] = claims
    return out


def smoke_unique_to_served() -> float:
    """Dedup ratio (bytes served / unique bytes encoded) of the tiny CI
    smoke scenario — the --check floor."""
    return _run("handout_smoke")["handout_dedup_ratio"]
