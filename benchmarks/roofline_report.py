"""Roofline report generator: dryrun.json -> EXPERIMENTS.md tables,
plus the PER-KERNEL roofline gate for the compression hot path.

Per (arch x cell x mesh):
  compute_s   = HLO dot FLOPs / peak            (per device, trip-scaled)
  memory_s    = essential HBM bytes / HBM bw
  collective_s= collective wire bytes / ICI bw
  MODEL_FLOPS = analytic useful FLOPs (6*N_active*D train / 2*N*D serve
                + exact attention/recurrence terms)
  ratio       = MODEL_FLOPS / (HLO_FLOPs * n_dev)   (remat/padding waste)
  frac        = projected roofline fraction = ideal compute time / bound

Per kernel (KERNEL_ROOFLINES registry; docs/ROOFLINE.md):
  analytic_bytes   = hand-derived minimum traffic the algorithm must move
  hlo_bytes        = essential bytes parsed from the compiled HLO
  traffic_fraction = analytic / hlo  (deterministic on a pinned jaxlib —
                     extra traffic from a broken fusion lowers it)
  achieved_bw      = hlo_bytes / measured wall-clock (loose floor only)

``check_kernel_rooflines`` enforces both against
results/BASELINE_roofline.json from ``benchmarks/run.py --check``.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.runtime.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                        KernelProfile, profile_kernel)

RESULTS = Path(__file__).resolve().parents[1] / "results"
ROOFLINE_BASELINE = RESULTS / "BASELINE_roofline.json"

# gate thresholds (docs/ROOFLINE.md):
# traffic_fraction is deterministic per jaxlib, so a RELATIVE ratchet with
# 25% slack is safe (layout-level jitter across minor recompiles) while a
# doubled-bytes regression halves the fraction and always trips; the
# measured-bandwidth floor is deliberately loose — it only exists to catch
# order-of-magnitude slowdowns without letting CI wall-clock noise flake
# the gate.
FRACTION_RTOL = 0.25
BW_FLOOR_FRACTION = 0.30

_COUNTS: Dict[str, tuple] = {}


def param_counts(arch: str) -> tuple:
    """(total, active) param counts. MoE experts count at top_k/n_experts."""
    if arch in _COUNTS:
        return _COUNTS[arch]
    cfg = get_config(arch)
    specs = build_model(cfg).param_specs()
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.moe and "moe" in pstr and any(
                pstr.endswith(s) for s in ("wi", "wg", "wo")):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    _COUNTS[arch] = (total, active)
    return _COUNTS[arch]


def attn_flops_per_token(cfg: ModelConfig, ctx: int, causal_avg: bool) -> float:
    """Exact per-token attention/mixer FLOPs at context `ctx` (score+out
    einsums; projections are inside N)."""
    total = 0.0
    for b in cfg.all_blocks:
        if b.mixer == "attn":
            eff = min(ctx, b.window) if (b.attn_kind == "swa" and b.window) \
                else ctx
            if causal_avg and not (b.attn_kind == "swa" and b.window
                                   and ctx > b.window):
                eff = eff / 2            # causal average over positions
            total += 4.0 * eff * cfg.n_heads * cfg.hd
        elif b.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            total += 6.0 * di * cfg.mamba.d_state
        elif b.mixer == "rwkv":
            hd = cfg.rwkv.head_dim
            total += 4.0 * cfg.d_model * hd      # wkv out + state update
    return total


def decode_model_bytes(arch: str, cell_name: str) -> float:
    """Speed-of-light HBM bytes for one decode step: weights (bf16, once —
    shared across the batch; all experts touched when b*k >= e) + the full
    per-layer state read (KV cache / recurrent state) + O(b) writes."""
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    b, ctx = cell.global_batch, cell.seq_len
    total, active = param_counts(arch)
    n_w = total if (cfg.moe and b * cfg.moe.top_k >= cfg.moe.n_experts) \
        else active
    bytes_w = n_w * 2.0
    bytes_state = 0.0
    for blk in cfg.all_blocks:
        if blk.mixer == "attn":
            eff = min(ctx, blk.window) if (blk.attn_kind == "swa"
                                           and blk.window) else ctx
            bytes_state += b * cfg.n_kv_heads * cfg.hd * eff * 2 * 2.0
        elif blk.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            bytes_state += b * di * cfg.mamba.d_state * 4.0
        elif blk.mixer == "rwkv":
            hd = cfg.rwkv.head_dim
            bytes_state += b * cfg.d_model * hd * 4.0
    if cfg.encoder is not None:
        bytes_state += (cfg.n_layers * b * cfg.n_kv_heads * cfg.hd
                        * cfg.encoder.n_frames * 2 * 2.0)
    return bytes_w + bytes_state


def model_flops(arch: str, cell_name: str) -> float:
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    total, active = param_counts(arch)
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = b * s
        return 6.0 * active * tokens + 3.0 * tokens * attn_flops_per_token(
            cfg, s, causal_avg=True)
    if cell.kind == "prefill":
        tokens = b * s
        return 2.0 * active * tokens + tokens * attn_flops_per_token(
            cfg, s, causal_avg=True)
    # decode: one token against ctx = seq_len
    return b * (2.0 * active + attn_flops_per_token(cfg, s, causal_avg=False))


def load(path: Optional[Path] = None) -> Dict:
    return json.loads((path or RESULTS / "dryrun.json").read_text())


def row(res: Dict) -> Optional[Dict]:
    if res.get("status") != "ok" or "roofline" not in res:
        return None
    n_dev = res["n_devices"]
    mf = model_flops(res["arch"], res["cell"])
    hlo_total = res["hlo"]["dot_flops"] * n_dev
    rt = res["roofline"]
    ideal_s = mf / n_dev / PEAK_FLOPS
    if SHAPES[res["cell"]].kind == "decode":
        # decode's speed of light is HBM-bound: weights + state streaming
        ideal_s = max(ideal_s,
                      decode_model_bytes(res["arch"], res["cell"])
                      / n_dev / HBM_BW)
    bound = max(rt["compute_s"], rt["memory_s"], rt["collective_s"], 1e-12)
    return {
        "arch": res["arch"], "cell": res["cell"],
        "mesh": "2x16x16" if res["multi_pod"] else "16x16",
        "attn": res.get("attn_mode", "-"),
        "compute_s": rt["compute_s"], "memory_s": rt["memory_s"],
        "collective_s": rt["collective_s"], "dominant": rt["dominant"],
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "ratio": mf / max(hlo_total, 1.0),
        "roofline_frac": ideal_s / bound,
        "peak_gib": res["mem"]["peak_per_device"] / 2 ** 30,
        "tag": res.get("tag", ""),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(rows, single_pod_only=True) -> str:
    hdr = ("| arch | cell | attn | compute | memory | collective | dominant "
           "| MODEL/HLO | roofline frac | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if single_pod_only and r["mesh"] != "16x16":
            continue
        if r["tag"]:
            continue
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['attn']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['ratio']:.2f} | {r['roofline_frac']:.2%} | "
            f"{r['peak_gib']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# per-kernel roofline gate (the compression hot path)
# ---------------------------------------------------------------------------

# bench-scale bus (same model as benchmarks/kernel_bench.py)
_N_LOGICAL = 2101504
_N_PADDED = 2105344
_DENSITY = 0.05
_K = max(1, int(_N_LOGICAL * _DENSITY))
_NG = -(-_K // 256)


def _kernel_inputs():
    import jax.numpy as jnp
    from repro.core import compression as C
    key = jax.random.PRNGKey(7)
    delta = 0.02 * jax.random.normal(key, (_N_PADDED,), jnp.float32)
    residual = 0.002 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (_N_PADDED,), jnp.float32)
    payload, _ = C.compress_flat(delta, density=_DENSITY,
                                 logical_n=_N_LOGICAL, residual=residual)
    return delta, residual, payload


def _entry_select_topk() -> Tuple[Callable, tuple, float]:
    from repro.core import compression as C
    delta, _, _ = _kernel_inputs()
    # floor: one streaming read of the input magnitudes
    return (lambda d: C.select_topk(d, _K)), (delta,), 4.0 * _N_PADDED


def _entry_compress_flat() -> Tuple[Callable, tuple, float]:
    from repro.core import compression as C
    delta, residual, _ = _kernel_inputs()

    def f(d, r):
        p, res = C.compress_flat(d, density=_DENSITY, logical_n=_N_LOGICAL,
                                 residual=r)
        return p.values, p.scales, p.indices, res
    # floor: read delta + read residual + write residual (+payload, small)
    return f, (delta, residual), 12.0 * _N_PADDED + 5.0 * _K + 4.0 * _NG


def _entry_threshold_sparsify() -> Tuple[Callable, tuple, float]:
    from repro.kernels import ref as R
    delta, _, _ = _kernel_inputs()
    # floor: read x + write kept + write residual
    return (lambda d: R.threshold_sparsify(d, 0.01)), (delta,), \
        12.0 * _N_PADDED


def _entry_pack_body() -> Tuple[Callable, tuple, float]:
    from repro.kernels import ref as R
    _, _, payload = _kernel_inputs()
    body = float(_K + 4 * _NG + 4 * _K)
    # floor: read the three sections + write the packed body
    return (lambda q, s, i: R.pack_body(q, s, i)), \
        (payload.values, payload.scales, payload.indices), 2.0 * body


def _entry_decompress_flat() -> Tuple[Callable, tuple, float]:
    from repro.core import compression as C
    _, _, payload = _kernel_inputs()

    def f(v, s, i):
        return C.decompress_flat(
            C.CompressedDelta(v, s, i, (_N_PADDED,), _DENSITY, 256))
    # floor: read the payload + write the dense buffer
    return f, (payload.values, payload.scales, payload.indices), \
        4.0 * _N_PADDED + 5.0 * _K + 4.0 * _NG


KERNEL_ROOFLINES: Dict[str, Callable[[], Tuple[Callable, tuple, float]]] = {
    "select_topk": _entry_select_topk,
    "compress_flat": _entry_compress_flat,
    "threshold_sparsify": _entry_threshold_sparsify,
    "pack_body": _entry_pack_body,
    "decompress_flat": _entry_decompress_flat,
}


def kernel_profiles(iters: int = 5) -> Dict[str, KernelProfile]:
    out = {}
    for name, build in KERNEL_ROOFLINES.items():
        fn, args, analytic = build()
        out[name] = profile_kernel(name, fn, args, analytic, iters=iters)
    return out


def write_roofline_baseline(profiles: Optional[Dict[str, KernelProfile]]
                            = None) -> Dict:
    profiles = profiles or kernel_profiles()
    data = {name: p.as_dict() for name, p in profiles.items()}
    ROOFLINE_BASELINE.write_text(json.dumps(data, indent=1))
    return data


def check_kernel_rooflines(profiles: Optional[Dict[str, KernelProfile]]
                           = None,
                           baseline_path: Path = ROOFLINE_BASELINE) -> int:
    """Per-kernel roofline gate.  Fails (returns 1) when a kernel's
    traffic fraction drops more than FRACTION_RTOL (relative) below its
    pinned value (it moves more bytes than it used to — e.g. a fused pass
    broke apart or a buffer got duplicated) or its achieved bandwidth
    falls under BW_FLOOR_FRACTION of the pinned measurement."""
    if not baseline_path.exists():
        print(f"no roofline baseline at {baseline_path}; run "
              f"--update-baseline first", file=sys.stderr)
        return 2
    pinned = json.loads(baseline_path.read_text())
    profiles = profiles or kernel_profiles()
    failures = []
    for name, pin in pinned.items():
        prof = profiles.get(name)
        if prof is None:
            failures.append(f"{name}: kernel missing from registry")
            continue
        frac, pfrac = prof.traffic_fraction, pin["traffic_fraction"]
        floor = pfrac * (1.0 - FRACTION_RTOL)
        if frac < floor:
            failures.append(
                f"{name}: traffic fraction {frac:.3f} < pinned "
                f"{pfrac:.3f} x {1.0 - FRACTION_RTOL} (hlo bytes "
                f"{prof.hlo_bytes / 1e6:.1f}MB vs analytic "
                f"{prof.analytic_bytes / 1e6:.1f}MB)")
        else:
            print(f"check roofline {name}: fraction {frac:.3f} >= "
                  f"{floor:.3f} OK")
        bw, pbw = prof.achieved_bw, pin["achieved_gbps"] * 1e9
        if bw < pbw * BW_FLOOR_FRACTION:
            failures.append(
                f"{name}: achieved bandwidth {bw / 1e9:.2f}GB/s < "
                f"{BW_FLOOR_FRACTION:.2f} x pinned {pbw / 1e9:.2f}GB/s")
    for f in failures:
        print(f"ROOFLINE REGRESSION {f}", file=sys.stderr)
    return 1 if failures else 0


def main():
    data = load()
    rows = [r for r in (row(v) for v in data.values()) if r]
    rows.sort(key=lambda r: (ARCHS.index(r["arch"]),
                             list(SHAPES).index(r["cell"]), r["mesh"]))
    print(markdown_table(rows))
    # worst cells by roofline fraction (hillclimb candidates)
    worst = sorted((r for r in rows if r["mesh"] == "16x16" and not r["tag"]),
                   key=lambda r: r["roofline_frac"])[:8]
    print("\nWorst roofline fractions (hillclimb candidates):")
    for r in worst:
        print(f"  {r['arch']:24s} {r['cell']:12s} {r['roofline_frac']:.2%} "
              f"dom={r['dominant']}")
    coll = sorted((r for r in rows if r["mesh"] == "16x16" and not r["tag"]),
                  key=lambda r: -r["collective_s"] / max(r["compute_s"], 1e-12))[:5]
    print("\nMost collective-bound:")
    for r in coll:
        print(f"  {r['arch']:24s} {r['cell']:12s} "
              f"coll/comp={r['collective_s'] / max(r['compute_s'], 1e-12):.1f}")


if __name__ == "__main__":
    main()
