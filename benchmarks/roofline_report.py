"""Roofline report generator: dryrun.json -> EXPERIMENTS.md tables.

Per (arch x cell x mesh):
  compute_s   = HLO dot FLOPs / peak            (per device, trip-scaled)
  memory_s    = essential HBM bytes / HBM bw
  collective_s= collective wire bytes / ICI bw
  MODEL_FLOPS = analytic useful FLOPs (6*N_active*D train / 2*N*D serve
                + exact attention/recurrence terms)
  ratio       = MODEL_FLOPS / (HLO_FLOPs * n_dev)   (remat/padding waste)
  frac        = projected roofline fraction = ideal compute time / bound
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.runtime.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS = Path(__file__).resolve().parents[1] / "results"

_COUNTS: Dict[str, tuple] = {}


def param_counts(arch: str) -> tuple:
    """(total, active) param counts. MoE experts count at top_k/n_experts."""
    if arch in _COUNTS:
        return _COUNTS[arch]
    cfg = get_config(arch)
    specs = build_model(cfg).param_specs()
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.moe and "moe" in pstr and any(
                pstr.endswith(s) for s in ("wi", "wg", "wo")):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    _COUNTS[arch] = (total, active)
    return _COUNTS[arch]


def attn_flops_per_token(cfg: ModelConfig, ctx: int, causal_avg: bool) -> float:
    """Exact per-token attention/mixer FLOPs at context `ctx` (score+out
    einsums; projections are inside N)."""
    total = 0.0
    for b in cfg.all_blocks:
        if b.mixer == "attn":
            eff = min(ctx, b.window) if (b.attn_kind == "swa" and b.window) \
                else ctx
            if causal_avg and not (b.attn_kind == "swa" and b.window
                                   and ctx > b.window):
                eff = eff / 2            # causal average over positions
            total += 4.0 * eff * cfg.n_heads * cfg.hd
        elif b.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            total += 6.0 * di * cfg.mamba.d_state
        elif b.mixer == "rwkv":
            hd = cfg.rwkv.head_dim
            total += 4.0 * cfg.d_model * hd      # wkv out + state update
    return total


def decode_model_bytes(arch: str, cell_name: str) -> float:
    """Speed-of-light HBM bytes for one decode step: weights (bf16, once —
    shared across the batch; all experts touched when b*k >= e) + the full
    per-layer state read (KV cache / recurrent state) + O(b) writes."""
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    b, ctx = cell.global_batch, cell.seq_len
    total, active = param_counts(arch)
    n_w = total if (cfg.moe and b * cfg.moe.top_k >= cfg.moe.n_experts) \
        else active
    bytes_w = n_w * 2.0
    bytes_state = 0.0
    for blk in cfg.all_blocks:
        if blk.mixer == "attn":
            eff = min(ctx, blk.window) if (blk.attn_kind == "swa"
                                           and blk.window) else ctx
            bytes_state += b * cfg.n_kv_heads * cfg.hd * eff * 2 * 2.0
        elif blk.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            bytes_state += b * di * cfg.mamba.d_state * 4.0
        elif blk.mixer == "rwkv":
            hd = cfg.rwkv.head_dim
            bytes_state += b * cfg.d_model * hd * 4.0
    if cfg.encoder is not None:
        bytes_state += (cfg.n_layers * b * cfg.n_kv_heads * cfg.hd
                        * cfg.encoder.n_frames * 2 * 2.0)
    return bytes_w + bytes_state


def model_flops(arch: str, cell_name: str) -> float:
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    total, active = param_counts(arch)
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = b * s
        return 6.0 * active * tokens + 3.0 * tokens * attn_flops_per_token(
            cfg, s, causal_avg=True)
    if cell.kind == "prefill":
        tokens = b * s
        return 2.0 * active * tokens + tokens * attn_flops_per_token(
            cfg, s, causal_avg=True)
    # decode: one token against ctx = seq_len
    return b * (2.0 * active + attn_flops_per_token(cfg, s, causal_avg=False))


def load(path: Optional[Path] = None) -> Dict:
    return json.loads((path or RESULTS / "dryrun.json").read_text())


def row(res: Dict) -> Optional[Dict]:
    if res.get("status") != "ok" or "roofline" not in res:
        return None
    n_dev = res["n_devices"]
    mf = model_flops(res["arch"], res["cell"])
    hlo_total = res["hlo"]["dot_flops"] * n_dev
    rt = res["roofline"]
    ideal_s = mf / n_dev / PEAK_FLOPS
    if SHAPES[res["cell"]].kind == "decode":
        # decode's speed of light is HBM-bound: weights + state streaming
        ideal_s = max(ideal_s,
                      decode_model_bytes(res["arch"], res["cell"])
                      / n_dev / HBM_BW)
    bound = max(rt["compute_s"], rt["memory_s"], rt["collective_s"], 1e-12)
    return {
        "arch": res["arch"], "cell": res["cell"],
        "mesh": "2x16x16" if res["multi_pod"] else "16x16",
        "attn": res.get("attn_mode", "-"),
        "compute_s": rt["compute_s"], "memory_s": rt["memory_s"],
        "collective_s": rt["collective_s"], "dominant": rt["dominant"],
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "ratio": mf / max(hlo_total, 1.0),
        "roofline_frac": ideal_s / bound,
        "peak_gib": res["mem"]["peak_per_device"] / 2 ** 30,
        "tag": res.get("tag", ""),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(rows, single_pod_only=True) -> str:
    hdr = ("| arch | cell | attn | compute | memory | collective | dominant "
           "| MODEL/HLO | roofline frac | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if single_pod_only and r["mesh"] != "16x16":
            continue
        if r["tag"]:
            continue
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['attn']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['ratio']:.2f} | {r['roofline_frac']:.2%} | "
            f"{r['peak_gib']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    data = load()
    rows = [r for r in (row(v) for v in data.values()) if r]
    rows.sort(key=lambda r: (ARCHS.index(r["arch"]),
                             list(SHAPES).index(r["cell"]), r["mesh"]))
    print(markdown_table(rows))
    # worst cells by roofline fraction (hillclimb candidates)
    worst = sorted((r for r in rows if r["mesh"] == "16x16" and not r["tag"]),
                   key=lambda r: r["roofline_frac"])[:8]
    print("\nWorst roofline fractions (hillclimb candidates):")
    for r in worst:
        print(f"  {r['arch']:24s} {r['cell']:12s} {r['roofline_frac']:.2%} "
              f"dom={r['dominant']}")
    coll = sorted((r for r in rows if r["mesh"] == "16x16" and not r["tag"]),
                  key=lambda r: -r["collective_s"] / max(r["compute_s"], 1e-12))[:5]
    print("\nMost collective-bound:")
    for r in coll:
        print(f"  {r['arch']:24s} {r['cell']:12s} "
              f"coll/comp={r['collective_s'] / max(r['compute_s'], 1e-12):.1f}")


if __name__ == "__main__":
    main()
