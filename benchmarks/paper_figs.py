"""Reproductions of the paper's figures/tables (one function per artifact).

Accuracy dynamics are REAL (JAX training on the synthetic task); wall-clock
is simulated from the paper's measured constants (§IV-A sizes, §IV-D
latencies, Table I speeds).  `quick` mode shrinks epochs for CI; the full
EXPERIMENTS.md numbers use epochs=40 (the paper's horizon).
"""
from __future__ import annotations

import json
from dataclasses import asdict
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core.baselines import VCASGD
from repro.core.cost_model import fleet_cost, paper_p5c5_fleet
from repro.core.simulator import (SimConfig, SimResult, run_simulation,
                                  run_single_instance)
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha

RESULTS = Path(__file__).resolve().parents[1] / "results"
RESULTS.mkdir(exist_ok=True)


def _task_data(quick: bool):
    n = 2500 if quick else 5000
    return MLPTask(), make_classification_data(n_train=n, n_val=800)


def _base(quick: bool, **kw) -> SimConfig:
    # param_bytes/upload_bytes pin BOTH transfer legs to the paper's
    # measured 21.2MB .h5 (§IV-A) so the figure timings stay
    # paper-calibrated; outside these reproductions the simulator defaults
    # to the REAL encoded frame lengths on both legs (transfer/wire.py)
    base = dict(n_shards=20 if quick else 50,
                max_epochs=8 if quick else 40,
                local_steps=2 if quick else 4,
                subtask_compute_s=180.0, seed=11,
                param_bytes=21.2e6, upload_bytes=21.2e6)
    base.update(kw)
    return SimConfig(**base)


def _curve(res: SimResult) -> List[Dict]:
    return [dict(epoch=p.epoch, hours=p.t_complete / 3600,
                 acc=round(p.acc_mean, 4), std=round(p.acc_std, 4))
            for p in res.points]


def fig2_distributed(quick: bool = True) -> Dict:
    """Fig. 2: accuracy vs time for P1C3T2 / P1C3T8 / P3C3T8 / P5C5T2,
    alpha = 0.95."""
    task, data = _task_data(quick)
    out = {}
    for name, (P, C, T) in {"P1C3T2": (1, 3, 2), "P1C3T8": (1, 3, 8),
                            "P3C3T8": (3, 3, 8), "P5C5T2": (5, 5, 2)}.items():
        cfg = _base(quick, n_param_servers=P, n_clients=C, tasks_per_client=T)
        res = run_simulation(task, data, VCASGD(0.95), cfg)
        out[name] = {"curve": _curve(res),
                     "final_acc": round(res.final_accuracy, 4),
                     "hours": round(res.wall_time_s / 3600, 3)}
    # paper claim: all configs converge to similar accuracy, times differ
    finals = [v["final_acc"] for v in out.values()]
    out["_claims"] = {
        "similar_final_accuracy": bool(max(finals) - min(finals) < 0.08),
        "times_differ": bool(max(v["hours"] for v in out.values()
                                 if isinstance(v, dict) and "hours" in v)
                             > 1.15 * min(v["hours"] for v in out.values()
                                          if isinstance(v, dict)
                                          and "hours" in v)),
    }
    return out


def fig3_server_scaling(quick: bool = True) -> Dict:
    """Fig. 3: training time vs (Pn, Tn) — server backlog when Cn*Tn results
    outrun Pn serial assimilation."""
    task, data = _task_data(quick)
    out = {}
    for P, C in ((1, 3), (3, 3), (5, 5)):
        for T in (2, 4, 8):
            cfg = _base(quick, n_param_servers=P, n_clients=C,
                        tasks_per_client=T, server_proc_s=4.0)
            res = run_simulation(task, data, VCASGD(0.95), cfg)
            out[f"P{P}C{C}T{T}"] = round(res.wall_time_s / 3600, 3)
    out["_claims"] = {
        # P1C3T8 backlogs behind P3C3T8 (paper: ~3h gap at 40 epochs)
        "P3_faster_than_P1_at_T8": out["P3C3T8"] < out["P1C3T8"],
    }
    return out


def fig4_alpha(quick: bool = True) -> Dict:
    """Fig. 4/5: alpha in {0.7, 0.95, 0.999, Var} on P3C3T4."""
    task, data = _task_data(quick)
    out = {}
    schemes = {"0.7": VCASGD(0.7), "0.95": VCASGD(0.95),
               "0.999": VCASGD(0.999), "var": VCASGD(var_alpha())}
    for name, scheme in schemes.items():
        cfg = _base(quick, n_param_servers=3, n_clients=3, tasks_per_client=4)
        res = run_simulation(task, data, scheme, cfg)
        out[name] = {"curve": _curve(res),
                     "final_acc": round(res.final_accuracy, 4),
                     "mean_std": round(float(np.mean([p.acc_std
                                                      for p in res.points])), 4)}
    early = {k: v["curve"][min(2, len(v["curve"]) - 1)]["acc"]
             for k, v in out.items()}
    out["_claims"] = {
        # small alpha learns faster early (rate prop. to 1-alpha)
        "alpha07_faster_early_than_0999": early["0.7"] > early["0.999"],
        # alpha=0.999 (EASGD-equivalent) is the slowest overall
        "alpha0999_slowest": out["0.999"]["final_acc"]
        == min(v["final_acc"] for k, v in out.items() if not k.startswith("_")),
        # var schedule at least matches 0.95 with smaller spread
        "var_competitive": out["var"]["final_acc"]
        >= out["0.95"]["final_acc"] - 0.02,
        "var_lower_std_than_07": out["var"]["mean_std"]
        <= out["0.7"]["mean_std"] + 1e-9,
    }
    return out


def fig6_vs_serial(quick: bool = True) -> Dict:
    """Fig. 6: distributed (P5C5T2, var alpha) vs single-instance serial."""
    task, data = _task_data(quick)
    cfg = _base(quick, n_param_servers=5, n_clients=5, tasks_per_client=2)
    dist = run_simulation(task, data, VCASGD(var_alpha()), cfg)
    serial = run_single_instance(task, data, max_epochs=cfg.max_epochs,
                                 steps_per_epoch=120 if quick else 250,
                                 epoch_time_s=dist.wall_time_s
                                 / max(dist.epochs_done, 1))
    gaps = []
    for pd, ps in zip(dist.points, serial.points):
        gaps.append(ps.acc_mean - pd.acc_mean)
    out = {
        "distributed": _curve(dist), "serial": _curve(serial),
        "final_gap": round(gaps[-1], 4) if gaps else None,
        "early_gap": round(gaps[min(2, len(gaps) - 1)], 4) if gaps else None,
        "dist_smoother": bool(np.std(np.diff([p.acc_mean for p in dist.points]))
                              <= np.std(np.diff([p.acc_mean
                                                 for p in serial.points]))),
    }
    out["_claims"] = {
        # serial >= distributed at matched epochs, gap shrinks over time
        "serial_ahead": (out["final_gap"] is not None
                         and out["final_gap"] > -0.02),
        "gap_narrows": (out["early_gap"] is not None
                        and out["final_gap"] <= out["early_gap"] + 0.02),
    }
    return out


def consistency_bench(quick: bool = True) -> Dict:
    """§IV-D: Redis (eventual) vs MySQL (strong) — per-update latency and
    the projected overhead at CIFAR10 (2k updates) / ImageNet (1.6M) scale."""
    from repro.core.consistency import MYSQL_UPDATE_S, REDIS_UPDATE_S
    task, data = _task_data(quick)
    res = {}
    for mode in ("eventual", "strong"):
        cfg = _base(quick, n_param_servers=3, n_clients=3,
                    tasks_per_client=4, consistency=mode)
        r = run_simulation(task, data, VCASGD(0.95), cfg)
        res[mode] = {"hours": round(r.wall_time_s / 3600, 3),
                     "lost_updates": r.store_stats.lost_updates,
                     "queue_wait_s": round(r.store_stats.queue_wait_s, 1),
                     "final_acc": round(r.final_accuracy, 4)}
    per_update_gap = MYSQL_UPDATE_S - REDIS_UPDATE_S
    res["projection"] = {
        "per_update_ratio": round(MYSQL_UPDATE_S / REDIS_UPDATE_S, 3),
        "cifar_2000_updates_overhead_min": round(2000 * per_update_gap / 60, 1),
        "imagenet_1p6m_updates_overhead_hr":
            round(1_600_000 * per_update_gap / 3600, 1),
    }
    res["_claims"] = {
        "ratio_1p5x": abs(MYSQL_UPDATE_S / REDIS_UPDATE_S - 1.5) < 0.05,
        "cifar_overhead_14min": abs(
            res["projection"]["cifar_2000_updates_overhead_min"] - 14) < 1.0,
        "imagenet_overhead_187hr": abs(
            res["projection"]["imagenet_1p6m_updates_overhead_hr"] - 187) < 5,
        "strong_no_loss": res["strong"]["lost_updates"] == 0,
        "eventual_acc_tolerates_loss": abs(res["eventual"]["final_acc"]
                                           - res["strong"]["final_acc"]) < 0.1,
    }
    return res


def cost_bench(quick: bool = True) -> Dict:
    """§IV-E: preemptible vs standard fleet cost for the P5C5T2 run."""
    fleet = paper_p5c5_fleet()
    rep = fleet_cost(fleet, hours=8.0)
    out = {
        "fleet_std_per_hr": round(rep.fleet_std_per_hr, 3),
        "fleet_pre_per_hr": round(rep.fleet_pre_per_hr, 3),
        "run_8h_std": round(rep.total_std, 2),
        "run_8h_pre": round(rep.total_pre, 2),
        "saving_frac": round(rep.saving_frac, 3),
    }
    out["_claims"] = {
        # paper: $1.67/hr std, $0.50/hr preemptible, 70% saving, $4 vs $13.4
        "std_rate_matches": abs(out["fleet_std_per_hr"] - 1.67) < 0.35,
        "saving_70_90pct": 0.69 <= out["saving_frac"] <= 0.91,
        "run_cost_band": out["run_8h_pre"] < 0.35 * out["run_8h_std"],
    }
    return out
