"""Cost/throughput frontier over the scenario registry (§IV-E extension).

The paper prices ONE fixed 5-instance fleet (standard vs preemptible).
The scenario registry spans a much wider operating space — correlated AZ
reclaims, spot-price churn, diurnal volunteers, heterogeneous tiers — and
each point trades assimilation throughput against fleet cost differently.
This bench runs each frontier scenario deterministically, re-prices its
exact fleet through core/cost_model.fleet_cost (per-instance Table I
prices, server always on-demand), and emits one frontier point per
scenario:

    results_per_hour   assimilated results / simulated hour
    usd_per_1k_pre     preemptible-fleet dollars per 1000 results
    saving_frac        1 - preemptible/standard $/hr
    wire_gb            upload bytes actually shipped (real frames)

Points on the Pareto front (max throughput, min $/1k results) are marked;
``benchmarks/run.py --only frontier`` writes results/BENCH_frontier.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

from repro.core.cost_model import fleet_cost
from repro.core.preemption import PreemptionModel, make_fleet
from repro.scenarios.registry import SCENARIOS, Scenario, get

RESULTS = Path(__file__).resolve().parents[1] / "results"

# the frontier slice of the registry: the CI smoke point plus every
# behaviour scenario (each opens a different preemption/heterogeneity axis)
FRONTIER_SCENARIOS = ("fleet_smoke", "az_reclaim", "spot_price",
                      "diurnal", "tiered")


def _fleet_for(sc: Scenario):
    """Rebuild the exact fleet the simulator will use (same seeds), so the
    pricing below matches the simulated instance mix."""
    cfg = sc.config()
    if cfg.fleet_fn is not None:
        return cfg.fleet_fn(cfg)
    pre = PreemptionModel(mean_lifetime_s=cfg.mean_lifetime_s,
                          restart_delay_s=cfg.restart_delay_s,
                          enabled=cfg.preemptible)
    return make_fleet(cfg.n_clients, seed=cfg.seed, preemption=pre)


def _point(sc: Scenario) -> Dict:
    t0 = time.perf_counter()
    res = sc.run()
    bench_wall = time.perf_counter() - t0
    hours = max(res.wall_time_s, 1.0) / 3600.0
    itypes = [c.itype for c in _fleet_for(sc)]
    report = fleet_cost(itypes, hours, include_server=True)
    results_per_hour = res.results_assimilated / hours
    usd_per_1k_pre = (report.total_pre
                      / max(res.results_assimilated, 1) * 1000.0)
    return {
        "scenario": sc.name,
        "n_clients": sc.config().n_clients,
        "sim_hours": round(hours, 3),
        "bench_wall_s": round(bench_wall, 2),
        "results_assimilated": res.results_assimilated,
        "results_per_hour": round(results_per_hour, 1),
        "fleet_std_per_hr": round(report.fleet_std_per_hr, 2),
        "fleet_pre_per_hr": round(report.fleet_pre_per_hr, 2),
        "total_usd_std": round(report.total_std, 2),
        "total_usd_pre": round(report.total_pre, 2),
        "saving_frac": round(report.saving_frac, 4),
        "usd_per_1k_pre": round(usd_per_1k_pre, 3),
        "preemptions": res.preemptions,
        "wire_gb": round(res.wire.bytes_sent / 2 ** 30, 3),
    }


def _pareto(points: List[Dict]) -> List[str]:
    """Non-dominated set: maximize results_per_hour, minimize
    usd_per_1k_pre."""
    front = []
    for p in points:
        dominated = any(
            q["results_per_hour"] >= p["results_per_hour"]
            and q["usd_per_1k_pre"] <= p["usd_per_1k_pre"]
            and (q["results_per_hour"] > p["results_per_hour"]
                 or q["usd_per_1k_pre"] < p["usd_per_1k_pre"])
            for q in points)
        if not dominated:
            front.append(p["scenario"])
    return front


def bench_frontier(quick: bool = True, *, write_json: bool = True) -> Dict:
    names = FRONTIER_SCENARIOS if quick else tuple(
        list(FRONTIER_SCENARIOS) + ["fleet_1k"])
    points = [_point(get(n)) for n in names]
    front = _pareto(points)
    for p in points:
        p["pareto"] = p["scenario"] in front
    out = {
        "points": points,
        "pareto_front": front,
        "_claims": {
            "pareto_nonempty": bool(front),
            # §IV-E: preemptible fleets must stay in the published 70-90%
            # discount band for every scenario's instance mix
            "saving_in_paper_band": all(
                0.5 <= p["saving_frac"] <= 0.95 for p in points),
            "all_scenarios_assimilate": all(
                p["results_assimilated"] > 0 for p in points),
        },
    }
    if write_json:
        RESULTS.mkdir(exist_ok=True)
        (RESULTS / "BENCH_frontier.json").write_text(
            json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    print(json.dumps(bench_frontier(), indent=1))
