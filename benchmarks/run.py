"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (contract) and writes the full
structured results (curves, claims) to results/bench_*.json.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,cost]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"
RESULTS.mkdir(exist_ok=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (40 epochs, 50 shards)")
    ap.add_argument("--only", default="",
                    help="comma list: fig2,fig3,fig4,fig6,consistency,cost,"
                         "kernels,flat,flat_adam")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import paper_figs as F
    from benchmarks.kernel_bench import (bench_flat_adam,
                                         bench_flat_assimilate, bench_kernels)

    benches = {
        "fig2": lambda: F.fig2_distributed(quick),
        "fig3": lambda: F.fig3_server_scaling(quick),
        "fig4": lambda: F.fig4_alpha(quick),
        "fig6": lambda: F.fig6_vs_serial(quick),
        "consistency": lambda: F.consistency_bench(quick),
        "cost": lambda: F.cost_bench(quick),
        "kernels": bench_kernels,
        "flat": bench_flat_assimilate,
        "flat_adam": bench_flat_adam,
    }

    print("name,us_per_call,derived")
    all_claims = {}
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        res = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        out = RESULTS / f"bench_{name}.json"
        out.write_text(json.dumps(res, indent=1, default=str))
        claims = res.pop("_claims", None) if isinstance(res, dict) else None
        if name in ("kernels", "flat", "flat_adam"):
            for k, v in res.items():
                print(f"{name}.{k},{v['us_per_call']},{v['derived']}")
        else:
            ok = (all(claims.values()) if claims else True)
            n_claims = len(claims) if claims else 0
            n_ok = sum(claims.values()) if claims else 0
            fails = ("" if ok else " FAILED:"
                     + str([k for k, v in claims.items() if not v]))
            print(f"{name},{dt_us:.0f},claims:{n_ok}/{n_claims}{fails}")
        if claims:
            all_claims[name] = claims
    (RESULTS / "bench_claims.json").write_text(
        json.dumps(all_claims, indent=1))


if __name__ == "__main__":
    main()
