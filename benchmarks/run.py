"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (contract) and writes ONE
canonical ``results/BENCH_<suite>.json`` per suite (plus the aggregated
claims in ``results/BENCH_claims.json``).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,cost]
  PYTHONPATH=src python -m benchmarks.run --check

``--check`` is the perf gate: it re-runs every launch-count-bearing suite
and fails (exit 1) if any suite's pallas launch counts regressed versus
the committed baseline (results/BASELINE_launches.json) — the fused
single-launch structure is the one perf property this CPU container can
pin exactly.  It ALSO runs the fleet smoke scenario and fails if its
event-loop throughput drops below the baselined events/sec floor
(baseline * FLOOR_FRACTION, so CI noise doesn't flake the gate), and the
per-kernel ROOFLINE gate (results/BASELINE_roofline.json): compiled-HLO
traffic per compression kernel vs its hand-derived analytic minimum, plus
a loose measured-bandwidth floor (see docs/ROOFLINE.md).  Before any of
that it runs the STATIC tier — ``tools/vclint.py --json`` against
results/BASELINE_vclint.json (exit 2 if no baseline is pinned; see
docs/LINT.md) — so protocol/wire/kernel invariant violations fail the
gate without running a single benchmark.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"
RESULTS.mkdir(exist_ok=True)

# one canonical BENCH_*.json name per suite (bench fns that write their own
# canonical file use the same name, so there is exactly ONE copy on disk)
CANONICAL = {
    "flat": "BENCH_flat_assimilate",
    "flat_adam": "BENCH_flat_adam",
    "sharded_flat": "BENCH_sharded_flat",
}

BASELINE = RESULTS / "BASELINE_launches.json"
# suites that carry a numeric _launches dict, gated by --check
LAUNCH_SUITES = ("flat", "flat_adam", "sharded_flat", "compression")


def _out_path(name: str) -> Path:
    return RESULTS / f"{CANONICAL.get(name, 'BENCH_' + name)}.json"


def check_vclint() -> int:
    """Static tier of the gate: run ``tools/vclint.py --json`` and defer
    to its ratchet exit code (0 clean, 1 new violations vs
    results/BASELINE_vclint.json, 2 no baseline pinned — re-pin with
    ``tools/vclint.py --update-baseline``, which --update-baseline here
    also does)."""
    import subprocess
    root = RESULTS.parent
    proc = subprocess.run(
        [sys.executable, str(root / "tools" / "vclint.py"), "--json"],
        capture_output=True, text=True, cwd=root)
    try:
        doc = json.loads(proc.stdout)
        print(f"check vclint: {doc['total']} violations in "
              f"{doc['files_checked']} files "
              f"({len(doc['rules_run'])} rules)")
    except (json.JSONDecodeError, KeyError):
        print(proc.stdout, file=sys.stderr)
    if proc.returncode:
        err = proc.stderr.strip()
        print(f"STATIC REGRESSION {err or 'vclint gate failed'}",
              file=sys.stderr)
    return proc.returncode


def check_launches(benches) -> int:
    """Re-run the launch-bearing suites and compare their _launches dicts
    against the committed baseline.  A HIGHER count than baseline is a
    regression (a fused pass broke apart); lower is an improvement (run
    with --update-baseline to ratchet it down)."""
    if not BASELINE.exists():
        print(f"no baseline at {BASELINE}; run --update-baseline first",
              file=sys.stderr)
        return 2
    baseline = json.loads(BASELINE.read_text())
    failures = []
    for name in LAUNCH_SUITES:
        res = benches[name]()
        _out_path(name).write_text(json.dumps(res, indent=1, default=str))
        current = res.get("_launches", {})
        base = baseline.get(name, {})
        for path_name, count in current.items():
            allowed = base.get(path_name)
            if allowed is None:
                failures.append(f"{name}.{path_name}: no baseline entry "
                                f"(current={count})")
            elif count > allowed:
                failures.append(f"{name}.{path_name}: {count} launches > "
                                f"baseline {allowed}")
            else:
                print(f"check {name}.{path_name}: {count} <= {allowed} OK")
    # event-loop throughput floor (fleet smoke scenario)
    from benchmarks.fleet_bench import FLOOR_FRACTION, smoke_events_per_sec
    base_eps = baseline.get("fleet", {}).get("smoke_events_per_sec")
    if base_eps is None:
        failures.append("fleet.smoke_events_per_sec: no baseline entry")
    else:
        eps = smoke_events_per_sec()
        floor = base_eps * FLOOR_FRACTION
        if eps < floor:
            failures.append(f"fleet.smoke_events_per_sec: {eps:.0f} < "
                            f"floor {floor:.0f} (baseline {base_eps:.0f})")
        else:
            print(f"check fleet.smoke_events_per_sec: {eps:.0f} >= "
                  f"{floor:.0f} OK")
    # handout dedup floor (content-addressed cache must keep serving
    # many more bytes than it encodes on the smoke subscriber scenario)
    from benchmarks.handout_bench import (DEDUP_FLOOR_FRACTION,
                                          smoke_unique_to_served)
    base_dedup = baseline.get("handout", {}).get("smoke_unique_to_served")
    if base_dedup is None:
        failures.append("handout.smoke_unique_to_served: no baseline entry")
    else:
        dedup = smoke_unique_to_served()
        floor = base_dedup * DEDUP_FLOOR_FRACTION
        if dedup < floor:
            failures.append(f"handout.smoke_unique_to_served: {dedup:.1f}x "
                            f"< floor {floor:.1f}x (baseline "
                            f"{base_dedup:.1f}x)")
        else:
            print(f"check handout.smoke_unique_to_served: {dedup:.1f}x >= "
                  f"{floor:.1f}x OK")
    # per-kernel roofline gate (results/BASELINE_roofline.json)
    from benchmarks.roofline_report import check_kernel_rooflines
    rc = check_kernel_rooflines()
    if rc:
        failures.append("kernel roofline gate failed (see above)")
    if failures:
        for f in failures:
            print(f"PERF REGRESSION {f}", file=sys.stderr)
        return 1
    print("launch-count + events/sec + dedup + roofline check passed")
    return 0


def update_baseline(benches) -> None:
    from benchmarks.fleet_bench import smoke_events_per_sec
    from benchmarks.handout_bench import smoke_unique_to_served
    from benchmarks.roofline_report import (ROOFLINE_BASELINE,
                                            write_roofline_baseline)
    out = {}
    for name in LAUNCH_SUITES:
        res = benches[name]()
        _out_path(name).write_text(json.dumps(res, indent=1, default=str))
        out[name] = res.get("_launches", {})
    out["fleet"] = {"smoke_events_per_sec": round(smoke_events_per_sec(), 1)}
    out["handout"] = {
        "smoke_unique_to_served": round(smoke_unique_to_served(), 1)}
    BASELINE.write_text(json.dumps(out, indent=1))
    print(f"wrote {BASELINE}: {json.dumps(out)}")
    write_roofline_baseline()
    print(f"wrote {ROOFLINE_BASELINE}")
    import subprocess
    subprocess.run(
        [sys.executable, str(RESULTS.parent / "tools" / "vclint.py"),
         "--update-baseline"], check=True, cwd=RESULTS.parent)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (40 epochs, 50 shards)")
    ap.add_argument("--only", default="",
                    help="comma list: fig2,fig3,fig4,fig6,consistency,cost,"
                         "kernels,flat,flat_adam,sharded_flat,fleet,"
                         "compression,frontier,handout")
    ap.add_argument("--check", action="store_true",
                    help="fail if vclint or any BENCH_*.json launch count "
                         "regresses vs the committed baselines")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite results/BASELINE_launches.json (and the "
                         "vclint baseline) from a fresh run")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import paper_figs as F
    from benchmarks.fleet_bench import bench_fleet
    from benchmarks.frontier_bench import bench_frontier
    from benchmarks.handout_bench import bench_handout
    from benchmarks.kernel_bench import (bench_compression, bench_flat_adam,
                                         bench_flat_assimilate,
                                         bench_kernels, bench_sharded_flat)

    benches = {
        "fig2": lambda: F.fig2_distributed(quick),
        "fig3": lambda: F.fig3_server_scaling(quick),
        "fig4": lambda: F.fig4_alpha(quick),
        "fig6": lambda: F.fig6_vs_serial(quick),
        "consistency": lambda: F.consistency_bench(quick),
        "cost": lambda: F.cost_bench(quick),
        "kernels": bench_kernels,
        "flat": bench_flat_assimilate,
        "flat_adam": bench_flat_adam,
        "sharded_flat": bench_sharded_flat,
        "compression": bench_compression,
        "fleet": lambda: bench_fleet(quick),
        "frontier": lambda: bench_frontier(quick),
        "handout": lambda: bench_handout(quick),
    }

    if args.check:
        raise SystemExit(check_vclint() or check_launches(benches))
    if args.update_baseline:
        update_baseline(benches)
        return

    print("name,us_per_call,derived")
    all_claims = {}
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        res = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        _out_path(name).write_text(json.dumps(res, indent=1, default=str))
        claims = res.pop("_claims", None) if isinstance(res, dict) else None
        if name in ("kernels", "flat", "flat_adam", "sharded_flat",
                    "compression"):
            for k, v in res.items():
                if k.startswith("_"):
                    continue
                print(f"{name}.{k},{v['us_per_call']},{v['derived']}")
        else:
            ok = (all(claims.values()) if claims else True)
            n_claims = len(claims) if claims else 0
            n_ok = sum(claims.values()) if claims else 0
            fails = ("" if ok else " FAILED:"
                     + str([k for k, v in claims.items() if not v]))
            print(f"{name},{dt_us:.0f},claims:{n_ok}/{n_claims}{fails}")
        if claims:
            all_claims[name] = claims
    if all_claims:
        # merge-on-write: a partial --only run must not drop the claims
        # recorded by suites it didn't run
        path = RESULTS / "BENCH_claims.json"
        merged = json.loads(path.read_text()) if path.exists() else {}
        merged.update(all_claims)
        path.write_text(json.dumps(merged, indent=1))


if __name__ == "__main__":
    main()
