import os
import sys
from pathlib import Path

# NOTE: we deliberately do NOT set xla_force_host_platform_device_count here
# — smoke tests and benches must see 1 device (multi-device tests spawn
# subprocesses).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
# tests/ itself, for the _hyp hypothesis-fallback helper
sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
