"""Wire format v1 + transports (transfer/): encode/decode round-trips over
arbitrary payloads, hard rejection of torn/corrupt frames (a damaged
transfer must NEVER be assimilated), and the simulator's real byte
accounting — frame lengths are measured off encoded payloads, not assumed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import compression as C
from repro.transfer import (LoopbackTransport, TransportError, wire)
from repro.transfer.wire import WireError


def _delta(key, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), (n,)) * scale


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [1, 255, 8192, 16384 + 7])
def test_dense_roundtrip(dtype, n):
    buf = _delta(0, n).astype(dtype)
    frame = wire.encode(buf)
    assert len(frame) == wire.dense_frame_bytes(n, str(jnp.dtype(dtype)))
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_DENSE
    out = np.asarray(msg.payload)
    assert out.dtype == np.asarray(buf).dtype
    np.testing.assert_array_equal(np.asarray(buf, np.float32),
                                  out.astype(np.float32))


@pytest.mark.parametrize("density,n,logical",
                         [(0.01, 8192, 8192), (0.25, 16384, 13130),
                          (1.0, 8192, 100), (0.05, 3 * 8192, 20000)])
def test_sparse_roundtrip(density, n, logical):
    payload, _ = C.compress_flat(_delta(1, n), density=density,
                                 logical_n=logical)
    frame = wire.encode(payload)
    assert len(frame) == wire.sparse_frame_bytes(int(payload.values.size),
                                                 payload.block)
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_SPARSE
    q = msg.payload
    np.testing.assert_array_equal(np.asarray(payload.values),
                                  np.asarray(q.values))
    np.testing.assert_array_equal(np.asarray(payload.indices),
                                  np.asarray(q.indices))
    np.testing.assert_array_equal(np.asarray(payload.scales),
                                  np.asarray(q.scales))
    assert q.shape == (n,) and q.block == payload.block
    np.testing.assert_array_equal(np.asarray(C.decompress_flat(payload)),
                                  np.asarray(C.decompress_flat(q)))


def test_roundtrip_bookkeeping_fields():
    """round / residual_norm ride the header (error-feedback bookkeeping)."""
    payload, res = C.compress_flat(_delta(2, 8192), density=0.1)
    rn = float(jnp.linalg.norm(res))
    msg = wire.decode(wire.encode(payload, round=17, residual_norm=rn))
    assert msg.round == 17
    assert abs(msg.residual_norm - rn) < 1e-3 * max(1.0, rn)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_sparse_roundtrip(data):
    """Arbitrary (length, density, block) round-trips exactly."""
    n_blocks = data.draw(st.integers(min_value=1, max_value=6))
    n = n_blocks * 8192
    logical = data.draw(st.integers(min_value=1, max_value=n))
    density = data.draw(st.floats(min_value=0.001, max_value=1.0))
    block = data.draw(st.sampled_from([32, 256, 1024]))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16))
    payload, _ = C.compress_flat(_delta(seed, n), density=density,
                                 block=block, logical_n=logical)
    frame = wire.encode(payload)
    assert len(frame) == wire.sparse_frame_bytes(int(payload.values.size),
                                                 block)
    q = wire.decode(frame).payload
    np.testing.assert_array_equal(np.asarray(C.decompress_flat(payload)),
                                  np.asarray(C.decompress_flat(q)))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_dense_roundtrip(data):
    n = data.draw(st.integers(min_value=1, max_value=70000))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16))
    buf = _delta(seed, n)
    out = np.asarray(wire.decode(wire.encode(buf)).payload)
    np.testing.assert_array_equal(np.asarray(buf), out)


# ---------------------------------------------------------------------------
# torn / corrupt frames are rejected, never assimilated
# ---------------------------------------------------------------------------

def _frames():
    dense = wire.encode(_delta(3, 8192))
    sparse = wire.encode(C.compress_flat(_delta(4, 8192), density=0.1)[0])
    return [dense, sparse]


@pytest.mark.parametrize("i", [0, 1])
def test_truncated_frame_rejected(i):
    frame = _frames()[i]
    for cut in (len(frame) - 1, len(frame) // 2, wire.HEADER_BYTES,
                wire.HEADER_BYTES - 1, 3, 0):
        with pytest.raises(WireError):
            wire.decode(frame[:cut])


@pytest.mark.parametrize("i", [0, 1])
def test_bitflip_rejected(i):
    """The crc covers header-sans-crc || body: a flip ANYWHERE in the
    frame — the n/k/density header fields included — is rejected."""
    frame = _frames()[i]
    header_positions = (6, 8, 16, 24, 28, 36, 40, 48, 56)
    body_positions = (wire.HEADER_BYTES, len(frame) - 1,
                      (wire.HEADER_BYTES + len(frame)) // 2)
    for pos in header_positions + body_positions:
        bad = bytearray(frame)
        bad[pos] ^= 0x41
        with pytest.raises(WireError):
            wire.decode(bytes(bad))


def test_bad_magic_and_future_version_rejected():
    frame = _frames()[0]
    bad = bytearray(frame)
    bad[0] ^= 0xFF
    with pytest.raises(WireError, match="magic"):
        wire.decode(bytes(bad))
    newer = bytearray(frame)
    newer[4] = 0xFF                               # version u16 lo byte
    with pytest.raises(WireError, match="version"):
        wire.decode(bytes(newer))


def test_oversized_frame_rejected():
    frame = _frames()[0]
    with pytest.raises(WireError):
        wire.decode(frame + b"\x00" * 8)


# ---------------------------------------------------------------------------
# loopback transport
# ---------------------------------------------------------------------------

def test_loopback_transport_accounting():
    t = LoopbackTransport()
    frames = _frames()
    ids = [t.send(f) for f in frames]
    assert t.in_flight == 2
    assert t.stats.frames_sent == 2
    assert t.stats.bytes_sent == sum(len(f) for f in frames)
    # out-of-order delivery by id
    assert t.recv(ids[1]) == frames[1]
    assert t.recv(ids[0]) == frames[0]
    assert t.stats.bytes_recv == t.stats.bytes_sent
    with pytest.raises(TransportError):
        t.recv(ids[0])                            # exactly-once delivery
    mid = t.send(frames[0])
    t.drop(mid)
    assert t.stats.frames_dropped == 1
    assert t.stats.bytes_dropped == len(frames[0])
    assert t.in_flight == 0


# ---------------------------------------------------------------------------
# the simulator puts REAL bytes on the wire (asserted, not simulated)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def task_data():
    from repro.core.tasks import MLPTask, make_classification_data
    return MLPTask(), make_classification_data(n_train=2000, n_val=400)


def _sim(task, data, scheme, **kw):
    from repro.core.simulator import SimConfig, run_simulation
    base = dict(n_param_servers=2, n_clients=3, tasks_per_client=2,
                n_shards=12, max_epochs=2, local_steps=2,
                subtask_compute_s=120.0, seed=1)
    base.update(kw)
    return run_simulation(task, data, scheme, SimConfig(**base))


def test_simulator_dense_byte_counts(task_data):
    """Every full-weight payload is one dense frame whose length is the
    flat bus size — totals are sums of measured frame lengths."""
    from repro.core import flat as F
    from repro.core.baselines import VCASGD
    task, data = task_data
    res = _sim(task, data, VCASGD(0.95))
    padded = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec.padded
    per_frame = wire.dense_frame_bytes(padded)
    assert res.results_assimilated > 0
    assert res.wire_dense_frames == res.results_assimilated
    assert res.wire_sparse_frames == 0
    assert res.wire.frames_sent == res.wire.frames_recv  # nothing torn/lost
    assert res.wire.bytes_sent == res.wire.frames_sent * per_frame
    assert res.wire.bytes_recv == res.wire.bytes_sent


def test_simulator_compressed_byte_counts(task_data):
    """compress_flat payloads travel as sparse frames: per-frame length is
    exactly header + k int8 + ceil(k/block) f32 + k int32."""
    from repro.core import flat as F
    from repro.core.baselines import CompressedVCASGD
    task, data = task_data
    density = 0.05
    res = _sim(task, data, CompressedVCASGD(0.95, density=density))
    spec = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec
    k = max(1, min(spec.n, int(spec.n * density)))
    per_frame = wire.sparse_frame_bytes(k)
    assert res.wire_sparse_frames == res.results_assimilated > 0
    assert res.wire.bytes_sent == res.wire.frames_sent * per_frame
    # the sparse path actually compresses vs the dense frames
    assert per_frame < wire.dense_frame_bytes(spec.padded) / 4


def test_simulator_easgd_flat_pod_compressed(task_data):
    """EASGDFlatPod rides the same wire: with compress_density set, every
    replica payload is a sparse frame (byte counts asserted) and training
    still completes."""
    from repro.core import flat as F
    from repro.core.baselines import EASGDFlatPod
    task, data = task_data
    res = _sim(task, data,
               EASGDFlatPod(n_replicas=3, beta=0.05, compress_density=0.1))
    spec = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec
    k = max(1, min(spec.n, int(spec.n * 0.1)))
    assert res.epochs_done == 2
    assert res.wire_sparse_frames == res.results_assimilated > 0
    assert res.wire.bytes_sent == \
        res.wire.frames_sent * wire.sparse_frame_bytes(k)
    assert np.isfinite(res.final_accuracy)


def test_simulator_compressed_still_learns(task_data):
    """Error feedback keeps the compressed path within reach of dense."""
    from repro.core.baselines import CompressedVCASGD, VCASGD
    task, data = task_data
    dense = _sim(task, data, VCASGD(0.95), max_epochs=4)
    sparse = _sim(task, data, CompressedVCASGD(0.95, density=0.1),
                  max_epochs=4)
    assert sparse.final_accuracy > 0.15
    assert abs(sparse.final_accuracy - dense.final_accuracy) < 0.1


def test_compressed_scheme_bookkeeping_hooks():
    """The Coordinator's residual ledger feeds the wire header's
    error-feedback field, and dropping a lease releases the per-unit
    reconstruction base (no leak when a result is discarded in flight)."""
    from repro.core import flat as F
    from repro.core.baselines import CompressedVCASGD
    from repro.protocol import Coordinator
    scheme = CompressedVCASGD(0.9, density=0.1)
    fp = F.flatten({"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))})
    coord = Coordinator(scheme, fp)
    assert coord.residual_norm(0) == 0.0
    lease = coord.issue(cid=0, uid=7, round=0, base=fp)
    assert (0, 7) in coord.leases and lease.base is not None
    coord.submit(lease, fp.buf + 0.1)
    assert coord.residual_norm(0) > 0.0           # top-k left mass behind
    assert coord.residual_mass() == coord.residual_norm(0)
    # residual_norm rides the wire header of the submitted frame
    msg = wire.decode(coord.transport.recv(lease.msg_id))
    assert abs(msg.residual_norm - coord.residual_norm(0)) \
        < 1e-3 * max(1.0, coord.residual_norm(0))
    coord.drop(lease)                             # discarded in flight
    assert (0, 7) not in coord.leases
    assert lease.released and lease.base is None


def test_compressed_assimilate_rides_transport():
    """The pod-scale compressed path (runtime/vc_runtime.py) sends every
    island's payload through the transport as real bytes."""
    from repro.runtime.vc_runtime import compressed_assimilate
    key = jax.random.PRNGKey(5)
    server = {"w": jax.random.normal(key, (64, 32))}
    islands = {"w": jnp.stack([server["w"] + 0.1, server["w"] - 0.2])}
    surv = jnp.ones((2,), bool)
    t = LoopbackTransport()
    s1, _ = compressed_assimilate(server, islands, 0.8, surv,
                                  density=0.25, transport=t)
    s0, _ = compressed_assimilate(server, islands, 0.8, surv, density=0.25)
    np.testing.assert_array_equal(np.asarray(s0["w"]), np.asarray(s1["w"]))
    assert t.stats.frames_sent == 2                    # one per island
    k = max(1, int(64 * 32 * 0.25))
    assert t.stats.bytes_sent == 2 * wire.sparse_frame_bytes(k)
