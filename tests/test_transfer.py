"""Wire format v1 + transports (transfer/): encode/decode round-trips over
arbitrary payloads, hard rejection of torn/corrupt frames (a damaged
transfer must NEVER be assimilated), and the simulator's real byte
accounting — frame lengths are measured off encoded payloads, not assumed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import compression as C
from repro.transfer import (LoopbackTransport, TransportError, wire)
from repro.transfer.wire import WireError


def _delta(key, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), (n,)) * scale


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [1, 255, 8192, 16384 + 7])
def test_dense_roundtrip(dtype, n):
    buf = _delta(0, n).astype(dtype)
    frame = wire.encode(buf)
    assert len(frame) == wire.dense_frame_bytes(n, str(jnp.dtype(dtype)))
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_DENSE
    out = np.asarray(msg.payload)
    assert out.dtype == np.asarray(buf).dtype
    np.testing.assert_array_equal(np.asarray(buf, np.float32),
                                  out.astype(np.float32))


@pytest.mark.parametrize("density,n,logical",
                         [(0.01, 8192, 8192), (0.25, 16384, 13130),
                          (1.0, 8192, 100), (0.05, 3 * 8192, 20000)])
def test_sparse_roundtrip(density, n, logical):
    payload, _ = C.compress_flat(_delta(1, n), density=density,
                                 logical_n=logical)
    frame = wire.encode(payload)
    assert len(frame) == wire.sparse_frame_bytes(int(payload.values.size),
                                                 payload.block)
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_SPARSE
    q = msg.payload
    np.testing.assert_array_equal(np.asarray(payload.values),
                                  np.asarray(q.values))
    np.testing.assert_array_equal(np.asarray(payload.indices),
                                  np.asarray(q.indices))
    np.testing.assert_array_equal(np.asarray(payload.scales),
                                  np.asarray(q.scales))
    assert q.shape == (n,) and q.block == payload.block
    np.testing.assert_array_equal(np.asarray(C.decompress_flat(payload)),
                                  np.asarray(C.decompress_flat(q)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shard_frame_roundtrip(dtype):
    """Handout segments (the DOWNLOAD leg) round-trip exactly, carrying
    their shard index and shard count in the v2 header."""
    seg = _delta(7, 8192).astype(dtype)
    frame = wire.encode_shard(seg, shard=3, n_shards=5, round=9)
    assert len(frame) == wire.shard_frame_bytes(8192, str(jnp.dtype(dtype)))
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_SHARD
    assert msg.shard == 3 and msg.n_shards == 5 and msg.round == 9
    np.testing.assert_array_equal(np.asarray(seg, np.float32),
                                  np.asarray(msg.payload, np.float32))


def test_shard_frame_bad_index_rejected():
    seg = _delta(8, 8192)
    with pytest.raises(WireError):
        wire.encode_shard(seg, shard=5, n_shards=5)
    with pytest.raises(WireError):
        wire.encode_shard(seg, shard=-1, n_shards=5)
    # a corrupt shard index fails the header crc before the range check
    frame = wire.encode_shard(seg, shard=1, n_shards=3)
    bad = bytearray(frame)
    bad[16] ^= 0x1                                # k u64 (the shard index)
    with pytest.raises(WireError):
        wire.decode(bytes(bad))


def test_roundtrip_bookkeeping_fields():
    """round / residual_norm ride the header (error-feedback bookkeeping)."""
    payload, res = C.compress_flat(_delta(2, 8192), density=0.1)
    rn = float(jnp.linalg.norm(res))
    msg = wire.decode(wire.encode(payload, round=17, residual_norm=rn))
    assert msg.round == 17
    assert abs(msg.residual_norm - rn) < 1e-3 * max(1.0, rn)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_sparse_roundtrip(data):
    """Arbitrary (length, density, block) round-trips exactly."""
    n_blocks = data.draw(st.integers(min_value=1, max_value=6))
    n = n_blocks * 8192
    logical = data.draw(st.integers(min_value=1, max_value=n))
    density = data.draw(st.floats(min_value=0.001, max_value=1.0))
    block = data.draw(st.sampled_from([32, 256, 1024]))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16))
    payload, _ = C.compress_flat(_delta(seed, n), density=density,
                                 block=block, logical_n=logical)
    frame = wire.encode(payload)
    assert len(frame) == wire.sparse_frame_bytes(int(payload.values.size),
                                                 block)
    q = wire.decode(frame).payload
    np.testing.assert_array_equal(np.asarray(C.decompress_flat(payload)),
                                  np.asarray(C.decompress_flat(q)))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_dense_roundtrip(data):
    n = data.draw(st.integers(min_value=1, max_value=70000))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16))
    buf = _delta(seed, n)
    out = np.asarray(wire.decode(wire.encode(buf)).payload)
    np.testing.assert_array_equal(np.asarray(buf), out)


# ---------------------------------------------------------------------------
# torn / corrupt frames are rejected, never assimilated
# ---------------------------------------------------------------------------

def _frames():
    dense = wire.encode(_delta(3, 8192))
    sparse = wire.encode(C.compress_flat(_delta(4, 8192), density=0.1)[0])
    shard = wire.encode_shard(_delta(5, 8192), shard=1, n_shards=3)
    return [dense, sparse, shard]


@pytest.mark.parametrize("i", [0, 1, 2])
def test_truncated_frame_rejected(i):
    frame = _frames()[i]
    for cut in (len(frame) - 1, len(frame) // 2, wire.HEADER_BYTES,
                wire.HEADER_BYTES - 1, 3, 0):
        with pytest.raises(WireError):
            wire.decode(frame[:cut])


@pytest.mark.parametrize("i", [0, 1, 2])
def test_bitflip_rejected(i):
    """The crc covers header-sans-crc || body: a flip ANYWHERE in the
    frame — the n/k/density header fields included — is rejected."""
    frame = _frames()[i]
    header_positions = (6, 8, 16, 24, 28, 36, 40, 48, 56)
    body_positions = (wire.HEADER_BYTES, len(frame) - 1,
                      (wire.HEADER_BYTES + len(frame)) // 2)
    for pos in header_positions + body_positions:
        bad = bytearray(frame)
        bad[pos] ^= 0x41
        with pytest.raises(WireError):
            wire.decode(bytes(bad))


def test_bad_magic_and_future_version_rejected():
    frame = _frames()[0]
    bad = bytearray(frame)
    bad[0] ^= 0xFF
    with pytest.raises(WireError, match="magic"):
        wire.decode(bytes(bad))
    newer = bytearray(frame)
    newer[4] = 0xFF                               # version u16 lo byte
    with pytest.raises(WireError, match="version"):
        wire.decode(bytes(newer))


def test_oversized_frame_rejected():
    frame = _frames()[0]
    with pytest.raises(WireError):
        wire.decode(frame + b"\x00" * 8)


# ---------------------------------------------------------------------------
# wire v3: aggregate frames (the edge tier's merged upstream payload)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("weight", [0.0, 0.25, 1.0])
def test_aggregate_roundtrip(dtype, weight):
    buf = _delta(7, 4097).astype(dtype)
    frame = wire.encode(wire.AggregatePayload(np.asarray(buf), weight))
    assert len(frame) == wire.agg_frame_bytes(4097, str(jnp.dtype(dtype)))
    msg = wire.decode(frame)
    assert msg.kind == wire.KIND_AGG
    assert msg.weight == weight                       # exact in f32
    np.testing.assert_array_equal(
        np.asarray(buf, np.float32),
        np.asarray(msg.payload).astype(np.float32))


def test_v3_emission_rule_keeps_old_kinds_byte_stable():
    """A frame is emitted at the OLDEST version that can express it:
    dense/sparse/shard stay version-2 68-byte headers (every pinned byte
    count in results/ depends on that), only KIND_AGG pays for the v3
    ``weight`` field, and v2 frames decode with the neutral weight."""
    import struct
    for frame in _frames():
        assert struct.unpack_from("<4sH", frame)[1] == 2
        assert wire.decode(frame).weight == 1.0
    dense = wire.encode(_delta(6, 256))
    assert len(dense) == wire.HEADER_BYTES + 256 * 4
    agg = wire.encode(wire.AggregatePayload(np.zeros(256, np.float32), 0.5))
    assert struct.unpack_from("<4sH", agg)[1] == 3
    assert len(agg) == wire.HEADER_BYTES_V3 + 256 * 4
    assert wire.WIRE_VERSION == 3


def test_aggregate_crc_covers_every_header_byte():
    """The v3 crc covers the WHOLE header — the new trailing weight field
    included — plus the body: a flip anywhere is rejected."""
    frame = wire.encode(wire.AggregatePayload(np.ones(16, np.float32), 0.5))
    body_positions = (wire.HEADER_BYTES_V3, len(frame) - 1)
    for pos in tuple(range(wire.HEADER_BYTES_V3)) + body_positions:
        bad = bytearray(frame)
        bad[pos] ^= 0x41
        with pytest.raises(WireError):
            wire.decode(bytes(bad))


def test_v2_header_cannot_carry_aggregate_kind():
    """KIND_AGG needs the v3 weight field: a (checksum-valid) v2 header
    claiming kind 3 is rejected outright, never decoded with a guessed
    weight."""
    import struct
    import zlib
    body = np.zeros(8, np.float32).tobytes()
    hdr = wire._HDR.pack(wire.MAGIC, 2, wire.KIND_AGG, 0, 8, 8, 0, 1.0,
                         0, 0.0, len(body), 0, 0)
    frame = hdr + struct.pack(
        "<I", zlib.crc32(body, zlib.crc32(hdr))) + body
    with pytest.raises(WireError, match="requires wire v3"):
        wire.decode(frame)


def test_aggregate_weight_range_validated_both_sides():
    for w in (-0.1, 1.5, float("nan")):
        with pytest.raises(WireError):
            wire.encode_aggregate(np.zeros(4, np.float32), weight=w)
    # decode side: patch a legal frame's weight to 2.0, fix up the crc —
    # the structural checks pass, the semantic range check still rejects
    import struct
    import zlib
    frame = bytearray(
        wire.encode_aggregate(np.zeros(4, np.float32), weight=1.0))
    struct.pack_into("<f", frame, wire._HDR3.size - 4, 2.0)
    hdr, body = bytes(frame[:wire._HDR3.size]), bytes(
        frame[wire.HEADER_BYTES_V3:])
    struct.pack_into("<I", frame, wire._HDR3.size,
                     zlib.crc32(body, zlib.crc32(hdr)))
    with pytest.raises(WireError, match="weight"):
        wire.decode(bytes(frame))


# ---------------------------------------------------------------------------
# loopback transport
# ---------------------------------------------------------------------------

def test_loopback_transport_accounting():
    t = LoopbackTransport()
    frames = _frames()
    ids = [t.send(f) for f in frames]
    assert t.in_flight == len(frames)
    assert t.stats.frames_sent == len(frames)
    assert t.stats.bytes_sent == sum(len(f) for f in frames)
    # out-of-order delivery by id
    assert t.recv(ids[1]) == frames[1]
    assert t.recv(ids[0]) == frames[0]
    assert t.recv(ids[2]) == frames[2]
    assert t.stats.bytes_recv == t.stats.bytes_sent
    with pytest.raises(TransportError):
        t.recv(ids[0])                            # exactly-once delivery
    mid = t.send(frames[0])
    t.drop(mid)
    assert t.stats.frames_dropped == 1
    assert t.stats.bytes_dropped == len(frames[0])
    assert t.in_flight == 0


# ---------------------------------------------------------------------------
# the simulator puts REAL bytes on the wire (asserted, not simulated)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def task_data():
    from repro.core.tasks import MLPTask, make_classification_data
    return MLPTask(), make_classification_data(n_train=2000, n_val=400)


def _sim(task, data, scheme, **kw):
    from repro.core.simulator import SimConfig, run_simulation
    base = dict(n_param_servers=2, n_clients=3, tasks_per_client=2,
                n_shards=12, max_epochs=2, local_steps=2,
                subtask_compute_s=120.0, seed=1)
    base.update(kw)
    return run_simulation(task, data, scheme, SimConfig(**base))


def test_simulator_dense_byte_counts(task_data):
    """BOTH legs are sums of measured frame lengths: every handout is one
    full-model dense frame (single-shard bus) and every full-weight
    result payload is one dense frame of the flat bus size."""
    from repro.core import flat as F
    from repro.core.baselines import VCASGD
    task, data = task_data
    res = _sim(task, data, VCASGD(0.95))
    padded = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec.padded
    per_frame = wire.dense_frame_bytes(padded)
    assert res.results_assimilated > 0
    assert res.wire_dense_frames == res.results_assimilated
    assert res.wire_sparse_frames == 0
    assert res.wire.frames_sent == res.wire.frames_recv  # nothing torn/lost
    # download leg: one lease per handout, every dispatched unit got one
    assert res.handout_frames >= res.results_assimilated
    assert res.handout_bytes == res.handout_frames * per_frame
    uploads = res.wire.frames_sent - res.handout_frames
    assert res.wire.bytes_sent == res.handout_bytes + uploads * per_frame
    assert res.wire.bytes_recv == res.wire.bytes_sent


def test_simulator_download_leg_timed_from_real_frames(task_data):
    """param_bytes is ONLY the paper-calibration override: by default the
    download leg costs the measured handout frame bytes (~66KB for the
    MLP bus), and pinning it to the paper's 21.2MB must slow the clock
    without touching the measured byte totals."""
    from repro.core import flat as F
    from repro.core.baselines import VCASGD
    task, data = task_data
    padded = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec.padded
    real = _sim(task, data, VCASGD(0.95))
    paper = _sim(task, data, VCASGD(0.95), param_bytes=21.2e6)
    for res in (real, paper):
        assert res.handout_bytes == \
            res.handout_frames * wire.dense_frame_bytes(padded)
    assert paper.wall_time_s > real.wall_time_s   # 21.2MB >> one real frame


def test_simulator_compressed_byte_counts(task_data):
    """compress_flat payloads travel as sparse frames (exactly header + k
    int8 + ceil(k/block) f32 + k int32 each); handouts stay dense —
    the total is the sum of both legs' frame lengths."""
    from repro.core import flat as F
    from repro.core.baselines import CompressedVCASGD
    task, data = task_data
    density = 0.05
    res = _sim(task, data, CompressedVCASGD(0.95, density=density))
    spec = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec
    k = max(1, min(spec.n, int(spec.n * density)))
    per_frame = wire.sparse_frame_bytes(k)
    assert res.wire_sparse_frames == res.results_assimilated > 0
    uploads = res.wire.frames_sent - res.handout_frames
    assert res.handout_bytes == \
        res.handout_frames * wire.dense_frame_bytes(spec.padded)
    assert res.wire.bytes_sent == res.handout_bytes + uploads * per_frame
    # the sparse path actually compresses vs the dense frames
    assert per_frame < wire.dense_frame_bytes(spec.padded) / 4


def test_simulator_easgd_flat_pod_compressed(task_data):
    """EASGDFlatPod rides the same wire: with compress_density set, every
    replica payload is a sparse frame (byte counts asserted, handouts
    dense) and training still completes."""
    from repro.core import flat as F
    from repro.core.baselines import EASGDFlatPod
    task, data = task_data
    res = _sim(task, data,
               EASGDFlatPod(n_replicas=3, beta=0.05, compress_density=0.1))
    spec = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec
    k = max(1, min(spec.n, int(spec.n * 0.1)))
    assert res.epochs_done == 2
    assert res.wire_sparse_frames == res.results_assimilated > 0
    uploads = res.wire.frames_sent - res.handout_frames
    assert res.wire.bytes_sent == res.handout_bytes \
        + uploads * wire.sparse_frame_bytes(k)
    assert np.isfinite(res.final_accuracy)


def test_simulator_compressed_still_learns(task_data):
    """Error feedback keeps the compressed path within reach of dense."""
    from repro.core.baselines import CompressedVCASGD, VCASGD
    task, data = task_data
    dense = _sim(task, data, VCASGD(0.95), max_epochs=4)
    sparse = _sim(task, data, CompressedVCASGD(0.95, density=0.1),
                  max_epochs=4)
    assert sparse.final_accuracy > 0.15
    assert abs(sparse.final_accuracy - dense.final_accuracy) < 0.1


def test_compressed_scheme_bookkeeping_hooks():
    """The Coordinator's residual ledger feeds the wire header's
    error-feedback field, and dropping a lease releases the per-unit
    reconstruction base (no leak when a result is discarded in flight)."""
    from repro.core import flat as F
    from repro.core.baselines import CompressedVCASGD
    from repro.protocol import Coordinator
    scheme = CompressedVCASGD(0.9, density=0.1)
    fp = F.flatten({"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))})
    coord = Coordinator(scheme, fp)
    assert coord.residual_norm(0) == 0.0
    lease = coord.issue(cid=0, uid=7, round=0, base=fp)
    assert (0, 7) in coord.leases and lease.base is not None
    coord.submit(lease, fp.buf + 0.1)
    assert coord.residual_norm(0) > 0.0           # top-k left mass behind
    assert coord.residual_mass() == coord.residual_norm(0)
    # residual_norm rides the wire header of the submitted frame
    msg = wire.decode(coord.transport.recv(lease.msg_id))
    assert abs(msg.residual_norm - coord.residual_norm(0)) \
        < 1e-3 * max(1.0, coord.residual_norm(0))
    coord.drop(lease)                             # discarded in flight
    assert (0, 7) not in coord.leases
    assert lease.released and lease.base is None


def test_delta_handout_per_shard_frames():
    """Over a sharded bus the DOWNLOAD leg ships per-shard frames, and a
    client re-fetches only the segments that changed since its last
    handout (delta handouts) — zero frames when nothing changed, full
    model for a fresh client, byte totals equal to frame-length sums."""
    from repro.core import flat as F
    from repro.core.baselines import Downpour
    from repro.protocol import Coordinator
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (40000,))}
    fp = F.flatten_sharded(tree, 4)
    sl = fp.spec.shard_len
    per_shard = wire.shard_frame_bytes(sl)
    coord = Coordinator(Downpour(server_lr=1.0), fp)
    # fresh client: every segment ships
    l0 = coord.issue(cid=0, uid=0, round=0, base=fp)
    assert l0.handout_frames == 4
    assert l0.handout_bytes == 4 * per_shard
    np.testing.assert_array_equal(np.asarray(l0.base.buf), np.asarray(fp.buf))
    # a delta confined to shard 2 leaves the other segments untouched
    delta = np.zeros(fp.spec.padded, np.float32)
    lo, hi = fp.spec.shard_bounds(2)
    delta[lo + 5] = 1.0
    coord.submit(l0, fp.buf + jnp.asarray(delta))
    coord.assimilate(l0, coord.deliver(l0), server_version=0)
    l1 = coord.issue(cid=0, uid=1, round=1, base=coord.state.params)
    assert l1.handout_frames == 1                 # only shard 2 re-ships
    assert l1.handout_bytes == per_shard
    np.testing.assert_array_equal(np.asarray(l1.base.buf),
                                  np.asarray(coord.state.params.buf))
    # caught-up client, unchanged server: ZERO download bytes
    l2 = coord.issue(cid=0, uid=2, round=2, base=coord.state.params)
    assert l2.handout_frames == 0 and l2.handout_bytes == 0
    np.testing.assert_array_equal(np.asarray(l2.base.buf),
                                  np.asarray(coord.state.params.buf))
    # a different (fresh) client still needs everything
    l3 = coord.issue(cid=1, uid=3, round=0, base=coord.state.params)
    assert l3.handout_frames == 4
    # a preempted client loses its held copy: full re-download
    coord.drop_client(1)
    l4 = coord.issue(cid=1, uid=4, round=1, base=coord.state.params)
    assert l4.handout_frames == 4
    # transport totals == handout frames + the one upload frame
    stats = coord.transport.stats
    assert stats.bytes_sent == coord.handout_bytes + l0.frame_bytes
    assert coord.handout_bytes == (4 + 1 + 0 + 4 + 4) * per_shard


def test_compressed_assimilate_rides_transport():
    """The pod-scale compressed path (runtime/vc_runtime.py) sends every
    island's payload through the transport as real bytes."""
    from repro.runtime.vc_runtime import compressed_assimilate
    key = jax.random.PRNGKey(5)
    server = {"w": jax.random.normal(key, (64, 32))}
    islands = {"w": jnp.stack([server["w"] + 0.1, server["w"] - 0.2])}
    surv = jnp.ones((2,), bool)
    t = LoopbackTransport()
    s1, _ = compressed_assimilate(server, islands, 0.8, surv,
                                  density=0.25, transport=t)
    s0, _ = compressed_assimilate(server, islands, 0.8, surv, density=0.25)
    np.testing.assert_array_equal(np.asarray(s0["w"]), np.asarray(s1["w"]))
    assert t.stats.frames_sent == 2                    # one per island
    k = max(1, int(64 * 32 * 0.25))
    assert t.stats.bytes_sent == 2 * wire.sparse_frame_bytes(k)
