"""Multi-device sharding correctness — runs in subprocesses so the main
test process keeps a single CPU device (per the dry-run rules)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(py: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Same loss on a (2,2) mesh as on 1 device (GSPMD correctness)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.registry import build_model
        from repro.optim import Adam
        from repro.runtime.sharding import MeshPlan
        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.train import make_train_step, shardings_for_train
        from repro.data import make_batch_for

        cfg = get_reduced("internlm2-1.8b").replace(compute_dtype="float32")
        model = build_model(cfg)
        opt = Adam(lr=1e-3)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = make_batch_for(cfg, 4, 64)

        # single device
        from repro.models.plan import NULL_PLAN
        loss1 = model.loss(params, batch)[0]

        mesh = compat_make_mesh((2, 2), ("data", "model"))
        plan = MeshPlan.build(cfg, mesh)
        step = make_train_step(model, plan, opt)
        ins, outs = shardings_for_train(model, plan, opt, batch)
        with mesh:
            p2, o2, m = jax.jit(step, in_shardings=ins,
                                out_shardings=outs)(params, opt_state, batch)
        loss2 = m["loss"]
        print("LOSS", float(loss1), float(loss2))
        assert abs(float(loss1) - float(loss2)) < 2e-3, (loss1, loss2)
    """)
    assert "LOSS" in out


@pytest.mark.slow
def test_cp_arch_sharded_matches_single_device():
    """qwen-family (CP attention) on a (2,2) mesh == 1-device forward."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.registry import build_model
        from repro.runtime.sharding import MeshPlan
        from repro.launch.mesh import compat_make_mesh
        from repro.data import make_batch_for

        cfg = get_reduced("qwen2.5-14b").replace(compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch_for(cfg, 4, 64)
        lg1 = model.forward(params, batch)

        mesh = compat_make_mesh((2, 2), ("data", "model"))
        # reduced config is tiny (d_model 80), so the planner would choose
        # "local"; force the CP path the full config takes (40 heads % 16)
        plan = MeshPlan.build(cfg, mesh, attn_mode="cp")
        assert plan.attn_mode == "cp", plan.attn_mode
        with mesh:
            lg2 = jax.jit(lambda p, b: model.forward(p, b, plan=plan))(params, batch)
        err = float(jnp.max(jnp.abs(lg1 - lg2)))
        print("ERR", err)
        assert err < 3e-3, err
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_decode_cache_seq_sharded_matches():
    """Two-tier chunk-sharded decode on a mesh == single-device decode."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models.registry import build_model
        from repro.runtime.sharding import MeshPlan
        from repro.launch.mesh import compat_make_mesh
        from repro.data import make_batch_for

        cfg = get_reduced("mixtral-8x7b").replace(compute_dtype="float32")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch_for(cfg, 4, 32)
        lg_p1, c1 = model.prefill(params, batch)
        tok = jnp.argmax(lg_p1[:, :cfg.vocab_size], -1).astype(jnp.int32)
        lg_d1, _ = model.decode_step(params, c1, tok, jnp.asarray(32, jnp.int32))

        mesh = compat_make_mesh((2, 2), ("data", "model"))
        plan = MeshPlan.build(cfg, mesh, decode_batch=4)
        with mesh:
            lg_p2, c2 = jax.jit(lambda p, b: model.prefill(p, b, plan=plan))(params, batch)
            lg_d2, _ = jax.jit(lambda p, c, t, i: model.decode_step(
                p, c, t, i, plan=plan))(params, c2, tok, jnp.asarray(32, jnp.int32))
        e1 = float(jnp.max(jnp.abs(lg_p1 - lg_p2)))
        e2 = float(jnp.max(jnp.abs(lg_d1 - lg_d2)))
        print("ERRS", e1, e2)
        assert e1 < 3e-3 and e2 < 3e-3, (e1, e2)
    """)
    assert "ERRS" in out


@pytest.mark.slow
def test_vc_round_multi_pod_elasticity():
    """vc_round on a real (2,1,2) pod mesh: loss decreases AND a masked
    island does not corrupt the server (elastic fault tolerance)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.registry import build_model
        from repro.optim import Adam
        from repro.runtime.sharding import MeshPlan
        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.vc_runtime import island_shardings, make_vc_round

        cfg = get_reduced("internlm2-1.8b")
        model = build_model(cfg)
        mesh = compat_make_mesh((2, 1, 2), ("pod", "data", "model"))
        plan = MeshPlan.build(cfg, mesh)
        opt = Adam(lr=1e-3)
        vc_round = make_vc_round(model, plan, 2, 2, opt)
        key = jax.random.PRNGKey(0)
        with mesh:
            server = model.init(key)
            islands = jax.tree.map(lambda s: jnp.stack([s, s]), server)
            opts = jax.vmap(opt.init)(islands)
            toks = jax.random.randint(key, (2, 2, 4, 32), 0, cfg.vocab_size)
            losses = []
            for rnd in range(3):
                surv = jnp.asarray([rnd != 1, True])
                server, islands, opts, m = vc_round(
                    server, islands, opts, {"tokens": toks},
                    jnp.asarray(0.6, jnp.float32), surv)
                losses.append(float(m["loss"]))
            ok = all(np.isfinite(np.asarray(l, np.float32)).all()
                     for l in jax.tree.leaves(server))
        print("LOSSES", losses, ok)
        assert losses[-1] < losses[0] and ok
    """)
    assert "LOSSES" in out
