"""Property tests of the paper's update rule (Eq. 1 / Eq. 2) — hypothesis
drives alphas, client counts and orderings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import vc_asgd as V

SHAPE = (13, 7)


def tree_of(key, n=2):
    ks = jax.random.split(key, n)
    return {"a": jax.random.normal(ks[0], SHAPE),
            "b": {"c": jax.random.normal(ks[1], (5,))}}


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(0.0, 1.0), n=st.integers(1, 8), seed=st.integers(0, 99))
def test_eq2_equals_folded_eq1(alpha, n, seed):
    """assimilate_many (Eq. 2 closed form) == folding Eq. 1 n times in
    arrival order."""
    key = jax.random.PRNGKey(seed)
    server = tree_of(key)
    clients = [tree_of(jax.random.fold_in(key, i + 1)) for i in range(n)]
    folded = server
    for c in clients:
        folded = V.vc_asgd_update(folded, c, alpha)
    closed = V.assimilate_many(server, clients, alpha)
    for l1, l2 in zip(jax.tree.leaves(folded), jax.tree.leaves(closed)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(alpha=st.floats(0.0, 1.0), n=st.integers(0, 20))
def test_weights_are_convex(alpha, n):
    assert V.is_convex_combination(n, alpha, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(0.01, 0.99), n=st.integers(2, 6), seed=st.integers(0, 50))
def test_order_sensitivity_matches_eq2(alpha, n, seed):
    """Eq. 2 weights are (1-a)*a^{n-1-j}: later arrivals weigh MORE."""
    w = V.assimilation_weights(n, alpha)
    assert all(w[j + 1] >= w[j] - 1e-12 for j in range(1, n))


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.1, 0.99), seed=st.integers(0, 50),
       drop=st.lists(st.booleans(), min_size=4, max_size=4))
def test_fault_tolerance_dropping_any_subset(alpha, seed, drop):
    """Dropping any subset of client results leaves a valid server state
    bounded by the max norm of the participants (convexity) — the paper's
    fault-tolerance claim in algebraic form."""
    key = jax.random.PRNGKey(seed)
    server = tree_of(key)
    clients = [tree_of(jax.random.fold_in(key, i + 1)) for i in range(4)]
    survivors = [c for c, d in zip(clients, drop) if not d]
    out = V.assimilate_many(server, survivors, alpha)
    bound = max(float(V.tree_max_abs(t)) for t in [server] + clients)
    assert float(V.tree_max_abs(out)) <= bound + 1e-5
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(out))


def test_delta_form_identity():
    key = jax.random.PRNGKey(0)
    server = tree_of(key)
    client = tree_of(jax.random.fold_in(key, 1))
    delta = jax.tree.map(lambda c, s: c - s, client, server)
    direct = V.vc_asgd_update(server, client, 0.9)
    via_delta = V.vc_asgd_update_delta(server, delta, 0.9)
    for l1, l2 in zip(jax.tree.leaves(direct), jax.tree.leaves(via_delta)):
        # a*s+(1-a)*c vs s+(1-a)*(c-s): equal in exact arithmetic, one ulp
        # apart in f32 near zero — hence the small atol
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-6, atol=1e-7)


def test_var_alpha_schedule():
    """The paper's alpha_e = e/(e+1): 0.5 at e=1, ~0.976 at e=40, rising."""
    f = V.var_alpha()
    assert f(1) == 0.5
    assert abs(f(40) - 40 / 41) < 1e-12
    vals = [f(e) for e in range(1, 41)]
    assert all(b > a for a, b in zip(vals, vals[1:]))


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(0.5, 0.999), stale=st.integers(0, 10),
       gamma=st.floats(0.1, 0.95))
def test_staleness_alpha_bounds(alpha, stale, gamma):
    a_eff = V.staleness_alpha(alpha, stale, gamma)
    assert alpha - 1e-12 <= a_eff <= 1.0
    # more staleness -> smaller client weight
    assert V.staleness_alpha(alpha, stale + 1, gamma) >= a_eff - 1e-12


def test_kernel_backed_update_matches():
    key = jax.random.PRNGKey(3)
    server = tree_of(key)
    client = tree_of(jax.random.fold_in(key, 9))
    a = V.vc_asgd_update(server, client, 0.93, use_kernel=False)
    b = V.vc_asgd_update(server, client, 0.93, use_kernel=True)
    for l1, l2 in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6,
                                   atol=1e-6)


def test_dc_gradient_shape_and_zero_lam():
    key = jax.random.PRNGKey(5)
    g = tree_of(key)
    wn = tree_of(jax.random.fold_in(key, 1))
    wb = tree_of(jax.random.fold_in(key, 2))
    out = V.dc_asgd_gradient(g, wn, wb, lam=0.0)
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))
