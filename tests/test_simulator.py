"""End-to-end behaviour of the VC system simulator: convergence, fault
tolerance under preemption, consistency trade-offs, baselines, the
boundary-only conversion budget, and the preempt-restore resume path."""
import numpy as np
import pytest

from repro.core import flat as F
from repro.core.baselines import (DCASGD, Downpour, EASGDFlatPod,
                                  EASGDPersistent, SyncBSP, VCASGD)
from repro.core.consistency import StoreStats
from repro.core.preemption import KillSchedule
from repro.core.simulator import (EpochPoint, SimConfig, SimResult,
                                  run_preemptible_training, run_simulation,
                                  run_single_instance)
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha


@pytest.fixture(scope="module")
def task_data():
    return MLPTask(), make_classification_data(n_train=3000, n_val=600)


def _cfg(**kw):
    base = dict(n_param_servers=2, n_clients=3, tasks_per_client=2,
                n_shards=12, max_epochs=5, local_steps=2,
                subtask_compute_s=120.0, seed=1)
    base.update(kw)
    return SimConfig(**base)


def test_vc_asgd_converges(task_data):
    task, data = task_data
    res = run_simulation(task, data, VCASGD(0.95), _cfg())
    assert res.epochs_done == 5
    accs = [p.acc_mean for p in res.points]
    assert accs[-1] > accs[0] + 0.1          # real learning happened
    assert accs[-1] > 0.3


def test_preemption_still_completes(task_data):
    """The paper's core claim: training completes on preemptible clients."""
    task, data = task_data
    res = run_simulation(task, data, VCASGD(0.95),
                         _cfg(preemptible=True, mean_lifetime_s=900.0,
                              n_clients=5))
    assert res.epochs_done == 5
    assert res.preemptions > 0               # failures actually happened
    assert res.final_accuracy > 0.3


def test_eventual_vs_strong(task_data):
    """Eventual loses some updates but keeps comparable accuracy; strong
    loses none but queues (the §IV-D trade-off)."""
    task, data = task_data
    re_ = run_simulation(task, data, VCASGD(0.95), _cfg(consistency="eventual",
                                                        tasks_per_client=4))
    rs = run_simulation(task, data, VCASGD(0.95), _cfg(consistency="strong",
                                                       tasks_per_client=4))
    assert rs.store_stats.lost_updates == 0
    assert re_.store_stats.lost_updates >= 0
    assert rs.store_stats.queue_wait_s >= 0
    assert abs(re_.final_accuracy - rs.final_accuracy) < 0.15


def test_var_alpha_runs(task_data):
    task, data = task_data
    res = run_simulation(task, data, VCASGD(var_alpha()), _cfg())
    assert res.epochs_done == 5
    assert res.final_accuracy > 0.3


@pytest.mark.parametrize("scheme_fn", [
    lambda: Downpour(server_lr=0.5),
    lambda: DCASGD(server_lr=0.5, lam=0.05),
    lambda: EASGDPersistent(beta=0.05),
    lambda: EASGDFlatPod(n_replicas=3, beta=0.05),
])
def test_baselines_run(task_data, scheme_fn):
    task, data = task_data
    res = run_simulation(task, data, scheme_fn(), _cfg(max_epochs=3))
    assert res.epochs_done == 3
    assert np.isfinite(res.final_accuracy)


def test_dcasgd_backups_are_wired(task_data):
    """The coordinator records the dispatch-time params on the lease and
    DC-ASGD snapshots them per client at on_issue, so the compensation
    backup is real — without it (W_now - W_backup) is identically zero
    and DC-ASGD degenerates to Downpour."""
    task, data = task_data
    scheme = DCASGD(server_lr=0.5, lam=0.05)
    res = run_simulation(task, data, scheme, _cfg(max_epochs=2))
    assert res.results_assimilated > 0
    assert len(res.scheme_state.backups) > 0


def test_sync_bsp_runs(task_data):
    task, data = task_data
    cfg = _cfg(max_epochs=3)
    res = run_simulation(task, data, SyncBSP(cfg.n_shards), cfg)
    assert res.epochs_done == 3


def test_simulator_expires_coordinator_leases(task_data):
    """Coordinator expiry is wired next to the scheduler's timeout sweep:
    a timed-out unit's lease is consumed (base released, in-flight frame
    dropped) the moment the deadline passes — it never lingers until the
    stale arrival happens to fire, and ``leases_expired`` counts it."""
    task, data = task_data
    # timeout shorter than the slow clients' compute: their units expire
    # and get reassigned; the fast clients still finish the job
    res = run_simulation(task, data, VCASGD(0.95),
                         _cfg(max_epochs=2, timeout_s=120.0))
    assert res.reassignments > 0
    assert res.leases_expired > 0
    assert res.epochs_done == 2


def test_acc_at_time_latest_before_t():
    """acc_at_time pins the latest-before-t contract: the value an
    observer reading the validation curve at time t sees — NOT a running
    best (accuracy can regress between epochs)."""
    def pt(epoch, t, acc):
        return EpochPoint(epoch=epoch, t_complete=t, acc_mean=acc,
                          acc_min=acc, acc_max=acc, acc_std=0.0)
    res = SimResult(points=[pt(1, 10.0, 0.5), pt(2, 20.0, 0.3),
                            pt(3, 30.0, 0.7)],
                    wall_time_s=30.0, epochs_done=3, final_accuracy=0.7,
                    store_stats=StoreStats(), reassignments=0,
                    preemptions=0, results_assimilated=3)
    assert res.acc_at_time(5.0) == 0.0            # before the first point
    assert res.acc_at_time(10.0) == 0.5           # inclusive at t_complete
    assert res.acc_at_time(25.0) == 0.3           # LATEST, not best-so-far
    assert res.acc_at_time(99.0) == 0.7


def test_single_instance_baseline(task_data):
    task, data = task_data
    res = run_single_instance(task, data, max_epochs=5, steps_per_epoch=60)
    accs = [p.acc_mean for p in res.points]
    assert accs[-1] > accs[0]


def test_determinism(task_data):
    task, data = task_data
    r1 = run_simulation(task, data, VCASGD(0.9), _cfg(max_epochs=2))
    r2 = run_simulation(task, data, VCASGD(0.9), _cfg(max_epochs=2))
    assert r1.wall_time_s == r2.wall_time_s
    assert r1.final_accuracy == r2.final_accuracy


def test_conversions_at_boundary_only(task_data):
    """Per assimilated result the simulator crosses the tree<->bus boundary
    exactly 3 times: unflatten for client training, flatten of the trained
    tree, unflatten for evaluation — schemes themselves do ZERO conversions
    (the PR-2 regression against per-round re-flattening)."""
    task, data = task_data
    F.reset_conversion_counts()
    res = run_simulation(task, data, VCASGD(0.95), _cfg(max_epochs=2))
    c = F.conversion_counts()
    r = res.results_assimilated
    assert r > 0
    assert c["flatten"] == r + 1           # + initial params0 flatten
    assert c["unflatten"] == 2 * r + 1     # + final evaluation


def test_preempt_restore_matches_uninterrupted(task_data, tmp_path):
    """Kill-and-restore fault injection: params+opt-state restored from the
    one-pass record reproduce the uninterrupted loss trajectory exactly at
    matching steps (the PR-2 acceptance criterion)."""
    task, data = task_data
    res_clean = run_preemptible_training(
        task, data, steps=24, batch=32, ckpt_every=5,
        ckpt_dir=tmp_path / "clean", seed=7)
    res_kill = run_preemptible_training(
        task, data, steps=24, batch=32, ckpt_every=5,
        ckpt_dir=tmp_path / "kill", seed=7,
        kill_schedule=KillSchedule.at(8, 19))
    assert res_kill.restores == 2
    assert res_kill.recomputed_steps > 0   # work was actually lost and redone
    for s in range(24):
        assert res_clean.losses[s] == res_kill.losses[s], s
    np.testing.assert_array_equal(np.asarray(res_clean.final_params.buf),
                                  np.asarray(res_kill.final_params.buf))


def test_kill_schedule_exponential_deterministic():
    a = KillSchedule.exponential(30.0, 200, seed=4)
    b = KillSchedule.exponential(30.0, 200, seed=4)
    assert a.kill_steps == b.kill_steps
    assert all(0 <= s < 200 for s in a.kill_steps)


def test_pick_server_earliest_free():
    """The PS pick is earliest-free, not blind round-robin: a result never
    queues behind a busy server while another sits idle (§IV-B), and ties
    break deterministically to the lowest index."""
    from repro.core.simulator import _pick_server
    assert _pick_server([10.0, 0.0, 5.0]) == 1     # the idle one
    assert _pick_server([7.0, 3.0, 5.0]) == 1      # earliest to free up
    assert _pick_server([4.0, 4.0, 9.0]) == 0      # tie -> lowest index
    assert _pick_server([0.0]) == 0
    # round-robin would hand the 2nd result to PS1 (busy until 100) while
    # PS2 idles; earliest-free never does
    busy = [100.0, 0.0, 0.0]
    assert _pick_server(busy) in (1, 2) and _pick_server(busy) == 1


def test_more_servers_reduce_backlog(task_data):
    """Fig. 3's shape: with Tn high, P1 backlogs; P3 strictly faster."""
    task, data = task_data
    r1 = run_simulation(task, data, VCASGD(0.95),
                        _cfg(n_param_servers=1, tasks_per_client=8,
                             max_epochs=3, server_proc_s=6.0))
    r3 = run_simulation(task, data, VCASGD(0.95),
                        _cfg(n_param_servers=3, tasks_per_client=8,
                             max_epochs=3, server_proc_s=6.0))
    assert r3.points[-1].t_complete < r1.points[-1].t_complete
