"""vclint: the static tier.

Two jobs: (1) the RATCHET — lint the real ``src/repro`` tree against the
committed baseline so a new violation fails tier 1 before any dynamic
test runs; (2) fixture coverage for every rule — tiny synthetic modules
that must trip / must pass each rule, including the three acceptance
cases (lease issued without a terminal transition on an exception path,
wire header reinterpretation without a version bump, ``jax.*`` inside a
simulator event handler), plus the framework itself (suppressions,
unused-suppression detection, JSON reporter schema, baseline ratchet
semantics).
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import baseline as B
from repro.analysis.framework import all_rules, lint_paths
from repro.analysis.reporters import JSON_SCHEMA_VERSION, json_report

pytestmark = pytest.mark.lint

REPO_ROOT = Path(__file__).resolve().parents[1]
BASELINE = REPO_ROOT / "results" / "BASELINE_vclint.json"

# real wire constants, reused by the wire fixtures
WIRE_OK = """
import struct
MAGIC = b"VCWF"
WIRE_VERSION = 3
KIND_DENSE = 0
KIND_SPARSE = 1
KIND_SHARD = 2
KIND_AGG = 3
_EMIT_VERSION = 2
_HDR = struct.Struct("<4sHBBQQIfIfQQQ")
_HDR3 = struct.Struct("<4sHBBQQIfIfQQQf")
_CRC = struct.Struct("<I")
_PEEK = struct.Struct("<4sH")
HEADER_BYTES = _HDR.size + _CRC.size
HEADER_BYTES_V3 = _HDR3.size + _CRC.size
"""


def lint_files(tmp_path, files):
    """Write {relpath: code} under tmp_path and lint the tree rooted
    there (suffix-based path matching lets fixtures impersonate repo
    modules like core/simulator.py)."""
    for rel, code in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(code))
    return lint_paths([tmp_path], repo_root=tmp_path)


def rules_hit(report):
    return set(report.by_rule)


# ---------------------------------------------------------------------------
# the ratchet over the real tree
# ---------------------------------------------------------------------------

def test_src_repro_clean_against_baseline():
    if not BASELINE.is_file():
        pytest.skip("no results/BASELINE_vclint.json in this checkout")
    report = lint_paths([REPO_ROOT / "src" / "repro"], repo_root=REPO_ROOT)
    code, msgs = B.check_ratchet(report, B.load_baseline(BASELINE))
    assert code == B.EXIT_CLEAN, "\n".join(
        [v.format() for v in report.violations] + msgs)


def test_registry_has_the_eight_rules():
    names = set(all_rules())
    assert {"lease-lifecycle", "wire-schema", "jit-purity",
            "kernel-triangle", "import-direction", "hotpath-jax",
            "rng-stream", "scheme-purity"} <= names


# ---------------------------------------------------------------------------
# acceptance case (a): lease without terminal transition on an
# exception path
# ---------------------------------------------------------------------------

def test_lease_registered_then_risky_fires(tmp_path):
    report = lint_files(tmp_path, {"protocol/coordinator.py": """
        class Coordinator:
            def issue(self, key):
                lease = Lease(key)
                self.leases[key] = lease
                self.scheme.on_issue(lease)
                return lease
    """})
    assert report.by_rule.get("lease-lifecycle") == 1
    assert "terminal transition" in report.violations[0].message


def test_lease_protected_by_except_passes(tmp_path):
    report = lint_files(tmp_path, {"protocol/coordinator.py": """
        class Coordinator:
            def issue(self, key):
                lease = Lease(key)
                self.leases[key] = lease
                try:
                    self.scheme.on_issue(lease)
                except BaseException:
                    self.drop(lease)
                    raise
                return lease
    """})
    assert "lease-lifecycle" not in rules_hit(report)


def test_attr_registered_lease_risky_fires(tmp_path):
    report = lint_files(tmp_path, {"protocol/aggregator.py": """
        class Agg:
            def open_window(self):
                self.up_lease = self.hub.issue(cid=1)
                self.state = self.scheme.init_state(self.up_lease.base)
                return self.up_lease
    """})
    assert report.by_rule.get("lease-lifecycle") == 1


def test_dead_lease_fires_and_returned_lease_passes(tmp_path):
    report = lint_files(tmp_path, {"protocol/leak.py": """
        def forgot():
            lease = Lease(1)
            count = 2

        def handed_back():
            lease = Lease(1)
            return lease
    """})
    assert report.by_rule.get("lease-lifecycle") == 1
    assert "never registered" in report.violations[0].message


def test_plain_issue_consumer_is_exempt(tmp_path):
    report = lint_files(tmp_path, {"core/driver.py": """
        def dispatch(coord, unit):
            lease = coord.issue(cid=unit.cid, uid=unit.uid)
            push(Event(lease=lease))
    """})
    assert "lease-lifecycle" not in rules_hit(report)


# ---------------------------------------------------------------------------
# acceptance case (b): wire reinterpretation without a version bump
# ---------------------------------------------------------------------------

def test_wire_matches_pin_passes(tmp_path):
    report = lint_files(tmp_path, {"transfer/wire.py": WIRE_OK})
    assert "wire-schema" not in rules_hit(report)


def test_wire_header_reinterpreted_without_bump_fires(tmp_path):
    bad = WIRE_OK.replace('_HDR = struct.Struct("<4sHBBQQIfIfQQQ")',
                          '_HDR = struct.Struct("<4sHBBQQIfIfQQI")')
    report = lint_files(tmp_path, {"transfer/wire.py": bad})
    msgs = [v.message for v in report.violations
            if v.rule == "wire-schema"]
    assert any("WIRE_VERSION bump" in m for m in msgs)


def test_wire_kind_renumbered_fires(tmp_path):
    bad = WIRE_OK.replace("KIND_AGG = 3", "KIND_AGG = 2")
    report = lint_files(tmp_path, {"transfer/wire.py": bad})
    msgs = [v.message for v in report.violations
            if v.rule == "wire-schema"]
    assert any("KIND_AGG" in m for m in msgs)
    assert any("reuses wire tag" in m for m in msgs)


def test_wire_version_bump_requires_repin(tmp_path):
    bumped = WIRE_OK.replace("WIRE_VERSION = 3", "WIRE_VERSION = 4")
    report = lint_files(tmp_path, {"transfer/wire.py": bumped})
    msgs = [v.message for v in report.violations
            if v.rule == "wire-schema"]
    assert len(msgs) == 1 and "re-pin" in msgs[0]


def test_wire_v3_header_must_extend_v2(tmp_path):
    bad = WIRE_OK.replace('_HDR3 = struct.Struct("<4sHBBQQIfIfQQQf")',
                          '_HDR3 = struct.Struct("<4sHBBfQQIfIfQQQ")')
    report = lint_files(tmp_path, {"transfer/wire.py": bad})
    msgs = [v.message for v in report.violations
            if v.rule == "wire-schema"]
    assert any("append-only" in m for m in msgs)


# ---------------------------------------------------------------------------
# acceptance case (c): jax.* in a simulator event handler
# ---------------------------------------------------------------------------

SIM_HOT = """
import numpy as np
import jax.numpy as jnp

def run_simulation(cfg):
    rng = np.random.default_rng(cfg.seed)

    def dispatch(ev):
        return jnp.asarray(ev.payload)

    while pending:
        dispatch(pop())
"""


def test_jax_in_event_handler_fires(tmp_path):
    report = lint_files(tmp_path, {"core/simulator.py": SIM_HOT})
    assert report.by_rule.get("hotpath-jax", 0) >= 1
    assert any("event loop" in v.message for v in report.violations)


def test_jax_before_loop_passes(tmp_path):
    report = lint_files(tmp_path, {"core/simulator.py": """
        import jax
        import numpy as np

        def run_simulation(cfg):
            key = jax.random.PRNGKey(cfg.seed)
            step = make_step(key)
            while pending:
                step(pop())
    """})
    assert "hotpath-jax" not in rules_hit(report)


def test_jnp_in_scenario_flat_path_fires(tmp_path):
    report = lint_files(tmp_path, {"scenarios/probe.py": """
        import jax.numpy as jnp

        class Probe:
            def client_train_flat(self, buf):
                return jnp.square(buf)
    """})
    assert report.by_rule.get("hotpath-jax") == 1


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------

def test_item_inside_jit_fires(tmp_path):
    report = lint_files(tmp_path, {"kernels/bad.py": """
        import jax

        @jax.jit
        def f(x):
            return x.sum().item()
    """})
    assert report.by_rule.get("jit-purity") == 1


def test_global_capture_in_pallas_kernel_fires(tmp_path):
    report = lint_files(tmp_path, {"kernels/bad2.py": """
        import random
        _hits = 0

        def _kern(x_ref, o_ref):
            global _hits
            _hits += 1
            o_ref[...] = x_ref[...] * random.random()

        def entry(x):
            return pl.pallas_call(_kern, out_shape=x)(x)
    """})
    msgs = [v.message for v in report.violations
            if v.rule == "jit-purity"]
    assert any("global" in m for m in msgs)
    assert any("random" in m for m in msgs)


def test_host_helpers_outside_trace_pass(tmp_path):
    report = lint_files(tmp_path, {"kernels/good.py": """
        import numpy as np

        def launch_count():
            return np.asarray(_counts).sum().item()
    """})
    assert "jit-purity" not in rules_hit(report)


# ---------------------------------------------------------------------------
# import-direction
# ---------------------------------------------------------------------------

def test_protocol_importing_simulator_fires(tmp_path):
    report = lint_files(tmp_path, {"protocol/bad.py": """
        from repro.core import simulator
    """})
    assert report.by_rule.get("import-direction") == 1


def test_transfer_importing_protocol_fires(tmp_path):
    report = lint_files(tmp_path, {"transfer/bad.py": """
        from repro.protocol.types import Lease
    """})
    assert report.by_rule.get("import-direction", 0) >= 1


def test_allowed_imports_pass(tmp_path):
    report = lint_files(tmp_path, {
        "protocol/ok.py": "from repro.core import flat\n",
        "transfer/ok.py": "import numpy as np\n",
    })
    assert "import-direction" not in rules_hit(report)


# ---------------------------------------------------------------------------
# rng-stream
# ---------------------------------------------------------------------------

def test_module_level_np_random_fires(tmp_path):
    report = lint_files(tmp_path, {"scenarios/bad.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n)
    """})
    assert report.by_rule.get("rng-stream") == 1


def test_named_generator_passes(tmp_path):
    report = lint_files(tmp_path, {"scenarios/good.py": """
        import numpy as np

        def jitter(rng, n):
            return np.random.default_rng(7).random(n) + rng.random(n)
    """})
    assert "rng-stream" not in rules_hit(report)


# ---------------------------------------------------------------------------
# scheme-purity
# ---------------------------------------------------------------------------

def test_scheme_self_mutation_fires(tmp_path):
    report = lint_files(tmp_path, {"core/bad_scheme.py": """
        class Sticky(ServerScheme):
            def assimilate(self, state, payload, meta):
                self.last_cid = meta.cid
                return state
    """})
    assert report.by_rule.get("scheme-purity") == 1


def test_scheme_io_and_subclass_chain_fires(tmp_path):
    report = lint_files(tmp_path, {"core/bad_scheme2.py": """
        class Base(ServerScheme):
            pass

        class Leaf(Base):
            def on_epoch(self, state, epoch):
                open("/tmp/x", "w")
    """})
    assert report.by_rule.get("scheme-purity") == 1


def test_state_mutation_in_scheme_passes(tmp_path):
    report = lint_files(tmp_path, {"core/good_scheme.py": """
        class VCASGD(ServerScheme):
            def __init__(self, alpha):
                self.alpha = alpha

            def assimilate(self, state, payload, meta):
                state.params = lerp(state.params, payload, self.alpha)
                state.version += 1
                return state
    """})
    assert "scheme-purity" not in rules_hit(report)


# ---------------------------------------------------------------------------
# kernel-triangle
# ---------------------------------------------------------------------------

def test_unmapped_pallas_entry_fires(tmp_path):
    report = lint_files(tmp_path, {"kernels/newkern.py": """
        def mystery(x):
            return pl.pallas_call(_kern, out_shape=x)(x)
    """})
    msgs = [v.message for v in report.violations
            if v.rule == "kernel-triangle"]
    assert any("no TRIANGLE entry" in m for m in msgs)


def test_mapped_kernel_missing_ref_fires(tmp_path):
    report = lint_files(tmp_path, {"kernels/flash_attention.py": """
        def flash_attention(q, k, v):
            return pl.pallas_call(_kern, out_shape=q)(q, k, v)
    """})
    msgs = [v.message for v in report.violations
            if v.rule == "kernel-triangle"]
    assert any("ref.py is missing" in m for m in msgs)


def test_real_kernels_triangle_closes():
    report = lint_paths([REPO_ROOT / "src" / "repro" / "kernels"],
                        repo_root=REPO_ROOT)
    assert "kernel-triangle" not in rules_hit(report)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_trailing_suppression_silences(tmp_path):
    report = lint_files(tmp_path, {"scenarios/sup.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n)  # vclint: disable=rng-stream
    """})
    assert report.total == 0


def test_standalone_suppression_covers_next_line(tmp_path):
    report = lint_files(tmp_path, {"scenarios/sup2.py": """
        import numpy as np

        def jitter(n):
            # vclint: disable=rng-stream
            return np.random.rand(n)
    """})
    assert report.total == 0


def test_unused_suppression_is_reported(tmp_path):
    report = lint_files(tmp_path, {"scenarios/sup3.py": """
        def clean(n):
            return n + 1  # vclint: disable=rng-stream
    """})
    assert report.by_rule.get("unused-suppression") == 1


def test_docstring_disable_example_is_not_a_suppression(tmp_path):
    report = lint_files(tmp_path, {"scenarios/doc.py": '''
        """Docs quoting `# vclint: disable=rng-stream` are not waivers."""
    '''})
    assert report.total == 0


# ---------------------------------------------------------------------------
# reporters + baseline ratchet
# ---------------------------------------------------------------------------

def test_json_reporter_schema(tmp_path):
    report = lint_files(tmp_path, {"scenarios/bad.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n)
    """})
    doc = json_report(report)
    assert doc["tool"] == "vclint"
    assert doc["schema_version"] == JSON_SCHEMA_VERSION
    assert doc["total"] == 1
    assert doc["by_rule"] == {"rng-stream": 1}
    assert set(doc["violations"][0]) == {"path", "line", "rule", "message"}
    json.dumps(doc)  # must be serializable


def test_ratchet_new_violation_fails(tmp_path):
    dirty = lint_files(tmp_path, {"scenarios/bad.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n)
    """})
    base = tmp_path / "BASELINE.json"
    B.write_baseline(base, dirty)

    worse = lint_files(tmp_path / "w", {"scenarios/bad.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n) + np.random.randn(n)
    """})
    code, msgs = B.check_ratchet(worse, B.load_baseline(base))
    assert code == B.EXIT_VIOLATIONS
    assert any("ratchet" in m for m in msgs)


def test_ratchet_shrink_passes_and_repins(tmp_path):
    dirty = lint_files(tmp_path, {"scenarios/bad.py": """
        import numpy as np

        def jitter(n):
            return np.random.rand(n)
    """})
    base = tmp_path / "BASELINE.json"
    B.write_baseline(base, dirty)

    clean = lint_files(tmp_path / "c", {"scenarios/good.py": """
        def jitter(rng, n):
            return rng.random(n)
    """})
    code, msgs = B.check_ratchet(clean, B.load_baseline(base))
    assert code == B.EXIT_CLEAN
    assert any("re-pin" in m for m in msgs)
    B.write_baseline(base, clean)                 # shrink re-pins fine
    assert B.load_baseline(base)["total"] == 0
    with pytest.raises(SystemExit):               # growing again refuses
        B.write_baseline(base, dirty)


def test_missing_baseline_is_exit_2(tmp_path):
    report = lint_files(tmp_path, {"scenarios/empty.py": "x = 1\n"})
    code, msgs = B.check_ratchet(report, None)
    assert code == B.EXIT_NO_BASELINE
