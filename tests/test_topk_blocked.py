"""Blocked top-k selection + fused wire-encode properties.

The selection contract: ``select_topk`` is EXACT top-|x| (deterministic
under ties — lowest index wins, same as ``lax.top_k``), returning exactly
k ASCENDING indices on every path (dense fallback and sampled-bracket
fast path alike); ``blocked_topk_sparsify`` emits (kept, residual) with
kept + residual == x BIT-exact.  The encode contract: the fused pack
writes the same ``values || scales || indices`` body bytes the pre-PR
encoder produced with separate numpy ``tobytes()`` copies — sparse wire
frames are byte-identical at equal (tau, k).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.transfer import wire

RNG = jax.random.PRNGKey(7)

# one fast-path size (sampled bracket + blocked kernels) and two fallback
# sizes; the fast path needs n >= _MIN_FAST_N and n % 32 == 0
FAST_N = C._MIN_FAST_N
CASES = [(4096, 409), (65536, 655), (FAST_N, FAST_N // 20)]


def _oracle_idx(x, k):
    """Sort oracle with the lax.top_k tie rule: by (|x| desc, index asc)."""
    mag = np.abs(np.asarray(x, np.float32))
    order = np.lexsort((np.arange(mag.size), -mag.astype(np.float64)))
    return np.sort(order[:k])


def _tie_heavy(n):
    """Coarsely quantized magnitudes: thousands of exact ties, including
    across the selection boundary."""
    x = jax.random.normal(RNG, (n,), jnp.float32)
    return jnp.round(x * 4.0) / 4.0


@pytest.mark.parametrize("n,k", CASES)
def test_select_topk_exact_vs_sort_oracle(n, k):
    x = jax.random.normal(jax.random.fold_in(RNG, n), (n,), jnp.float32)
    idx = np.asarray(C.select_topk(x, k))
    assert idx.shape == (k,)
    assert (np.diff(idx) > 0).all()                  # ascending, unique
    np.testing.assert_array_equal(idx, _oracle_idx(x, k))


@pytest.mark.parametrize("n,k", CASES)
def test_select_topk_deterministic_k_under_ties(n, k):
    x = _tie_heavy(n)
    idx = np.asarray(C.select_topk(x, k))
    assert idx.shape == (k,)                          # exactly k, always
    np.testing.assert_array_equal(idx, _oracle_idx(x, k))


def test_select_topk_all_zero_input():
    n, k = CASES[0]
    idx = np.asarray(C.select_topk(jnp.zeros((n,), jnp.float32), k))
    np.testing.assert_array_equal(idx, np.arange(k))  # tie rule: lowest


@pytest.mark.parametrize("n,k", CASES)
def test_blocked_sparsify_kept_plus_residual_bit_exact(n, k):
    x = jax.random.normal(jax.random.fold_in(RNG, 2 * n + 1), (n,),
                          jnp.float32)
    kept, res = K.blocked_topk_sparsify(x, k)
    kb = np.asarray(kept).view(np.uint32)
    rb = np.asarray(res).view(np.uint32)
    xb = np.asarray(x).view(np.uint32)
    # reconstruction is BIT-exact: kept entries carry x with res == 0,
    # dropped entries carry res == x with kept == 0
    np.testing.assert_array_equal(
        np.asarray(kept + res).view(np.uint32), xb)
    idx = _oracle_idx(x, k)
    mask = np.zeros(n, bool)
    mask[idx] = True
    np.testing.assert_array_equal(kb[~mask], 0)
    np.testing.assert_array_equal(kb[mask], xb[mask])
    np.testing.assert_array_equal(rb[mask], 0)


@pytest.mark.parametrize("n,k", CASES)
def test_retained_mass_matches_sort_oracle(n, k):
    x = _tie_heavy(n)
    kept, _ = K.blocked_topk_sparsify(x, k)
    got = np.sort(np.abs(np.asarray(kept)[np.asarray(kept) != 0.0]))
    mag = np.sort(np.abs(np.asarray(x)))[-k:]
    # same multiset of magnitudes as the sort oracle's top k (ties may
    # leave zeros out of `kept`'s nonzero set only if x itself had a
    # zero in the top k, impossible for k < count of nonzeros)
    np.testing.assert_array_equal(got, mag[mag != 0.0])


def test_fused_encode_byte_identity_with_pre_pr_layout():
    """wire.encode(sparse) body == values.tobytes() || scales.tobytes()
    || indices.tobytes() — the exact byte layout the pre-PR encoder
    emitted with three separate host copies."""
    n, k = 8192, 819                              # k > block: 4 scale groups
    x = jax.random.normal(jax.random.fold_in(RNG, 99), (n,), jnp.float32)
    payload, _ = C.compress_flat(x, density=k / n)
    v = np.asarray(payload.values)
    s = np.asarray(payload.scales)
    i = np.asarray(payload.indices)
    expected_body = v.tobytes() + s.tobytes() + i.tobytes()
    frame = wire.encode(payload, round=3, residual_norm=0.5)
    assert frame.endswith(expected_body)
    msg = wire.decode(frame)
    np.testing.assert_array_equal(np.asarray(msg.payload.values), v)
    np.testing.assert_array_equal(np.asarray(msg.payload.scales), s)
    np.testing.assert_array_equal(np.asarray(msg.payload.indices), i)
    # the fused pack kernel and its oracle both reproduce the same bytes
    np.testing.assert_array_equal(
        np.asarray(K.fused_pack_body(payload.values, payload.scales,
                                     payload.indices)),
        np.frombuffer(expected_body, np.uint8))
    np.testing.assert_array_equal(
        np.asarray(R.pack_body(payload.values, payload.scales,
                               payload.indices)),
        np.frombuffer(expected_body, np.uint8))


def test_fused_quantize_pack_self_consistent():
    """The single-launch quantize+pack writes a body that encodes its OWN
    q/scales outputs exactly (no re-quantization drift between the body
    bytes and the returned arrays)."""
    k, block = 1024, 256
    sel = jax.random.normal(jax.random.fold_in(RNG, 5), (k,), jnp.float32)
    idx = jnp.sort(jax.random.permutation(
        jax.random.fold_in(RNG, 6), 4 * k)[:k]).astype(jnp.int32)
    body, q, scales = K.fused_quantize_pack(sel, idx, block=block)
    ng = -(-k // block)
    body = np.asarray(body)
    np.testing.assert_array_equal(
        body[:k], np.asarray(q)[:k].view(np.uint8))
    np.testing.assert_array_equal(
        body[k:k + 4 * ng], np.asarray(scales).view(np.uint8))
    np.testing.assert_array_equal(
        body[k + 4 * ng:], np.asarray(idx).view(np.uint8))
