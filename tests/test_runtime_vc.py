"""Pod-scale VC runtime on a 1x1x1 mesh: island weights (Eq. 2), survivor
masking, the vc_round contract, and compressed assimilation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import flat as F
from repro.core.vc_asgd import assimilation_weights
from repro.models.registry import build_model
from repro.optim import Adam
from repro.runtime.sharding import MeshPlan
from repro.runtime.vc_runtime import (compressed_assimilate, island_weights,
                                      make_vc_round, redistribute_flat,
                                      redistribute_per_leaf)
from repro.launch.mesh import compat_make_mesh, make_pod_mesh


def test_island_weights_match_eq2():
    w, ws = island_weights(4, 0.9, jnp.ones((4,), bool))
    ref = assimilation_weights(4, 0.9)
    np.testing.assert_allclose(np.asarray(w), ref[1:], rtol=1e-6)
    assert abs(float(ws) - ref[0]) < 1e-6


def test_island_weights_survivor_mask():
    surv = jnp.asarray([True, False, True, True])
    w, ws = island_weights(4, 0.9, surv)
    assert float(w[1]) == 0.0
    assert abs(float(w.sum() + ws) - 1.0) < 1e-6      # still convex


def test_vc_round_runs_and_learns():
    cfg = get_reduced("internlm2-1.8b")
    model = build_model(cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=1e-3)
    n_pods, k = 2, 2
    vc_round = make_vc_round(model, plan, n_pods, k, opt)
    key = jax.random.PRNGKey(0)
    server = model.init(key)
    islands = jax.tree.map(lambda s: jnp.stack([s] * n_pods), server)
    opts = jax.vmap(opt.init)(islands)
    toks = jax.random.randint(key, (n_pods, k, 4, 32), 0, cfg.vocab_size)
    batches = {"tokens": toks}
    with mesh:
        losses = []
        for rnd in range(4):
            server, islands, opts, m = vc_round(
                server, islands, opts, batches,
                jnp.asarray(0.5, jnp.float32), jnp.ones((n_pods,), bool))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_vc_round_dead_island_is_ignored():
    """A dead island's (stale) params must not affect the server."""
    cfg = get_reduced("internlm2-1.8b")
    model = build_model(cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=1e-3)
    vc_round = make_vc_round(model, plan, 2, 1, opt)
    key = jax.random.PRNGKey(1)
    server = model.init(key)
    islands = jax.tree.map(lambda s: jnp.stack([s, s]), server)
    # poison island 0 with garbage
    islands = jax.tree.map(
        lambda x: x.at[0].set(jnp.full_like(x[0], 1e9)), islands)
    opts = jax.vmap(opt.init)(islands)
    toks = jax.random.randint(key, (2, 1, 2, 16), 0, cfg.vocab_size)
    with mesh:
        server2, _, _, _ = vc_round(server, islands, opts, {"tokens": toks},
                                    jnp.asarray(0.9, jnp.float32),
                                    jnp.asarray([False, True]))
    for leaf in jax.tree.leaves(server2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
        assert np.abs(np.asarray(leaf, np.float32)).max() < 1e6


def test_redistribute_flat_matches_per_leaf_broadcast():
    """Step-3 redistribution on the bus is BIT-identical to the retained
    per-leaf tree.map broadcast oracle, mixed dtypes included."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    server = {"w": jax.random.normal(ks[0], (300, 41)),
              "b": jax.random.normal(ks[1], (9,), jnp.bfloat16),
              "d": {"m": jax.random.normal(ks[2], (2, 3, 4))}}
    n_pods = 3
    islands = jax.tree.map(
        lambda s: jnp.stack([s + 0.1 * (j + 1) for j in range(n_pods)]),
        server)
    isl_buf, spec = F.flatten_batched(islands)
    s_buf = F.flatten_like(server, spec)
    got = F.unflatten_batched(redistribute_flat(s_buf, n_pods), spec)
    oracle = redistribute_per_leaf(server, islands)
    for a, b in zip(jax.tree.leaves(oracle), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_redistribute_flat_sharded_1dev_matches():
    """The shard_map route (each device broadcasts only its own segment)
    equals the single-host broadcast bit-for-bit."""
    mesh = make_pod_mesh(1)
    buf = jax.random.normal(jax.random.PRNGKey(4), (2 * 8192,))
    plain = redistribute_flat(buf, 4)
    shard = redistribute_flat(buf, 4, mesh=mesh, shard_axis="pod")
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(shard))


def test_compressed_assimilate_error_feedback():
    key = jax.random.PRNGKey(2)
    server = {"w": jax.random.normal(key, (64, 32))}
    islands = {"w": jnp.stack([server["w"] + 0.1,
                               server["w"] - 0.2])}
    surv = jnp.ones((2,), bool)
    s1, res = compressed_assimilate(server, islands, 0.8, surv, density=0.25)
    # residuals exist and have island-major shape
    assert res["w"].shape == (2, 64, 32)
    # a second round with residual carry moves closer to the uncompressed
    from repro.runtime.vc_runtime import island_weights
    w, ws = island_weights(2, 0.8, surv)
    exact = ws * server["w"] + sum(
        float(w[j]) * islands["w"][j] for j in range(2))
    err1 = float(jnp.abs(s1["w"] - exact).mean())
    assert err1 < 0.05                                 # compression is close
