"""Pod-scale VC runtime on a 1x1x1 mesh: island weights (Eq. 2), survivor
masking, the vc_round contract, and compressed assimilation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.vc_asgd import assimilation_weights
from repro.models.registry import build_model
from repro.optim import Adam
from repro.runtime.sharding import MeshPlan
from repro.runtime.vc_runtime import (compressed_assimilate, island_weights,
                                      make_vc_round)
from repro.launch.mesh import compat_make_mesh


def test_island_weights_match_eq2():
    w, ws = island_weights(4, 0.9, jnp.ones((4,), bool))
    ref = assimilation_weights(4, 0.9)
    np.testing.assert_allclose(np.asarray(w), ref[1:], rtol=1e-6)
    assert abs(float(ws) - ref[0]) < 1e-6


def test_island_weights_survivor_mask():
    surv = jnp.asarray([True, False, True, True])
    w, ws = island_weights(4, 0.9, surv)
    assert float(w[1]) == 0.0
    assert abs(float(w.sum() + ws) - 1.0) < 1e-6      # still convex


def test_vc_round_runs_and_learns():
    cfg = get_reduced("internlm2-1.8b")
    model = build_model(cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=1e-3)
    n_pods, k = 2, 2
    vc_round = make_vc_round(model, plan, n_pods, k, opt)
    key = jax.random.PRNGKey(0)
    server = model.init(key)
    islands = jax.tree.map(lambda s: jnp.stack([s] * n_pods), server)
    opts = jax.vmap(opt.init)(islands)
    toks = jax.random.randint(key, (n_pods, k, 4, 32), 0, cfg.vocab_size)
    batches = {"tokens": toks}
    with mesh:
        losses = []
        for rnd in range(4):
            server, islands, opts, m = vc_round(
                server, islands, opts, batches,
                jnp.asarray(0.5, jnp.float32), jnp.ones((n_pods,), bool))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_vc_round_dead_island_is_ignored():
    """A dead island's (stale) params must not affect the server."""
    cfg = get_reduced("internlm2-1.8b")
    model = build_model(cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=1e-3)
    vc_round = make_vc_round(model, plan, 2, 1, opt)
    key = jax.random.PRNGKey(1)
    server = model.init(key)
    islands = jax.tree.map(lambda s: jnp.stack([s, s]), server)
    # poison island 0 with garbage
    islands = jax.tree.map(
        lambda x: x.at[0].set(jnp.full_like(x[0], 1e9)), islands)
    opts = jax.vmap(opt.init)(islands)
    toks = jax.random.randint(key, (2, 1, 2, 16), 0, cfg.vocab_size)
    with mesh:
        server2, _, _, _ = vc_round(server, islands, opts, {"tokens": toks},
                                    jnp.asarray(0.9, jnp.float32),
                                    jnp.asarray([False, True]))
    for leaf in jax.tree.leaves(server2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
        assert np.abs(np.asarray(leaf, np.float32)).max() < 1e6


def test_compressed_assimilate_error_feedback():
    key = jax.random.PRNGKey(2)
    server = {"w": jax.random.normal(key, (64, 32))}
    islands = {"w": jnp.stack([server["w"] + 0.1,
                               server["w"] - 0.2])}
    surv = jnp.ones((2,), bool)
    s1, res = compressed_assimilate(server, islands, 0.8, surv, density=0.25)
    # residuals exist and have island-major shape
    assert res["w"].shape == (2, 64, 32)
    # a second round with residual carry moves closer to the uncompressed
    from repro.runtime.vc_runtime import island_weights
    w, ws = island_weights(2, 0.8, surv)
    exact = ws * server["w"] + sum(
        float(w[j]) * islands["w"][j] for j in range(2))
    err1 = float(jnp.abs(s1["w"] - exact).mean())
    assert err1 < 0.05                                 # compression is close
