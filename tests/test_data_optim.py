"""Data pipeline determinism/shard-disjointness + optimizer math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import SyntheticTokenSource, ShardedTokenDataset, make_batch_for
from repro.data.pipeline import Prefetcher
from repro.optim import Adam, Sgd, clip_by_global_norm, cosine_schedule


def test_source_determinism():
    s1 = SyntheticTokenSource(1000, seed=5).sample(4, 64, offset=3)
    s2 = SyntheticTokenSource(1000, seed=5).sample(4, 64, offset=3)
    np.testing.assert_array_equal(s1, s2)
    s3 = SyntheticTokenSource(1000, seed=6).sample(4, 64, offset=3)
    assert (s1 != s3).any()


def test_shard_batches_distinct():
    ds = ShardedTokenDataset(SyntheticTokenSource(512, 0), n_shards=4,
                             seqs_per_shard=100, seq_len=32)
    b0 = ds.shard_batch(0, 8, 0)
    b1 = ds.shard_batch(1, 8, 0)
    assert (b0 != b1).any()
    np.testing.assert_array_equal(b0, ds.shard_batch(0, 8, 0))


def test_make_batch_for_families():
    for arch in ("internvl2-2b", "whisper-tiny", "internlm2-1.8b"):
        cfg = get_reduced(arch)
        b = make_batch_for(cfg, 2, 32)
        assert b["tokens"].dtype == jnp.int32
        assert int(b["tokens"].max()) < cfg.vocab_size
        if cfg.vision is not None:
            assert b["tokens"].shape == (2, 32 - cfg.vision.n_patches)
        else:
            assert b["tokens"].shape == (2, 32)


def test_prefetcher_order():
    out = list(Prefetcher(iter(range(10)), depth=3))
    assert out == list(range(10))


def test_adam_quadratic_descent():
    opt = Adam(lr=0.1)
    p = {"x": jnp.asarray([5.0, -3.0])}
    st = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        p, st = opt.update(g, st, p)
    assert float(jnp.abs(p["x"]).max()) < 0.05


def test_sgd_momentum_descent():
    opt = Sgd(lr=0.05, momentum=0.9)
    p = {"x": jnp.asarray([2.0])}
    st = opt.init(p)
    for _ in range(100):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        p, st = opt.update(g, st, p)
    assert float(jnp.abs(p["x"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(cn - 1.0) < 1e-4


def test_cosine_schedule_shape():
    f = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(f(jnp.asarray(100))) < float(f(jnp.asarray(50)))
