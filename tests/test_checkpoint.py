"""Checkpoint durability: bit-exact round trip (incl. bf16), retention,
kill/restore resume semantics, and the one-pass (params + m + v) train
record — including a real-SIGKILL atomicity test (slow)."""
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              load_flat_checkpoint, load_train_checkpoint,
                              save_checkpoint, save_flat_checkpoint,
                              save_train_checkpoint)
from repro.core import flat as F
from repro.optim import Adam


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (33, 17), jnp.float32),
            "b": (jax.random.normal(ks[1], (9,), jnp.bfloat16),
                  jnp.arange(5, dtype=jnp.int32)),
            "n": jax.random.normal(ks[2], (2, 3, 4))}


def test_roundtrip_bit_exact(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path / "c.msgpack", t, {"step": 7})
    out, extra = load_checkpoint(tmp_path / "c.msgpack", t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(jax.random.PRNGKey(1))
    for step in (1, 2, 3, 4):
        t2 = jax.tree.map(lambda x: x + step if x.dtype != jnp.int32 else x, t)
        mgr.save(step, t2, {"round": step})
    assert mgr.latest_step() == 4
    ckpts = sorted((tmp_path).glob("ckpt_*.msgpack"))
    assert len(ckpts) == 2                            # retention

    # simulated restart: fresh manager restores the newest snapshot
    mgr2 = CheckpointManager(tmp_path, keep=2)
    restored, extra, step = mgr2.restore_or_init(t, lambda: t)
    assert step == 4 and extra["round"] == 4
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(t["w"]) + 4)


def test_restore_or_init_fresh(tmp_path):
    mgr = CheckpointManager(tmp_path / "empty")
    t = _tree(jax.random.PRNGKey(2))
    out, extra, step = mgr.restore_or_init(t, lambda: t)
    assert step == 0 and extra == {}
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    t = _tree(jax.random.PRNGKey(3))
    mgr.save(1, t)
    mgr.wait()
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# one-pass train checkpoints: params + m + v as three lanes of ONE record
# ---------------------------------------------------------------------------

def _train_state(key, n_steps=3):
    tree = {"w": jax.random.normal(key, (40, 9)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (17,))}
    opt = Adam(lr=1e-2)
    fp = F.flatten(tree)
    fos = opt.init_flat(fp)
    for i in range(n_steps):
        g = F.flatten_like(jax.tree.map(
            lambda x: jax.random.normal(jax.random.fold_in(key, 10 + i),
                                        x.shape), tree), fp.spec)
        fp, fos = opt.update_flat(g, fos, fp)
    return fp, fos


def test_train_checkpoint_roundtrip(tmp_path):
    fp, fos = _train_state(jax.random.PRNGKey(0))
    save_train_checkpoint(tmp_path / "t.msgpack", fp, fos, {"round": 9})
    fp2, fos2, extra = load_train_checkpoint(tmp_path / "t.msgpack", fp)
    assert extra["round"] == 9
    assert int(fos2.step) == int(fos.step) == 3
    np.testing.assert_array_equal(np.asarray(fp.buf), np.asarray(fp2.buf))
    np.testing.assert_array_equal(np.asarray(fos.m), np.asarray(fos2.m))
    np.testing.assert_array_equal(np.asarray(fos.v), np.asarray(fos2.v))


def test_train_checkpoint_is_one_contiguous_record(tmp_path):
    """The whole (params, m, v) state is ONE msgpack binary record — a
    header plus exactly one buffer write, no per-leaf packing."""
    fp, fos = _train_state(jax.random.PRNGKey(1))
    save_train_checkpoint(tmp_path / "t.msgpack", fp, fos)
    with open(tmp_path / "t.msgpack", "rb") as f:
        objs = list(msgpack.Unpacker(f, raw=False, max_buffer_size=2 ** 31))
    assert len(objs) == 2                  # header + ONE record
    header, record = objs
    assert header["kind"] == "flat-train"
    assert len(record) == sum(header["lane_bytes"])
    assert len(record) == 3 * fp.spec.padded * 4      # three f32 lanes


def test_train_checkpoint_kind_mismatch_raises(tmp_path):
    fp, fos = _train_state(jax.random.PRNGKey(2))
    save_train_checkpoint(tmp_path / "train.msgpack", fp, fos)
    save_flat_checkpoint(tmp_path / "flat.msgpack", fp)
    with pytest.raises(ValueError):
        load_flat_checkpoint(tmp_path / "train.msgpack", fp)
    with pytest.raises(ValueError):
        load_train_checkpoint(tmp_path / "flat.msgpack", fp)


def test_train_checkpoint_layout_mismatch_raises(tmp_path):
    fp, fos = _train_state(jax.random.PRNGKey(3))
    save_train_checkpoint(tmp_path / "t.msgpack", fp, fos)
    other = F.flatten({"z": jnp.zeros((5,))})
    with pytest.raises(ValueError):
        load_train_checkpoint(tmp_path / "t.msgpack", other)


def test_manager_train_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    fp, fos = _train_state(jax.random.PRNGKey(4))
    mgr.save_train(5, fp, fos, {"round": 5})
    mgr2 = CheckpointManager(tmp_path)
    (fp2, fos2), extra, step = mgr2.restore_train_or_init(
        fp, lambda: (None, None))
    assert step == 5 and extra["round"] == 5
    np.testing.assert_array_equal(np.asarray(fp.buf), np.asarray(fp2.buf))
    np.testing.assert_array_equal(np.asarray(fos.v), np.asarray(fos2.v))


# ---------------------------------------------------------------------------
# REAL kill: SIGKILL the training process mid-run, then restore.  Atomic
# rename means the newest committed record always loads cleanly, and the
# resumed trajectory equals the uninterrupted one at matching steps.
# ---------------------------------------------------------------------------

_CHILD = """
import sys, time
sys.path.insert(0, sys.argv[2])
from repro.core.simulator import run_preemptible_training
from repro.core.tasks import MLPTask, make_classification_data

task = MLPTask()
data = make_classification_data(n_train=600, n_val=100)
print("READY", flush=True)
run_preemptible_training(task, data, steps=10 ** 9, batch=32, ckpt_every=3,
                         ckpt_dir=sys.argv[1], seed=5,
                         on_step=lambda s: time.sleep(0.01))
"""


@pytest.mark.slow
def test_sigkill_mid_training_restores_and_matches(tmp_path):
    from repro.core.simulator import run_preemptible_training
    from repro.core.tasks import MLPTask, make_classification_data

    src = str(Path(__file__).resolve().parents[1] / "src")
    ckpt_dir = tmp_path / "ckpt"
    proc = subprocess.Popen([sys.executable, "-c", _CHILD, str(ckpt_dir), src],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    try:
        assert proc.stdout.readline().strip() == b"READY"
        # let it train + checkpoint for a while, then pull the plug
        deadline = time.time() + 60
        while time.time() < deadline:
            time.sleep(0.5)
            ckpts = list(ckpt_dir.glob("ckpt_*.msgpack"))
            if len(ckpts) >= 3:
                break
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)

    committed = list(ckpt_dir.glob("ckpt_*.msgpack"))
    assert committed, ("child process wrote no checkpoint before the kill "
                       "(machine too slow? raise the deadline)")

    task = MLPTask()
    data = make_classification_data(n_train=600, n_val=100)
    key = jax.random.PRNGKey(5)
    like = F.flatten(task.init_params(key))
    # the newest COMMITTED record loads cleanly (atomic rename: no torn file)
    mgr = CheckpointManager(ckpt_dir)
    (fp, fos), extra, step = mgr.restore_train_or_init(like, lambda: None)
    assert step > 0 and step % 3 == 0 and extra["step"] == step
    assert int(fos.step) == step

    # resuming from the survivor reproduces the uninterrupted trajectory
    horizon = step + 6
    resumed = run_preemptible_training(task, data, steps=horizon, batch=32,
                                       ckpt_every=3, ckpt_dir=ckpt_dir,
                                       seed=5)
    clean = run_preemptible_training(task, data, steps=horizon, batch=32,
                                     ckpt_every=3,
                                     ckpt_dir=tmp_path / "clean", seed=5)
    for s in range(step, horizon):
        assert resumed.losses[s] == clean.losses[s], s
    np.testing.assert_array_equal(np.asarray(resumed.final_params.buf),
                                  np.asarray(clean.final_params.buf))
