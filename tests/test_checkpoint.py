"""Checkpoint durability: bit-exact round trip (incl. bf16), retention,
kill/restore resume semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(key):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (33, 17), jnp.float32),
            "b": (jax.random.normal(ks[1], (9,), jnp.bfloat16),
                  jnp.arange(5, dtype=jnp.int32)),
            "n": jax.random.normal(ks[2], (2, 3, 4))}


def test_roundtrip_bit_exact(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path / "c.msgpack", t, {"step": 7})
    out, extra = load_checkpoint(tmp_path / "c.msgpack", t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(jax.random.PRNGKey(1))
    for step in (1, 2, 3, 4):
        t2 = jax.tree.map(lambda x: x + step if x.dtype != jnp.int32 else x, t)
        mgr.save(step, t2, {"round": step})
    assert mgr.latest_step() == 4
    ckpts = sorted((tmp_path).glob("ckpt_*.msgpack"))
    assert len(ckpts) == 2                            # retention

    # simulated restart: fresh manager restores the newest snapshot
    mgr2 = CheckpointManager(tmp_path, keep=2)
    restored, extra, step = mgr2.restore_or_init(t, lambda: t)
    assert step == 4 and extra["round"] == 4
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(t["w"]) + 4)


def test_restore_or_init_fresh(tmp_path):
    mgr = CheckpointManager(tmp_path / "empty")
    t = _tree(jax.random.PRNGKey(2))
    out, extra, step = mgr.restore_or_init(t, lambda: t)
    assert step == 0 and extra == {}
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    t = _tree(jax.random.PRNGKey(3))
    mgr.save(1, t)
    mgr.wait()
    assert mgr.latest_step() == 1
