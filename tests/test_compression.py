"""Compression invariants: error feedback conserves the delta exactly;
round-trips bound quantization error; ratios are as advertised."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import compression as C


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), density=st.floats(0.01, 0.5))
def test_error_feedback_conserves_delta(seed, density):
    """transmitted + residual == delta exactly (up to quantization error
    already inside `transmitted`): delta - residual == dequant(payload)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (257, 33)) * 2
    payload, residual = C.compress_delta(x, density=density)
    deq = C.decompress_delta(payload)
    np.testing.assert_allclose(np.asarray(x - residual), np.asarray(deq),
                               rtol=1e-5, atol=1e-6)


def test_topk_selects_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 1.0])
    payload, res = C.compress_delta(x, density=0.34)       # k = 2
    deq = np.asarray(C.decompress_delta(payload))
    nz = np.flatnonzero(deq)
    assert set(nz) == {1, 3}


def test_quant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (10000,)) * 7
    q, s = C.quantize_int8(x)
    deq = C.dequantize_int8(q, s, x.size)
    per_block_scale = np.repeat(np.asarray(s), 256)[: x.size]
    assert (np.abs(np.asarray(x) - np.asarray(deq))
            <= per_block_scale * 0.5 + 1e-7).all()


def test_compression_ratio():
    x = jax.random.normal(jax.random.PRNGKey(1), (100_000,))
    payload, _ = C.compress_delta(x, density=0.05)
    ratio = C.compression_ratio(payload)
    # 5% density, ~5 bytes/kept value (1B q + 4B idx + scale amortized):
    # ratio = 4n / (5 * 0.05n) = 16
    assert 14.0 < ratio < 18.0
