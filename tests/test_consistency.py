"""Store semantics: eventual last-writer-wins clobbering + snapshot reads;
strong serialization with zero loss (§III-D / §IV-D)."""
import numpy as np

from repro.core.consistency import (MYSQL_UPDATE_S, REDIS_UPDATE_S,
                                    EventualStore, StrongStore)


def test_eventual_lww_clobbers_racing_commit():
    st = EventualStore({"w": 0.0})
    # PS A reads at t=0, PS B reads at t=0.1; B commits first, A clobbers it
    snapA, _ = st.read_at(0.0)
    snapB, _ = st.read_at(0.1)
    tB = st.commit(0.1, 1.0, {"w": snapB["w"] + 10})
    tA = st.commit(0.0, 2.0, {"w": snapA["w"] + 1})
    assert tB < tA
    assert st.stats.lost_updates == 1
    assert st.head()["w"] == 1.0                    # B's +10 was lost
    # and future snapshot reads never resurrect the clobbered value
    assert st.read_at(tA + 1)[0]["w"] == 1.0


def test_eventual_sequential_no_loss():
    st = EventualStore({"w": 0.0})
    t = 0.0
    for i in range(5):
        snap, _ = st.read_at(t)
        t = st.commit(t, t, {"w": snap["w"] + 1})
        t += 0.01
    assert st.stats.lost_updates == 0
    assert st.head()["w"] == 5.0


def test_strong_serializes_and_never_loses():
    st = StrongStore({"w": 0.0})
    # three transactions requested at the same time: they queue
    t1 = st.transact(0.0, lambda p: {"w": p["w"] + 1})
    t2 = st.transact(0.0, lambda p: {"w": p["w"] + 1})
    t3 = st.transact(0.0, lambda p: {"w": p["w"] + 1})
    assert st.head()["w"] == 3.0
    assert abs(t1 - MYSQL_UPDATE_S) < 1e-9
    assert abs(t2 - 2 * MYSQL_UPDATE_S) < 1e-9
    assert abs(t3 - 3 * MYSQL_UPDATE_S) < 1e-9
    assert st.stats.queue_wait_s > 0


def test_update_latency_ratio_matches_paper():
    """§IV-D: MySQL takes ~1.5x longer per update transaction."""
    assert abs(MYSQL_UPDATE_S / REDIS_UPDATE_S - 1.48) < 0.02
