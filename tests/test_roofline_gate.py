"""HLO roofline parser + per-kernel gate unit tests.

Parser tests run over PINNED HLO text snippets (no compiler in the
loop), covering the call-graph multiplier pass: while-loop trip counts,
fusion IO, and lax.cond conditionals (every branch charged at the
caller's multiplier — a conservative upper bound).  Gate tests drive
``check_kernel_rooflines`` against synthetic profiles: the shipped
profile passes its own baseline, an injected doubled-bytes regression
fails, and so do a missing kernel and an order-of-magnitude slowdown.
"""
import json

import pytest

from repro.runtime.hlo_analysis import (KernelProfile, analyze_hlo_text,
                                        profile_kernel)

# ---------------------------------------------------------------------------
# pinned HLO snippets
# ---------------------------------------------------------------------------

_WHILE_HLO = """\
ENTRY %main.1 (p0: f32[256]) -> f32[256] {
  %p0 = f32[256] parameter(0)
  ROOT %while.1 = f32[256] while(%p0), condition=%cond_c, body=%body_c, backend_config={"known_trip_count":{"n":"5"}}
}

%body_c (p: f32[256]) -> f32[256] {
  %p = f32[256] parameter(0)
  ROOT %sort.2 = f32[256] sort(%p), dimensions={0}
}

%cond_c (p: f32[256]) -> pred[] {
  %p = f32[256] parameter(0)
  %c9 = s32[] constant(9)
  ROOT %lt.1 = pred[] compare(%c9, %c9), direction=LT
}
"""

_COND_HLO = """\
ENTRY %main.2 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  %b0 = s32[] parameter(1)
  ROOT %conditional.3 = f32[1024] conditional(%b0, %p0, %p0), branch_computations={%branch_a, %branch_b}
}

%branch_a (pa: f32[1024]) -> f32[1024] {
  %pa = f32[1024] parameter(0)
  ROOT %sort.4 = f32[1024] sort(%pa), dimensions={0}
}

%branch_b (pb: f32[1024]) -> f32[1024] {
  %pb = f32[1024] parameter(0)
  ROOT %sort.5 = f32[1024] sort(%pb), dimensions={0}
}
"""

_TF_COND_HLO = """\
ENTRY %main.3 (p0: f32[512]) -> f32[512] {
  %p0 = f32[512] parameter(0)
  %pr = pred[] parameter(1)
  ROOT %conditional.6 = f32[512] conditional(%pr, %p0, %p0), true_computation=%tbr, false_computation=%fbr
}

%tbr (pt: f32[512]) -> f32[512] {
  %pt = f32[512] parameter(0)
  ROOT %sort.7 = f32[512] sort(%pt), dimensions={0}
}

%fbr (pf: f32[512]) -> f32[512] {
  %pf = f32[512] parameter(0)
  ROOT %sort.8 = f32[512] sort(%pf), dimensions={0}
}
"""


def test_while_trip_count_multiplies_body_bytes():
    cost = analyze_hlo_text(_WHILE_HLO)
    assert cost.while_trips == {"body_c": 5}
    # one sort per trip: (result 1024B + operand 1024B) x 5
    assert cost.hbm_strict == 5 * 2048
    assert cost.hbm_bytes == 5 * 2048


def test_while_trip_from_condition_constant():
    # strip the known_trip_count annotation: the parser falls back to the
    # largest integer constant in the condition computation (9)
    txt = _WHILE_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    cost = analyze_hlo_text(txt)
    assert cost.while_trips == {"body_c": 9}
    assert cost.hbm_strict == 9 * 2048


def test_conditional_charges_every_branch():
    cost = analyze_hlo_text(_COND_HLO)
    # both 4096B sorts counted at the caller's x1 multiplier — only one
    # branch ever runs, so the denominator is a conservative upper bound
    assert cost.hbm_strict == 2 * (4096 + 4096)


def test_true_false_conditional_charges_both_sides():
    cost = analyze_hlo_text(_TF_COND_HLO)
    assert cost.hbm_strict == 2 * (2048 + 2048)


def test_profile_kernel_measures_real_traffic():
    import jax.numpy as jnp
    x = jnp.arange(8192, dtype=jnp.float32)
    prof = profile_kernel("inc", lambda v: v + 1.0, (x,),
                          analytic_bytes=2 * 4 * 8192, iters=2)
    assert prof.hlo_bytes > 0
    assert prof.measured_s > 0
    assert 0 < prof.traffic_fraction <= 4.0


# ---------------------------------------------------------------------------
# the gate itself
# ---------------------------------------------------------------------------


def _profiles():
    return {
        "a": KernelProfile("a", analytic_bytes=1e6, hlo_bytes=4e6,
                           hlo_flops=0.0, measured_s=1e-3),
        "b": KernelProfile("b", analytic_bytes=2e6, hlo_bytes=2e6,
                           hlo_flops=0.0, measured_s=2e-3),
    }


def _baseline(tmp_path, profiles):
    p = tmp_path / "BASELINE_roofline.json"
    p.write_text(json.dumps({n: pr.as_dict()
                             for n, pr in profiles.items()}))
    return p


def test_gate_passes_on_identical_profiles(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    base = _baseline(tmp_path, _profiles())
    assert check_kernel_rooflines(_profiles(), baseline_path=base) == 0


def test_gate_fails_on_injected_doubled_bytes(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    base = _baseline(tmp_path, _profiles())
    worse = _profiles()
    worse["a"] = KernelProfile("a", analytic_bytes=1e6, hlo_bytes=8e6,
                               hlo_flops=0.0, measured_s=1e-3)
    assert check_kernel_rooflines(worse, baseline_path=base) == 1


def test_gate_fails_on_missing_kernel(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    base = _baseline(tmp_path, _profiles())
    only_a = {"a": _profiles()["a"]}
    assert check_kernel_rooflines(only_a, baseline_path=base) == 1


def test_gate_fails_on_order_of_magnitude_slowdown(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    base = _baseline(tmp_path, _profiles())
    slow = _profiles()
    slow["b"] = KernelProfile("b", analytic_bytes=2e6, hlo_bytes=2e6,
                              hlo_flops=0.0, measured_s=2e-2)
    assert check_kernel_rooflines(slow, baseline_path=base) == 1


def test_gate_tolerates_fraction_jitter(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    base = _baseline(tmp_path, _profiles())
    jitter = _profiles()
    # 10% more HLO bytes: inside the 25% relative ratchet slack
    jitter["a"] = KernelProfile("a", analytic_bytes=1e6, hlo_bytes=4.4e6,
                                hlo_flops=0.0, measured_s=1e-3)
    assert check_kernel_rooflines(jitter, baseline_path=base) == 0


def test_gate_reports_missing_baseline(tmp_path):
    from benchmarks.roofline_report import check_kernel_rooflines
    assert check_kernel_rooflines(
        _profiles(), baseline_path=tmp_path / "nope.json") == 2


def test_shipped_baseline_has_every_registered_kernel():
    """The committed baseline and the registry must stay in sync — a
    kernel added without re-pinning (or pinned without a builder) would
    make --check fail in CI."""
    from benchmarks.roofline_report import (KERNEL_ROOFLINES,
                                            ROOFLINE_BASELINE)
    pinned = json.loads(ROOFLINE_BASELINE.read_text())
    assert set(pinned) == set(KERNEL_ROOFLINES)
    for name, pin in pinned.items():
        assert pin["traffic_fraction"] > 0
        assert pin["achieved_gbps"] > 0
