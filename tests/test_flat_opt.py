"""FlatOptState (core/flat.py + optim/optimizers.py): Adam m/v as extra
lanes of the parameter bus.  Deterministic tiers: flat-vs-tree bit-exactness
over multi-step sequences, padding invariants, single-launch fused kernel
parity, pytree registration, and the fused flat EASGD pod baseline.
Property tier (hypothesis, via the _hyp fallback): bit-exactness over
RANDOM step sequences and hyperparameters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flat as F
from repro.core.baselines import (EASGDFlatPod, ResultMeta,
                                  easgd_elastic_update)
from repro.kernels import ref as R
from repro.kernels import vc_asgd_update as VK
from repro.optim import Adam
from repro.optim.optimizers import flat_opt_from_tree, flat_opt_to_tree


def f32_tree(key):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (130, 7)),
            "b": {"c": jax.random.normal(ks[1], (55,)),
                  "d": jax.random.normal(ks[2], (3, 3))}}


def grad_like(tree, key):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size), x.shape),
        tree)


def run_both_paths(opt, tree, n_steps, key):
    """(tree-path params/state, flat-path params/state) after n_steps of
    identical random gradients."""
    state_t = opt.init(tree)
    fp = F.flatten(tree)
    state_f = opt.init_flat(fp)
    p_t, p_f = tree, fp
    for i in range(n_steps):
        g = grad_like(tree, jax.random.fold_in(key, i))
        p_t, state_t = opt.update(g, state_t, p_t)
        gbuf = F.flatten_like(g, fp.spec)
        p_f, state_f = opt.update_flat(gbuf, state_f, p_f)
    return (p_t, state_t), (p_f, state_f)


# ---------------------------------------------------------------------------
# flat vs tree Adam: bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [
    Adam(lr=1e-3),
    Adam(lr=3e-2, b1=0.8, b2=0.95, weight_decay=0.01),
    Adam(lr=lambda t: 1e-3 * jnp.minimum(1.0, t / 3.0)),   # schedule
])
def test_flat_adam_bit_exact_vs_tree(opt):
    tree = f32_tree(jax.random.PRNGKey(0))
    (p_t, s_t), (p_f, s_f) = run_both_paths(opt, tree, 5,
                                            jax.random.PRNGKey(1))
    for a, b in zip(jax.tree.leaves(p_t), jax.tree.leaves(F.unflatten(p_f))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    back = flat_opt_to_tree(s_f)
    assert int(back.step) == int(s_t.step)
    for a, b in zip(jax.tree.leaves(s_t.m), jax.tree.leaves(back.m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_t.v), jax.tree.leaves(back.v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_adam_padding_stays_zero():
    """The zero tail is a fixed point of the update: g=0 -> m=v=0 ->
    step=0, even with weight decay (p=0 there too)."""
    opt = Adam(lr=1e-2, weight_decay=0.1)
    tree = f32_tree(jax.random.PRNGKey(2))
    _, (p_f, s_f) = run_both_paths(opt, tree, 4, jax.random.PRNGKey(3))
    n = p_f.spec.n
    np.testing.assert_array_equal(np.asarray(p_f.buf[n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(s_f.m[n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(s_f.v[n:]), 0.0)


def test_flat_opt_state_roundtrips_through_tree():
    opt = Adam(lr=1e-3)
    tree = f32_tree(jax.random.PRNGKey(4))
    (_, s_t), (p_f, _) = run_both_paths(opt, tree, 3, jax.random.PRNGKey(5))
    fos = flat_opt_from_tree(s_t, p_f.spec)
    back = flat_opt_to_tree(fos)
    for a, b in zip(jax.tree.leaves(s_t.m), jax.tree.leaves(back.m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(fos.m[p_f.spec.n:]), 0.0)


def test_flat_opt_state_is_a_pytree():
    fp = F.flatten(f32_tree(jax.random.PRNGKey(6)))
    fos = F.init_opt_state(fp.spec)
    doubled = jax.jit(lambda s: jax.tree.map(lambda x: 2 * x + 1, s))(fos)
    assert isinstance(doubled, F.FlatOptState)
    assert doubled.spec is fos.spec
    np.testing.assert_array_equal(np.asarray(doubled.m),
                                  np.ones_like(np.asarray(fos.m)))


# ---------------------------------------------------------------------------
# fused kernel path: single launch, parity with the eager flat path
# ---------------------------------------------------------------------------

def test_flat_adam_kernel_single_launch_whole_model():
    opt = Adam(lr=1e-3, weight_decay=0.01)
    tree = f32_tree(jax.random.PRNGKey(7))
    fp = F.flatten(tree)
    fos = opt.init_flat(fp)
    g = F.flatten_like(grad_like(tree, jax.random.PRNGKey(8)), fp.spec)

    VK.reset_launch_count()
    p_k, s_k = opt.update_flat(g, fos, fp, use_kernel=True)
    assert VK.launch_count() == 1          # whole model, one pallas_call

    p_e, s_e = opt.update_flat(g, fos, fp)
    np.testing.assert_allclose(np.asarray(p_k.buf), np.asarray(p_e.buf),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(s_k.m), np.asarray(s_e.m),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(s_k.v), np.asarray(s_e.v),
                               rtol=2e-6, atol=2e-6)
    assert int(s_k.step) == 1


# ---------------------------------------------------------------------------
# flat EASGD pod baseline
# ---------------------------------------------------------------------------

def _meta(cid):
    return ResultMeta(cid=cid, unit_uid=cid, epoch=0, shard=cid,
                      read_version=0, server_version=0)


def test_easgd_flat_pod_round_matches_ref():
    """One complete round == the simultaneous elastic update on the stacked
    replica matrix (kernels/ref.py oracle)."""
    key = jax.random.PRNGKey(9)
    tree = f32_tree(key)
    scheme = EASGDFlatPod(n_replicas=3, beta=0.1)
    state = scheme.init_state(F.flatten(tree))
    center0 = state.params.buf
    payloads = [center0 + 0.1 * (j + 1) for j in range(3)]
    for j in range(3):
        state = scheme.assimilate(state, payloads[j], _meta(j))
        assert state.version == (1 if j == 2 else 0)      # round barrier
    c_ref, x_ref = R.easgd_elastic(center0, jnp.stack(payloads), 0.1)
    np.testing.assert_allclose(np.asarray(state.params.buf),
                               np.asarray(c_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.replicas),
                               np.asarray(x_ref), rtol=1e-6, atol=1e-6)


def test_easgd_flat_pod_drop_client_restarts_from_center():
    tree = f32_tree(jax.random.PRNGKey(10))
    scheme = EASGDFlatPod(n_replicas=2, beta=0.1)
    state = scheme.init_state(F.flatten(tree))
    state = scheme.assimilate(state, state.params.buf + 1.0, _meta(0))
    scheme.drop_client(state, 0)
    # the preempted slot's handout is the center, not its stale replica
    np.testing.assert_array_equal(
        np.asarray(scheme.params_for_client(state, 0).buf),
        np.asarray(state.params.buf))
    assert 0 not in state.pending          # the barrier re-waits for slot 0


def test_easgd_flat_pod_rejects_slot_collision():
    tree = f32_tree(jax.random.PRNGKey(12))
    scheme = EASGDFlatPod(n_replicas=2, beta=0.1)
    state = scheme.init_state(F.flatten(tree))
    state = scheme.assimilate(state, state.params.buf + 1.0, _meta(0))
    with pytest.raises(ValueError):        # cid 2 maps onto cid 0's slot
        scheme.assimilate(state, state.params.buf + 2.0, _meta(2))


def test_easgd_elastic_update_kernel_matches_jnp():
    key = jax.random.PRNGKey(11)
    c = jax.random.normal(key, (2 * F.BLOCK,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 2 * F.BLOCK))
    c_j, x_j = easgd_elastic_update(c, x, 0.07)
    VK.reset_launch_count()
    c_k, x_k = easgd_elastic_update(c, x, 0.07, use_kernel=True)
    assert VK.launch_count() == 1
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_j),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_j),
                               rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# property tier (skips cleanly without hypothesis — tests/_hyp.py)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_prop_flat_adam_bit_exact_random_sequences(data):
    """Flat == tree Adam bit-for-bit over RANDOM step counts, hyperparams
    and leaf layouts (the acceptance-criterion property)."""
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    n_steps = data.draw(st.integers(1, 7), label="n_steps")
    lr = data.draw(st.floats(1e-5, 0.1, allow_nan=False), label="lr")
    wd = data.draw(st.sampled_from([0.0, 0.01, 0.1]), label="wd")
    n_leaves = data.draw(st.integers(1, 4), label="n_leaves")
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(n_leaves):
        shape = tuple(data.draw(st.lists(st.integers(1, 9), min_size=0,
                                         max_size=2), label=f"shape{i}"))
        tree[f"l{i}"] = jax.random.normal(jax.random.fold_in(key, i), shape)
    opt = Adam(lr=lr, weight_decay=wd)
    (p_t, s_t), (p_f, s_f) = run_both_paths(opt, tree, n_steps,
                                            jax.random.fold_in(key, 999))
    for a, b in zip(jax.tree.leaves(p_t), jax.tree.leaves(F.unflatten(p_f))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    back = flat_opt_to_tree(s_f)
    for a, b in zip(jax.tree.leaves(s_t.v), jax.tree.leaves(back.v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n = p_f.spec.n
    np.testing.assert_array_equal(np.asarray(s_f.m[n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(s_f.v[n:]), 0.0)
