"""Content-addressed handout cache + read-only serving layer.

The contract under test (transfer/handout_cache.py, protocol/handout.py):

* **Byte-identity** — the cached frame for (round, chunk, content) is
  byte-for-byte what a fresh per-client encode would produce, under
  arbitrary interleavings of mutation / handout / drop / checkpoint
  restore (including the full re-download after a restore).
* **Bounded memory** — at most ``n_chunks * keep_rounds`` frames
  resident no matter how many rounds/readers pass; the retention
  watermark evicts, rewound requests bypass the cache.
* **Dedup accounting** — a second identical handout costs ZERO new
  encodes; served-vs-encoded bytes drive the dedup ratio the benchmark
  gates on.
* **bf16 download frames** — f32 masters, bf16-exact reconstruction,
  half the bytes.
"""
from __future__ import annotations

import json
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flat as F
from repro.core.baselines import VCASGD
from repro.protocol import Coordinator, HandoutService
from repro.transfer import wire
from repro.transfer.handout_cache import HandoutCache, chunk_hash

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))


def _params(seed=0, shape=(40, 16), n_shards=8):
    tree = {"w": jax.random.normal(jax.random.PRNGKey(seed), shape)}
    return (F.flatten(tree) if n_shards <= 1
            else F.flatten_sharded(tree, n_shards))


def _oracle_frames(coord, rnd):
    """Fresh per-client encode of every chunk straight from the wire
    module — what the pre-cache coordinator did per client."""
    buf = np.asarray(coord.state.params.buf)
    spec = coord.state.params.spec
    bf16 = coord.handout_dtype == "bfloat16"
    n = spec.n_shards if isinstance(spec, F.ShardedTreeSpec) else 1
    out = []
    for i in range(n):
        if n == 1:
            seg = buf
        else:
            lo, hi = spec.shard_bounds(i)
            seg = buf[lo:hi]
        if bf16:
            seg = seg.astype(jnp.bfloat16)
        out.append(wire.encode_dense(seg, round=rnd) if n == 1
                   else wire.encode_shard(seg, shard=i, n_shards=n,
                                          round=rnd))
    return out


# ---------------------------------------------------------------------------
# HandoutCache unit contract
# ---------------------------------------------------------------------------

def test_cache_second_identical_request_is_free():
    cache = HandoutCache()
    data = np.arange(8, dtype=np.float32)
    calls = []

    def enc():
        calls.append(1)
        return b"frame-bytes"

    f1, fresh1 = cache.get(round=0, chunk=0, version=1, data=data, encode=enc)
    f2, fresh2 = cache.get(round=0, chunk=0, version=1, data=data, encode=enc)
    assert (fresh1, fresh2) == (True, False)
    assert f1 == f2 == b"frame-bytes" and len(calls) == 1
    assert cache.encodes == 1 and cache.hits == 1
    assert cache.served_frames == 2
    assert cache.served_bytes == 2 * len(b"frame-bytes")
    assert cache.dedup_ratio == 2.0


def test_cache_content_change_is_a_new_key_and_supersedes():
    cache = HandoutCache()
    a = np.zeros(4, dtype=np.float32)
    b = np.ones(4, dtype=np.float32)
    cache.get(round=0, chunk=0, version=1, data=a, encode=lambda: b"A")
    f, fresh = cache.get(round=0, chunk=0, version=2, data=b,
                         encode=lambda: b"B")
    assert fresh and f == b"B"
    # within-round supersede: old content can never be served again
    assert cache.frames_held == 1 and cache.evicted == 1
    # and the hash really keys on content, not version
    assert chunk_hash(a) != chunk_hash(b)


def test_cache_watermark_eviction_and_rewind_bypass():
    cache = HandoutCache(keep_rounds=2)
    data = np.zeros(4, dtype=np.float32)
    for rnd in range(6):
        cache.get(round=rnd, chunk=0, version=1, data=data,
                  encode=lambda: b"x%d" % rnd)
    assert cache.watermark == 5 - 2 + 1 == 4
    assert cache.frames_held <= 2
    held_before = cache.frames_held
    # a rewound requester (restore took rounds backwards) is served a
    # fresh encode and the cache stays clean — never stored, never wrong
    f, fresh = cache.get(round=0, chunk=0, version=1, data=data,
                         encode=lambda: b"rewound")
    assert fresh and f == b"rewound"
    assert cache.frames_held == held_before


def test_cache_keep_rounds_validation():
    with pytest.raises(ValueError):
        HandoutCache(keep_rounds=0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_cache_random_schedule_always_serves_oracle_bytes(seed):
    """Random (round, chunk, mutate?) schedules with nondecreasing
    rounds: the cache's answer is ALWAYS the oracle encode of the
    current content, and residency never exceeds n_chunks*keep_rounds."""
    rng = np.random.default_rng(seed)
    n_chunks = int(rng.integers(1, 5))
    cache = HandoutCache(keep_rounds=int(rng.integers(1, 4)))
    content = [np.zeros(6, dtype=np.float32) for _ in range(n_chunks)]
    version = [1] * n_chunks
    rnd = 0
    for _ in range(60):
        op = rng.random()
        if op < 0.2:
            rnd += int(rng.integers(0, 3))
        chunk = int(rng.integers(0, n_chunks))
        if op < 0.35:
            content[chunk] = content[chunk] + 1.0
            version[chunk] += 1
        oracle = wire.encode_shard(content[chunk], shard=chunk,
                                   n_shards=n_chunks, round=rnd)
        frame, _ = cache.get(round=rnd, chunk=chunk,
                             version=version[chunk], data=content[chunk],
                             encode=lambda c=chunk, r=rnd:
                             wire.encode_shard(content[c], shard=c,
                                               n_shards=n_chunks, round=r))
        assert frame == oracle
        assert cache.frames_held <= n_chunks * cache.keep_rounds


# ---------------------------------------------------------------------------
# Coordinator routes every handout through the cache
# ---------------------------------------------------------------------------

def test_second_client_same_round_costs_zero_encodes():
    fp = _params()
    n = fp.spec.n_shards
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    l1 = coord.issue(cid=0, uid=1, round=0, base=fp)
    assert coord.handout_cache.encodes == n
    l2 = coord.issue(cid=1, uid=2, round=0, base=fp)
    assert coord.handout_cache.encodes == n          # all hits
    assert coord.handout_cache.hits == n
    assert l1.handout_bytes == l2.handout_bytes
    np.testing.assert_array_equal(np.asarray(l1.base.buf),
                                  np.asarray(l2.base.buf))
    coord.drop(l1), coord.drop(l2)


def test_handout_frames_byte_identical_under_random_schedule():
    """Random mutate/handout/drop/restore interleavings: every chunk
    frame the coordinator would ship equals the oracle per-client
    encode, and every handed-out base equals the server params exactly
    (including the full re-download after a checkpoint restore)."""
    from repro.checkpoint import CheckpointManager

    for seed in (1, 7, 42):
        rng = np.random.default_rng(seed)
        fp = _params(seed)
        n = fp.spec.n_shards
        coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
        mgr = CheckpointManager(tempfile.mkdtemp(prefix="handout_t_"),
                                async_save=False)
        uid, rnd, saved = 0, 0, False
        for _ in range(50):
            op = rng.random()
            if op < 0.45:                            # handout
                uid += 1
                lease = coord.issue(cid=uid % 4, uid=uid, round=rnd,
                                    base=coord.state.params)
                np.testing.assert_array_equal(
                    np.asarray(lease.base.buf),
                    np.asarray(coord.state.params.buf))
                for i, oracle in enumerate(_oracle_frames(coord, rnd)):
                    frame, _ = coord._chunk_frame(i, rnd)
                    assert frame == oracle
                if rng.random() < 0.5:               # mutate: fold it in
                    coord.submit(lease, lease.base.buf + 0.25)
                    coord.assimilate(lease, coord.deliver(lease),
                                     server_version=coord.state.version)
                else:                                # wasted work
                    coord.drop(lease)
            elif op < 0.6:
                rnd += 1
            elif op < 0.75 or not saved:             # checkpoint
                coord.save_checkpoint(mgr, step=rnd + 1)
                saved = True
            else:                                    # restore: rounds rewind
                coord.restore_checkpoint(mgr)
                rnd = 0
                uid += 1                             # full re-download
                lease = coord.issue(cid=uid % 4, uid=uid, round=rnd,
                                    base=coord.state.params)
                assert lease.handout_frames == n
                np.testing.assert_array_equal(
                    np.asarray(lease.base.buf),
                    np.asarray(coord.state.params.buf))
                coord.drop(lease)
        assert coord.handout_cache.hits > 0          # the cache did work


def test_cache_bounded_across_rounds():
    fp = _params()
    n = fp.spec.n_shards
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    cache = coord.handout_cache
    for rnd in range(12):
        for cid in range(3):
            uid = rnd * 3 + cid + 1
            lease = coord.issue(cid=cid, uid=uid, round=rnd,
                                base=coord.state.params)
            if cid == 0:
                coord.submit(lease, lease.base.buf + 0.1)
                coord.assimilate(lease, coord.deliver(lease),
                                 server_version=coord.state.version)
            else:
                coord.drop(lease)
        assert cache.frames_held <= n * cache.keep_rounds
    assert cache.watermark == 12 - cache.keep_rounds
    assert cache.evicted > 0


# ---------------------------------------------------------------------------
# bf16 download frames: f32 masters, bf16-exact reconstruction, half bytes
# ---------------------------------------------------------------------------

def test_bf16_handout_reconstruction_is_bf16_exact():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9,
                        handout_dtype="bf16")
    assert coord.handout_dtype == "bfloat16"         # alias normalized
    lease = coord.issue(cid=0, uid=1, round=0, base=fp)
    want = np.asarray(fp.buf).astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(lease.base.buf), want)
    # fold a result so some (not all) chunks change, then re-download:
    # unchanged chunks come from the held copy, changed ones from bf16
    # frames — BOTH must equal the bf16 image of the f32 master
    coord.submit(lease, lease.base.buf + 0.125)
    coord.assimilate(lease, coord.deliver(lease),
                     server_version=coord.state.version)
    l2 = coord.issue(cid=0, uid=2, round=1, base=coord.state.params)
    want2 = (np.asarray(coord.state.params.buf)
             .astype(jnp.bfloat16).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(l2.base.buf), want2)
    coord.drop(l2)


def test_bf16_halves_handout_bytes():
    fp = _params()
    sl, n = fp.spec.shard_len, fp.spec.n_shards
    f32 = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    b16 = Coordinator(VCASGD(0.9), fp, timeout_s=1e9,
                      handout_dtype="bfloat16")
    a = f32.issue(cid=0, uid=1, round=0, base=fp)
    b = b16.issue(cid=0, uid=1, round=0, base=fp)
    assert a.handout_bytes == n * wire.shard_frame_bytes(sl)
    assert b.handout_bytes == n * wire.shard_frame_bytes(sl, "bfloat16")
    assert b.handout_bytes < 0.55 * a.handout_bytes
    f32.drop(a), b16.drop(b)


def test_bad_handout_dtype_rejected():
    fp = _params()
    with pytest.raises(ValueError):
        Coordinator(VCASGD(0.9), fp, handout_dtype="int8")


# ---------------------------------------------------------------------------
# HandoutService: the read-only subscriber layer
# ---------------------------------------------------------------------------

def test_service_fresh_then_caught_up_then_delta():
    fp = _params()
    n = fp.spec.n_shards
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    svc = HandoutService(coord)
    s1 = svc.pull(0, coord.state.params, round=0)
    assert s1.fresh and s1.frames == n               # full first download
    s2 = svc.pull(0, coord.state.params, round=0)
    assert s2.frames == 0 and s2.bytes == 0          # caught up: free
    # fold one result -> only the touched chunks re-ship
    lease = coord.issue(cid=0, uid=1, round=0, base=coord.state.params)
    nudged = np.asarray(lease.base.buf).copy()
    lo, hi = fp.spec.shard_bounds(2)
    nudged[lo:hi] += 1.0
    coord.submit(lease, nudged)
    coord.assimilate(lease, coord.deliver(lease),
                     server_version=coord.state.version)
    s3 = svc.pull(0, coord.state.params, round=1)
    assert 1 <= s3.frames < n
    # a brand-new subscriber rides entirely on cached frames when a
    # same-round reader already paid the encodes
    before = coord.handout_cache.encodes
    s4 = svc.pull(1, coord.state.params, round=1)
    assert s4.frames == n
    # chunks served to sub 0 at round 1 are cached; the rest encode once
    assert coord.handout_cache.encodes == before + (n - s3.frames)
    assert svc.subscribers == 2
    svc.drop_subscriber(0)
    assert svc.subscribers == 1
    s5 = svc.pull(0, coord.state.params, round=1)    # dropped: full again
    assert s5.fresh and s5.frames == n


def test_service_dense_single_chunk_delta():
    fp = _params(n_shards=1)
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    svc = HandoutService(coord)
    s1 = svc.pull(0, coord.state.params, round=0)
    assert s1.frames == 1
    # the dense bus is ONE chunk in the ledger: an unchanged model is a
    # zero-frame pull even without sharding (clients still always get
    # the full dense frame — that behavior is pinned elsewhere)
    s2 = svc.pull(0, coord.state.params, round=0)
    assert s2.frames == 0


def test_service_version_vectors_share_storage():
    """1M subscribers must not mean 1M vector copies: caught-up
    subscribers hold REFERENCES to the coordinator's copy-on-write
    version vector."""
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=1e9)
    svc = HandoutService(coord)
    for s in range(64):
        svc.pull(s, coord.state.params, round=0)
    ids = {id(v) for v in svc._sub_vec.values()}
    assert len(ids) == 1


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------

def _smoke_run(**overrides):
    from repro.scenarios.registry import get

    return get("handout_smoke").run(**overrides)


@pytest.mark.slow
def test_subscribers_leave_trainer_trace_invariant():
    """Read-only subscribers may not move a single float of training:
    same config with subscribers on vs off produces the identical
    trainer fingerprint (only event/serving counters differ)."""
    off = _smoke_run(subscribers=0)
    on = _smoke_run(subscribers=50)
    for field in ("final_accuracy", "wall_time_s", "epochs_done",
                  "results_assimilated", "preemptions", "reassignments",
                  "handout_frames", "handout_bytes"):
        assert getattr(on, field) == getattr(off, field), field
    assert on.sub_pulls > 0 and off.sub_pulls == 0


@pytest.mark.slow
def test_subscriber_scenario_dedups_and_reports_latency():
    from repro.scenarios.registry import get

    sc = get("handout_smoke")
    cfg = sc.config()
    res = sc.run()
    assert res.subscribers == cfg.subscribers
    assert res.sub_pulls > cfg.subscribers           # pulls recur
    assert res.handout_dedup_ratio > 10.0
    assert res.handout_bytes_served > res.handout_unique_bytes_encoded
    assert 0.0 < res.sub_latency_p50_s <= res.sub_latency_p99_s


@pytest.mark.slow
def test_bf16_halves_served_bytes_in_sim():
    f32 = _smoke_run(max_epochs=1)
    b16 = _smoke_run(max_epochs=1, handout_dtype="bfloat16")
    assert b16.sub_bytes_served < 0.55 * f32.sub_bytes_served
    assert b16.handout_bytes < 0.55 * f32.handout_bytes


def test_pinned_cases_do_not_serialize_serving_fields():
    """The pinned regression stays byte-identical BY CONSTRUCTION: the
    fixture serializes a fixed field list that the serving counters are
    not part of (and subscribers default to 0)."""
    pinned = json.loads(
        (Path(__file__).resolve().parents[1] / "results" /
         "PINNED_sim_regression.json").read_text())
    case = next(iter(pinned["cases"].values()))
    assert "sub_pulls" not in case
    assert "handout_dedup_ratio" not in case
