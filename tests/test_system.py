"""End-to-end behaviour tests: the whole paper pipeline at laptop scale —
work generation -> scheduling -> client training -> VC-ASGD assimilation ->
epoch rollover -> checkpoint/restart."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.baselines import VCASGD
from repro.core.simulator import SimConfig, run_simulation
from repro.core.tasks import MLPTask, make_classification_data
from repro.core.vc_asgd import var_alpha
from repro.launch.mesh import compat_make_mesh


def test_full_system_with_everything_on(tmp_path):
    """Preemptible + eventual consistency + var-alpha + heterogeneous fleet:
    the paper's full configuration, end to end."""
    task = MLPTask()
    data = make_classification_data(n_train=2000, n_val=500)
    cfg = SimConfig(n_param_servers=3, n_clients=5, tasks_per_client=2,
                    n_shards=10, max_epochs=4, local_steps=2,
                    preemptible=True, mean_lifetime_s=1500.0,
                    consistency="eventual", subtask_compute_s=150.0, seed=7)
    res = run_simulation(task, data, VCASGD(var_alpha()), cfg)
    assert res.epochs_done == 4
    assert res.final_accuracy > 0.25
    assert res.results_assimilated >= 40          # every shard, every epoch


def test_checkpoint_restart_mid_training(tmp_path):
    """Kill-and-resume: server params checkpointed after round r restore
    bit-exactly and training continues."""
    from repro.configs import get_reduced
    from repro.models.registry import build_model
    from repro.optim import Adam
    from repro.runtime.sharding import MeshPlan
    from repro.runtime.vc_runtime import make_vc_round

    cfg = get_reduced("internlm2-1.8b")
    model = build_model(cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    plan = MeshPlan.build(cfg, mesh)
    opt = Adam(lr=1e-3)
    vc = make_vc_round(model, plan, 2, 1, opt)
    key = jax.random.PRNGKey(0)
    mgr = CheckpointManager(tmp_path, async_save=False)

    with mesh:
        server = model.init(key)
        islands = jax.tree.map(lambda s: jnp.stack([s, s]), server)
        opts = jax.vmap(opt.init)(islands)
        toks = jax.random.randint(key, (2, 1, 2, 32), 0, cfg.vocab_size)
        for rnd in range(2):
            server, islands, opts, _ = vc(server, islands, opts,
                                          {"tokens": toks},
                                          jnp.asarray(0.7, jnp.float32),
                                          jnp.ones((2,), bool))
            mgr.save(rnd + 1, server, {"round": rnd + 1})

        # crash; restore
        restored, extra, step = mgr.restore_or_init(server, lambda: None)
        assert step == 2 and extra["round"] == 2
        for a, b in zip(jax.tree.leaves(server), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        # and training continues from the restored copy
        server2, _, _, m = vc(restored, islands, opts, {"tokens": toks},
                              jnp.asarray(0.75, jnp.float32),
                              jnp.ones((2,), bool))
        assert np.isfinite(float(m["loss"]))
