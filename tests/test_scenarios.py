"""Fleet-scale PR tests: the version-vector delta ledger against a
byte-map oracle (the OLD per-client held-bytes algorithm), the
PendingQueue against the old sorted-list selection, event-ordering
determinism, eval-stride memory hygiene, and the scenario registry."""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flat as F
from repro.core.baselines import VCASGD
from repro.core.preemption import (CorrelatedReclaimModel, DiurnalChurnModel,
                                   PAPER_FLEET, PreemptionModel,
                                   SpotPricePreemption, make_fleet)
from repro.core.simulator import SimConfig, run_simulation
from repro.core.work_generator import PendingQueue, WorkUnit
from repro.protocol import Coordinator
from repro.scenarios.probe import ProbeTask, make_probe_data
from repro.scenarios.registry import SCENARIOS, get
from repro.transfer import wire
from repro.transfer.transport import LoopbackTransport


# ---------------------------------------------------------------------------
# version-vector ledger vs the old per-client byte-map ledger
# ---------------------------------------------------------------------------

class ByteMapOracle:
    """The pre-PR delta-handout algorithm, verbatim: one full byte copy
    per client, per-shard np.array_equal against it on every handout."""

    def __init__(self):
        self.held = {}

    def handout(self, cid, buf, spec):
        prev = self.held.get(cid)
        sent = []
        for i in range(spec.n_shards):
            lo, hi = spec.shard_bounds(i)
            if prev is not None and np.array_equal(buf[lo:hi], prev[lo:hi]):
                continue
            sent.append((i, buf[lo:hi].tobytes()))
        held = prev.copy() if prev is not None else np.zeros_like(buf)
        for i, _ in sent:
            lo, hi = spec.shard_bounds(i)
            held[lo:hi] = buf[lo:hi]
        self.held[cid] = held
        return sent

    def drop(self, cid):
        self.held.pop(cid, None)

    def restore(self):
        self.held.clear()


class RecordingTransport(LoopbackTransport):
    """Captures every sent frame so tests can decode what went on the
    wire (the handout leg is the only sender in these tests)."""

    def __init__(self):
        super().__init__()
        self.sent_frames = []

    def send(self, frame):
        self.sent_frames.append(bytes(frame))
        return super().send(frame)


def _mk_bus(n_shards, fill=0.0):
    tree = {"w": np.full((n_shards * 8,), fill, np.float32)}
    return F.flatten_sharded(tree, n_shards)


def _mutate(fp, shard_ids, stamp):
    """Fresh params: write a NEVER-REPEATING stamp into the given shards
    (monotone-distinct content — float training never reverts bytes, and
    the version ledger's over-send-on-revert is deliberately out of
    contract)."""
    spec = fp.spec
    buf = np.asarray(fp.buf).copy()
    for s in shard_ids:
        lo, hi = spec.shard_bounds(s)
        buf[lo:hi] = float(stamp) + s * 0.001
    import jax.numpy as jnp
    return F.FlatParams(jnp.asarray(buf), spec)


def _run_schedule(n_shards, schedule):
    """Drive a real Coordinator and the byte-map oracle through the same
    handout/drop schedule; compare the wire frames frame-for-frame."""
    fp = _mk_bus(n_shards)
    transport = RecordingTransport()
    coord = Coordinator(VCASGD(0.95), fp, transport=transport)
    oracle = ByteMapOracle()
    uid = 0
    stamp = 1
    for op, arg in schedule:
        if op == "mutate":
            fp = _mutate(fp, arg, stamp)
            stamp += 1
        elif op == "drop":
            coord.drop_client(arg)
            oracle.drop(arg)
        elif op == "handout":
            cid = arg
            n_before = len(transport.sent_frames)
            lease = coord.issue(cid=cid, uid=uid, round=0, base=fp)
            uid += 1
            got = []
            for fr in transport.sent_frames[n_before:]:
                msg = wire.decode(fr)
                assert msg.kind == wire.KIND_SHARD
                got.append((msg.shard,
                            np.asarray(msg.payload).tobytes()))
            want = oracle.handout(cid, np.asarray(fp.buf), fp.spec)
            assert got == want, (
                f"frame mismatch for cid {cid}: sent shards "
                f"{[s for s, _ in got]} vs oracle {[s for s, _ in want]}")
            # the reconstructed base must be the full current bus
            assert np.array_equal(np.asarray(lease.base.buf),
                                  np.asarray(fp.buf))
            coord.drop(lease)       # keep the lease registry from growing
    return coord, oracle


def test_version_vector_matches_byte_map_deterministic():
    n_shards = 6
    schedule = [
        ("handout", 0),                 # fresh: all 6 shards
        ("handout", 0),                 # unchanged: 0 frames
        ("mutate", [2, 4]), ("handout", 0),     # delta: shards 2,4
        ("handout", 1),                 # fresh client: all 6
        ("mutate", [0]), ("handout", 1),        # delta: shard 0
        ("handout", 0),                 # client 0 missed the [0] write too
        ("drop", 0), ("handout", 0),    # preempted: full re-download
        ("mutate", [1, 2, 3]), ("handout", 1),
        ("drop", 1), ("mutate", [5]), ("handout", 1),
    ]
    _run_schedule(n_shards, schedule)


def test_version_vector_full_redownload_after_restore(tmp_path):
    from repro.checkpoint import CheckpointManager

    fp = _mk_bus(4)
    transport = RecordingTransport()
    coord = Coordinator(VCASGD(0.95), fp, transport=transport)
    l0 = coord.issue(cid=0, uid=0, round=0, base=fp)
    assert l0.handout_frames == 4           # fresh: full download
    coord.drop(l0)
    l1 = coord.issue(cid=0, uid=1, round=0, base=fp)
    assert l1.handout_frames == 0           # caught up
    coord.drop(l1)
    mgr = CheckpointManager(tmp_path, async_save=False)
    coord.save_checkpoint(mgr, step=1)
    assert coord.restore_checkpoint(mgr) == 1
    l2 = coord.issue(cid=0, uid=2, round=0, base=fp)
    assert l2.handout_frames == 4           # restore forgets client vectors


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_version_vector_matches_byte_map_property(data):
    n_shards = data.draw(st.integers(min_value=2, max_value=8))
    n_clients = data.draw(st.integers(min_value=1, max_value=4))
    ops = data.draw(st.lists(st.tuples(
        st.sampled_from(["handout", "mutate", "drop"]),
        st.integers(min_value=0, max_value=9)), min_size=1, max_size=40))
    schedule = []
    for op, x in ops:
        if op == "handout" or op == "drop":
            schedule.append((op, x % n_clients))
        else:
            shards = [x % n_shards, (x * 7 + 1) % n_shards]
            schedule.append((op, sorted(set(shards))))
    _run_schedule(n_shards, schedule)


# ---------------------------------------------------------------------------
# PendingQueue vs the old sorted-list selection
# ---------------------------------------------------------------------------

def _unit(uid, shard, epoch=1):
    return WorkUnit(uid=uid, epoch=epoch, shard=shard, param_version=-1)


def test_pending_queue_matches_sorted_oracle_deterministic():
    rng = np.random.default_rng(0)
    q = PendingQueue()
    shadow = []
    uid = 0
    for _ in range(300):
        op = rng.integers(3)
        if op == 0 or not shadow:
            u = _unit(uid, int(rng.integers(8)))
            uid += 1
            q.append(u)
            shadow.append(u)
        elif op == 1:
            cache = set(int(s) for s in
                        rng.choice(8, size=int(rng.integers(4)),
                                   replace=False))
            k = int(rng.integers(1, 4))
            want = sorted(shadow,
                          key=lambda u: (u.shard not in cache, u.uid))[:k]
            got = q.select(cache, k)
            assert [u.uid for u in got] == [u.uid for u in want]
            for u in want:
                shadow.remove(u)
        else:
            u = shadow.pop(int(rng.integers(len(shadow))))
            q.remove(u)
        assert len(q) == len(shadow)
        assert sorted(u.uid for u in q) == sorted(u.uid for u in shadow)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7),
                          st.integers(1, 3)), min_size=1, max_size=60))
def test_pending_queue_matches_sorted_oracle_property(ops):
    q = PendingQueue()
    shadow = []
    uid = 0
    for op, shard, k in ops:
        if op == 0 or not shadow:
            u = _unit(uid, shard)
            uid += 1
            q.append(u)
            shadow.append(u)
        elif op == 1:
            cache = {shard, (shard + 3) % 8}
            want = sorted(shadow,
                          key=lambda u: (u.shard not in cache, u.uid))[:k]
            got = q.select(cache, k)
            assert [u.uid for u in got] == [u.uid for u in want]
            for u in want:
                shadow.remove(u)
        else:
            u = shadow.pop(shard % len(shadow))
            q.remove(u)


# ---------------------------------------------------------------------------
# event loop: determinism, eval stride, sharded bus in-sim
# ---------------------------------------------------------------------------

def _small_cfg(**kw):
    base = dict(n_param_servers=2, n_clients=60, tasks_per_client=1,
                n_shards=120, max_epochs=1, local_steps=1,
                timeout_s=1800.0, preemptible=True, mean_lifetime_s=3600.0,
                restart_delay_s=60.0, subtask_compute_s=60.0,
                server_proc_s=0.05, seed=3)
    base.update(kw)
    return SimConfig(**base)


def _fingerprint(res):
    return (res.wall_time_s, res.results_assimilated, res.preemptions,
            res.reassignments, res.final_accuracy,
            int(res.wire.bytes_sent), int(res.handout_bytes),
            res.events_processed)


def _run(cfg):
    task = ProbeTask()
    data = make_probe_data(cfg.n_shards, seed=cfg.seed)
    return run_simulation(task, data, VCASGD(0.95), cfg)


def test_same_seed_same_trace():
    a, b = _run(_small_cfg()), _run(_small_cfg())
    assert _fingerprint(a) == _fingerprint(b)
    assert a.events_processed > 0


def test_eval_stride_changes_only_eval_sampling():
    full = _run(_small_cfg())
    strided = _run(_small_cfg(eval_stride=8))
    # the virtual clock, wire traffic, and churn are eval-independent
    assert strided.wall_time_s == full.wall_time_s
    assert strided.results_assimilated == full.results_assimilated
    assert int(strided.wire.bytes_sent) == int(full.wire.bytes_sent)
    assert strided.preemptions == full.preemptions
    assert strided.events_processed == full.events_processed
    # the final (unconditional) evaluation is identical
    assert strided.final_accuracy == full.final_accuracy


def test_sharded_bus_runs_delta_ledger_in_sim():
    dense = _run(_small_cfg(preemptible=False))
    sharded = _run(_small_cfg(preemptible=False, bus_shards=4))
    # same virtual-time behaviour class, but per-shard delta frames:
    # later handouts skip unchanged shards, so frame count per handout
    # drops below bus_shards on average
    assert sharded.results_assimilated == dense.results_assimilated
    assert sharded.handout_frames > 0
    n_handouts = sharded.handout_frames  # frames, not handouts; bound it:
    assert n_handouts < 4 * (sharded.results_assimilated + 60)


# ---------------------------------------------------------------------------
# preemption models + registry
# ---------------------------------------------------------------------------

def test_lifetime_end_base_matches_sample_lifetime():
    m = PreemptionModel(mean_lifetime_s=500.0)
    r1, r2 = np.random.default_rng(9), np.random.default_rng(9)
    assert m.lifetime_end(r1, 10.0) == 10.0 + m.sample_lifetime(r2)
    off = PreemptionModel(enabled=False)
    assert m.lifetime_end(np.random.default_rng(0), 0.0) < float("inf")
    assert off.lifetime_end(np.random.default_rng(0), 0.0) == float("inf")


def test_correlated_reclaim_kills_whole_az_together():
    m = CorrelatedReclaimModel(mean_lifetime_s=1e12, n_az=2,
                               az_reclaim_interval_s=3600.0, reclaim_seed=1)
    fleet = make_fleet(6, seed=1, preemption=m, n_az=2)
    ends = {}
    for c in fleet:
        ends.setdefault(c.az, set()).add(m.lifetime_end(c.rng, 0.0, c))
    # individual lifetimes are ~inf, so every client in an AZ dies at
    # the AZ's first reclaim time
    assert all(len(v) == 1 for v in ends.values())
    assert ends[0] != ends[1]


def test_spot_price_is_deterministic_and_az_correlated():
    m = SpotPricePreemption(n_az=2, bid=0.9, price_seed=3)
    fleet = make_fleet(4, seed=2, preemption=m, n_az=2)
    e0 = m.lifetime_end(fleet[0].rng, 0.0, fleet[0])
    e2 = m.lifetime_end(fleet[2].rng, 0.0, fleet[2])
    assert e0 == e2                     # same AZ -> same crossing
    later = m.lifetime_end(fleet[0].rng, e0 + 1.0, fleet[0])
    assert later > e0                   # strictly the NEXT crossing


def test_diurnal_lifetimes_monotone_in_hazard_draw():
    m = DiurnalChurnModel(mean_lifetime_s=3600.0, n_regions=2)
    fleet = make_fleet(2, seed=5, preemption=m, n_az=2)
    e = m.lifetime_end(np.random.default_rng(1), 0.0, fleet[0])
    assert 0.0 < e < float("inf")


def test_tiered_fleet_keeps_default_rng_stream():
    f_plain = make_fleet(8, seed=6)
    tiers = [(PAPER_FLEET[0], 0.5), (PAPER_FLEET[3], 0.5)]
    f_tier = make_fleet(8, seed=6, tiers=tiers, n_az=2)
    for a, b in zip(f_plain, f_tier):
        assert a.rng.integers(2 ** 32) == b.rng.integers(2 ** 32)
    assert {c.az for c in f_tier} == {0, 1}


def test_registry_scenarios_resolve_and_smoke_runs():
    for name in ("fleet_smoke", "fleet_1k", "fleet_10k", "fleet_100k",
                 "az_reclaim", "spot_price", "diurnal", "tiered"):
        assert get(name).name == name
    with pytest.raises(KeyError):
        get("nope")
    res = get("fleet_smoke").run()
    assert res.results_assimilated == 400
    assert res.events_processed > 0


def test_behaviour_scenarios_run_small():
    """Each fleet_fn drives an actual (tiny) simulation end to end; the
    az_reclaim variant keeps the sharded bus so the thundering-herd
    re-downloads go through the version-vector ledger."""
    from repro.scenarios import registry as R

    for fleet_fn, extra in ((R._az_reclaim_fleet, {"bus_shards": 4}),
                            (R._spot_price_fleet, {}),
                            (R._diurnal_fleet, {}),
                            (R._tiered_fleet, {})):
        cfg = _small_cfg(n_clients=40, n_shards=80, fleet_fn=fleet_fn,
                         **extra)
        res = _run(cfg)
        assert res.results_assimilated == 80
        assert res.final_accuracy > 0.0
