"""Hypothesis import guard (ISSUE 1 satellite): the property tests skip
cleanly where `hypothesis` is absent, while the deterministic tests in the
same files keep running — a fallback instead of a module-level
``pytest.importorskip`` (which would skip the whole file).

Usage in test modules:

    from _hyp import given, settings, st

With hypothesis installed this is a passthrough.  Without it, ``@given``
replaces the test with a skip, and ``st.*`` return inert placeholders so
module-level strategy expressions still evaluate.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _InertStrategies:
        """st.floats(...), st.integers(...), ... evaluate to None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _InertStrategies()
