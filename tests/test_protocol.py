"""The typed VC protocol (repro.protocol): lease lifecycle, Coordinator
bookkeeping, the pinned pre-redesign bit-identity contract, and a full VC
round over ``ProcessTransport`` — real frames across a real OS process
boundary.

Three guarantees anchor the redesign:

1. **Bit identity** — every scheme driven through the Coordinator
   reproduces the pre-redesign simulator EXACTLY (pinned fixture,
   results/PINNED_sim_regression.json).
2. **Exactly once** — a lease is consumed by exactly one of
   assimilate/expire/drop; a timed-out-and-reassigned result can never be
   assimilated twice.
3. **No leaks** — every terminal transition releases the lease's
   reconstruction-base ref, and drop_client releases the client's
   residual; live-buffer counts stay bounded over random preemption
   schedules.
"""
import json
import os
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flat as F
from repro.core.baselines import (CompressedVCASGD, DCASGD, Downpour,
                                  EASGDFlatPod, EASGDPersistent, VCASGD)
from repro.protocol import (LEASE_ASSIMILATED, LEASE_EXPIRED,
                            LEASE_IN_FLIGHT, LEASE_ISSUED, Coordinator,
                            LeaseError, SchemeState)
from repro.transfer import wire
from repro.transfer.transport import ProcessTransport, TransportError

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import pin_sim_regression as PIN  # noqa: E402  (the single case registry)


def _params(seed=0, shape=(64, 32)):
    return F.flatten({"w": jax.random.normal(jax.random.PRNGKey(seed),
                                             shape)})


# ---------------------------------------------------------------------------
# pinned bit-identity regression: the redesign may not move a single float
# ---------------------------------------------------------------------------

def test_pinned_regression_bit_identical():
    """Every scheme, driven through the Lease/Coordinator API, reproduces
    the committed pre-redesign results EXACTLY — wall clock, accuracy
    trace, wire bytes, store/scheduler counters, all of it."""
    pinned = json.loads(
        (Path(__file__).resolve().parents[1] / "results" /
         "PINNED_sim_regression.json").read_text())
    task = PIN.MLPTask()
    d = pinned["data"]
    data = PIN.make_classification_data(n_train=d["n_train"],
                                        n_val=d["n_val"], seed=d["seed"])
    assert set(pinned["cases"]) == set(PIN.CASES)
    for name in PIN.CASES:
        got = PIN.run_case(task, data, name)
        want = pinned["cases"][name]
        mismatches = {k: (got[k], want[k]) for k in want if got[k] != want[k]}
        assert not mismatches, f"{name}: {mismatches}"


def test_pinned_tier_matches_flat_twin():
    """The aggregation-tier contract inside the pinned fixture itself:
    the 2-level run's whole accuracy trace is bit-identical to its flat
    twin (one strong PS, matching weights) — asserted case against case,
    not just case against fixture."""
    pinned = json.loads(
        (Path(__file__).resolve().parents[1] / "results" /
         "PINNED_sim_regression.json").read_text())
    flat = pinned["cases"]["tier-flat-twin"]
    tier = pinned["cases"]["tier-2level"]
    # final params (hence final eval) are bitwise equal; the mid-run
    # accuracy TRACES legitimately differ — the hub only observes
    # parameters at flush commits, the flat server at every result
    assert tier["final_accuracy"] == flat["final_accuracy"]
    assert tier["results_assimilated"] == flat["results_assimilated"]
    # and the tier really ran as a tier: merged upstream frames only
    assert tier["aggregators"] == 1
    assert tier["wire_agg_frames"] == tier["agg_flushes"] >= 1
    assert tier["wire_frames_sent"] < flat["wire_frames_sent"]


# ---------------------------------------------------------------------------
# lease lifecycle: exactly-once + release guarantees
# ---------------------------------------------------------------------------

def test_lease_lifecycle_happy_path():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=100.0)
    lease = coord.issue(cid=0, uid=1, round=1, shard=3, read_version=0,
                        base=fp, now=5.0)
    assert lease.status == LEASE_ISSUED
    assert lease.deadline == 105.0                 # now + timeout_s
    assert coord.in_flight == 1
    # the DOWNLOAD leg shipped real bytes and the base is the DECODED copy
    assert lease.handout_frames == 1
    assert lease.handout_bytes == wire.dense_frame_bytes(fp.spec.padded)
    np.testing.assert_array_equal(np.asarray(lease.base.buf),
                                  np.asarray(fp.buf))
    coord.submit(lease, fp.buf + 0.5)
    assert lease.status == LEASE_IN_FLIGHT
    assert lease.frame_bytes == wire.dense_frame_bytes(fp.spec.padded)
    payload = coord.deliver(lease)
    state = coord.assimilate(lease, payload, server_version=0)
    assert lease.status == LEASE_ASSIMILATED and lease.released
    assert coord.in_flight == 0 and coord.assimilated == 1
    assert state.version == 1
    n = fp.spec.n                              # padding tail stays zero
    np.testing.assert_allclose(
        np.asarray(state.params.buf[:n]),
        np.asarray(0.9 * fp.buf[:n] + 0.1 * (fp.buf[:n] + 0.5)), rtol=1e-5, atol=1e-6)


def test_lease_never_assimilated_twice():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp)
    coord.submit(lease, fp.buf + 1.0)
    payload = coord.deliver(lease)
    coord.assimilate(lease, payload, server_version=0)
    with pytest.raises(LeaseError):
        coord.assimilate(lease, payload, server_version=0)


def test_timed_out_and_reassigned_lease_cannot_assimilate():
    """The BOINC double: a unit times out mid-flight, is reassigned under
    a new lease, and THEN the stale result arrives.  The stale lease was
    consumed by expire() — assimilating it raises, and only the fresh
    lease's result lands."""
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=10.0)
    stale = coord.issue(cid=0, uid=1, round=0, base=fp, now=0.0)
    coord.submit(stale, fp.buf + 1.0)
    expired = coord.expire(now=20.0)
    assert expired == [stale] and stale.status == LEASE_EXPIRED
    assert stale.released and coord.transport.in_flight == 0  # frame dropped
    # reassignment: same shard, NEW uid, new lease
    fresh = coord.issue(cid=1, uid=2, round=0, base=fp, now=20.0)
    coord.submit(fresh, fp.buf + 2.0)
    with pytest.raises(LeaseError):
        coord.assimilate(stale, fp.buf + 1.0, server_version=0)
    state = coord.assimilate(fresh, coord.deliver(fresh), server_version=0)
    assert coord.assimilated == 1 and state.version == 1
    n = fp.spec.n                              # padding tail stays zero
    np.testing.assert_allclose(
        np.asarray(state.params.buf[:n]),
        np.asarray(0.9 * fp.buf[:n] + 0.1 * (fp.buf[:n] + 2.0)), rtol=1e-5, atol=1e-6)


def test_duplicate_issue_rejected():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp)
    coord.issue(cid=0, uid=1, round=0, base=fp)
    with pytest.raises(LeaseError):
        coord.issue(cid=0, uid=1, round=0, base=fp)


def test_renew_extends_deadline():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=10.0)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp, now=0.0)
    coord.renew(lease, deadline=50.0)
    assert coord.expire(now=20.0) == []            # renewed past the timeout
    assert coord.expire(now=60.0) == [lease]
    with pytest.raises(LeaseError):                # terminal leases can't renew
        coord.renew(lease, deadline=99.0)


def test_submit_after_expiry_rejected():
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=10.0)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp, now=0.0)
    coord.expire(now=20.0)
    with pytest.raises(LeaseError):
        coord.submit(lease, fp.buf)


def _random_preemption_run(seed: int, steps: int = 120):
    """Drive a compressed coordinator through a random schedule of
    issue/submit/assimilate/drop/expire/drop_client and check the no-leak
    invariants after every step."""
    rng = np.random.default_rng(seed)
    fp = _params(seed)
    coord = Coordinator(CompressedVCASGD(0.9, density=0.1), fp,
                        timeout_s=30.0)
    uid, now, version = 0, 0.0, 0
    live = []                                  # leases we still hold

    def pick(status=None):
        cand = [l for l in live if status is None or l.status == status]
        return cand[int(rng.integers(0, len(cand)))] if cand else None

    for _ in range(steps):
        now += float(rng.exponential(4.0))
        op = rng.integers(0, 6)
        if op == 0 or not live:                # issue (to a random client)
            lease = coord.issue(cid=int(rng.integers(0, 4)), uid=uid,
                                round=0, base=fp, now=now)
            uid += 1
            live.append(lease)
        elif op == 1:                          # client uploads (stays live)
            lease = pick(LEASE_ISSUED)
            if lease is not None:
                coord.submit(lease, fp.buf + float(rng.standard_normal()))
        elif op == 2:                          # delivery + assimilation
            lease = pick(LEASE_IN_FLIGHT)
            if lease is not None:
                payload = coord.deliver(lease)
                coord.assimilate(lease, payload, server_version=version)
                version += 1
                live.remove(lease)
        elif op == 3:                          # result discarded in flight
            lease = pick()
            if lease is not None:
                coord.drop(lease)
                live.remove(lease)
        elif op == 4:                          # client preempted
            coord.drop_client(int(rng.integers(0, 4)))
            live = [l for l in live if not l.terminal]
        else:                                  # deadline sweep
            coord.expire(now)
            live = [l for l in live if not l.terminal]
        # ---- invariants: nothing leaks, ever --------------------------
        # terminated leases never linger in the registry...
        assert len(coord.leases) == len(live)
        # ...live leases keep their base ref, terminal ones released it
        for lease in live:
            assert not lease.released
        assert coord.transport.in_flight == \
            sum(1 for l in live if l.status == LEASE_IN_FLIGHT)
        assert len(coord._residuals) <= 4      # bounded by fleet size
        assert coord.residual_mass() == pytest.approx(
            sum(coord._res_norms.values()))
    # total drain: every client preempted -> all buffers released
    for cid in range(4):
        coord.drop_client(cid)
    assert coord.leases == {} and coord._residuals == {}
    assert coord.residual_mass() == pytest.approx(0.0)
    assert coord.transport.in_flight == 0
    stats = coord.wire_stats
    assert stats.frames_sent == stats.frames_recv + stats.frames_dropped


def test_random_preemption_no_leaks_deterministic():
    for seed in range(3):
        _random_preemption_run(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_random_preemption_no_leaks(seed):
    _random_preemption_run(seed, steps=60)


def test_drop_client_releases_residual_o1():
    """Residual-norm totals are RUNNING sums (updated at submit/drop),
    not scans: check they track exactly across submits and drops."""
    fp = _params()
    coord = Coordinator(CompressedVCASGD(0.9, density=0.1), fp)
    for cid in range(3):
        lease = coord.issue(cid=cid, uid=cid, round=0, base=fp)
        coord.submit(lease, fp.buf + float(cid + 1))
    norms = [coord.residual_norm(c) for c in range(3)]
    assert all(n > 0 for n in norms)
    assert coord.residual_mass() == pytest.approx(sum(norms))
    coord.drop_client(1)
    assert coord.residual_norm(1) == 0.0
    assert coord.residual_mass() == pytest.approx(norms[0] + norms[2])


# ---------------------------------------------------------------------------
# typed states + checkpoint hooks
# ---------------------------------------------------------------------------

def test_scheme_states_are_pytrees():
    fp = _params()
    for scheme in [VCASGD(0.9), Downpour(0.5), DCASGD(0.5, lam=0.1),
                   EASGDPersistent(0.05), EASGDFlatPod(n_replicas=2)]:
        state = scheme.init_state(fp)
        assert isinstance(state, SchemeState)
        leaves = jax.tree.leaves(state)
        assert any(l is state.params.buf for l in leaves)
        mapped = jax.tree.map(lambda x: x, state)
        assert type(mapped) is type(state)
        np.testing.assert_array_equal(np.asarray(mapped.params.buf),
                                      np.asarray(state.params.buf))


def test_coordinator_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp)
    coord.submit(lease, fp.buf + 1.0)
    coord.assimilate(lease, coord.deliver(lease), server_version=0)
    mgr = CheckpointManager(tmp_path, async_save=False)
    coord.save_checkpoint(mgr, step=7, extra={"next_uid": 42})
    # a fresh coordinator (fresh params) resumes the durable state
    coord2 = Coordinator(VCASGD(0.9), _params(seed=99))
    assert coord2.restore_checkpoint(mgr) == 7
    assert coord2.restored_extra["next_uid"] == 42   # runtime counters ride
    assert coord2.state.version == coord.state.version == 1
    np.testing.assert_array_equal(np.asarray(coord2.state.params.buf),
                                  np.asarray(coord.state.params.buf))
    # nothing to restore -> state untouched
    coord3 = Coordinator(VCASGD(0.9), _params(seed=5))
    assert coord3.restore_checkpoint(
        CheckpointManager(tmp_path / "empty", async_save=False)) is None


def test_restore_rebuilds_scheme_local_state(tmp_path):
    """Scheme-local state is rebuilt from the RESTORED params: a resumed
    pod coordinator hands out replicas tiled from the checkpointed
    center, never from its construction-time fresh init."""
    from repro.checkpoint import CheckpointManager
    fp = _params()
    coord = Coordinator(EASGDFlatPod(n_replicas=2, beta=0.1), fp)
    mgr = CheckpointManager(tmp_path, async_save=False)
    coord.save_checkpoint(mgr, step=3)
    resumed = Coordinator(EASGDFlatPod(n_replicas=2, beta=0.1),
                          _params(seed=123))
    assert resumed.restore_checkpoint(mgr) == 3
    lease = resumed.issue(cid=0, uid=0, round=0,
                          base=resumed.state.params)
    np.testing.assert_array_equal(np.asarray(lease.base.buf),
                                  np.asarray(fp.buf))
    np.testing.assert_array_equal(np.asarray(resumed.state.replicas[1]),
                                  np.asarray(fp.buf))


# ---------------------------------------------------------------------------
# ProcessTransport: frames really cross an OS process boundary
# ---------------------------------------------------------------------------

def test_process_transport_semantics():
    with ProcessTransport() as t:
        assert t.broker_pid != os.getpid()     # a REAL second process
        frames = [wire.encode(jnp.arange(8192, dtype=jnp.float32)),
                  b"short-frame"]
        ids = [t.send(f) for f in frames]
        assert t.in_flight == 2
        assert t.recv(ids[1]) == frames[1]     # out-of-order by id
        assert t.recv(ids[0]) == frames[0]
        with pytest.raises(TransportError):
            t.recv(ids[0])                     # exactly-once delivery
        mid = t.send(frames[0])
        t.drop(mid)
        t.drop(mid)                            # idempotent
        assert t.stats.frames_dropped == 1
        assert t.stats.bytes_dropped == len(frames[0])
        assert t.in_flight == 0
        assert t.stats.bytes_sent == t.stats.bytes_recv + t.stats.bytes_dropped


def test_full_vc_round_over_process_transport():
    """A full VC round (dispatch -> train -> upload -> assimilate) with
    every payload crossing a REAL OS process boundary: results are
    bit-identical to the loopback run and byte counts equal the
    transfer/wire.py frame lengths."""
    task = PIN.MLPTask()
    data = PIN.make_classification_data(n_train=600, n_val=150, seed=0)
    cfg = PIN.SimConfig(n_param_servers=2, n_clients=3, tasks_per_client=2,
                        n_shards=6, max_epochs=1, local_steps=2,
                        subtask_compute_s=120.0, seed=3)
    loop = PIN.run_simulation(task, data, VCASGD(0.95), cfg)
    with ProcessTransport() as t:
        proc = PIN.run_simulation(task, data, VCASGD(0.95), cfg, transport=t)
        assert t.broker_pid != os.getpid()
        stats = t.stats
    padded = F.flatten(task.init_params(jax.random.PRNGKey(0))).spec.padded
    per_frame = wire.dense_frame_bytes(padded)
    assert proc.results_assimilated > 0
    # both legs crossed the broker: handout frames at issue + one upload
    # frame per result; totals are sums of the measured frame lengths
    assert proc.handout_frames > 0
    assert proc.handout_bytes == proc.handout_frames * per_frame
    uploads = stats.frames_sent - proc.handout_frames
    assert uploads == proc.results_assimilated + stats.frames_dropped
    assert stats.bytes_sent == proc.handout_bytes + uploads * per_frame
    assert stats.bytes_recv == proc.handout_bytes \
        + proc.results_assimilated * per_frame
    # the transport is invisible to the math: bit-identical to loopback
    assert proc.wall_time_s == loop.wall_time_s
    assert proc.final_accuracy == loop.final_accuracy
    assert proc.results_assimilated == loop.results_assimilated
    assert stats.bytes_sent == loop.wire.bytes_sent
