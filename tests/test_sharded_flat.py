"""ShardedFlat (core/flat.py ShardedTreeSpec + runtime/sharding.py flat
ops): layout invariants, shard-vs-whole BIT-exactness of the flat kernels
under shard_map, the vc_round flat assimilation against the retained
per-leaf oracle, and sharded one-pass train records.

The multi-device parity sweep runs in a subprocess (slow-marked, like
tests/test_sharding_multi.py) so the main test process keeps one device.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import flat as F
from repro.core import vc_asgd as V
from repro.launch.mesh import make_pod_mesh
from repro.optim import Adam
from repro.runtime import sharding as S
from repro.runtime.vc_runtime import (assimilate_flat,
                                      assimilate_islands_per_leaf,
                                      island_weights)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def mixed_tree(key):
    ks = jax.random.split(key, 4)
    return {"w": jax.random.normal(ks[0], (300, 41), jnp.float32),
            "b": (jax.random.normal(ks[1], (9,), jnp.bfloat16),
                  jnp.arange(-3, 11, dtype=jnp.int32)),
            "deep": {"m": jax.random.normal(ks[2], (2, 3, 4), jnp.float32),
                     "v": jax.random.normal(ks[3], (130,), jnp.bfloat16)}}


# ---------------------------------------------------------------------------
# layout invariants + round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 7])
def test_sharded_layout_contract(n_shards):
    tree = mixed_tree(jax.random.PRNGKey(0))
    fp = F.flatten_sharded(tree, n_shards)
    sp = fp.spec
    assert isinstance(sp, F.ShardedTreeSpec)
    assert sp.padded == n_shards * sp.shard_len
    assert sp.shard_len % F.BLOCK == 0
    assert sp.padded >= sp.n
    # same leaf packing as the single-host layout (only tail pad differs)
    base = F.tree_spec(tree)
    assert sp.offsets == base.offsets and sp.sizes == base.sizes
    assert sp.n == base.n
    np.testing.assert_array_equal(np.asarray(fp.buf[sp.n:]), 0.0)
    # round trip with dtypes preserved
    back = F.unflatten(fp)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.parametrize("n_shards", [1, 2, 5])
def test_shard_table_partitions_every_leaf_exactly_once(n_shards):
    tree = mixed_tree(jax.random.PRNGKey(1))
    sp = F.sharded_tree_spec(tree, n_shards)
    seen = {i: np.zeros(sz, bool) for i, sz in enumerate(sp.sizes)}
    for shard_i, segs in enumerate(sp.shard_table()):
        lo, hi = sp.shard_bounds(shard_i)
        for leaf_idx, leaf_off, length in segs:
            gstart = sp.offsets[leaf_idx] + leaf_off
            assert lo <= gstart and gstart + length <= hi   # truly local
            assert not seen[leaf_idx][leaf_off:leaf_off + length].any()
            seen[leaf_idx][leaf_off:leaf_off + length] = True
    for cov in seen.values():
        assert cov.all()


def test_shard_spec_rejects_bad_counts():
    sp = F.tree_spec(mixed_tree(jax.random.PRNGKey(2)))
    with pytest.raises(ValueError):
        F.shard_spec(sp, 0)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_sharded_roundtrip(data):
    n_leaves = data.draw(st.integers(min_value=1, max_value=5))
    shapes = [tuple(data.draw(st.integers(min_value=1, max_value=17))
                    for _ in range(data.draw(st.integers(min_value=1,
                                                         max_value=3))))
              for _ in range(n_leaves)]
    n_shards = data.draw(st.integers(min_value=1, max_value=6))
    key = jax.random.PRNGKey(data.draw(st.integers(min_value=0,
                                                   max_value=2 ** 16)))
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), shp)
            for i, shp in enumerate(shapes)}
    fp = F.flatten_sharded(tree, n_shards)
    assert fp.spec.padded == n_shards * fp.spec.shard_len
    back = F.unflatten(fp)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# shard-vs-whole parity on the in-process (1,) mesh (the multi-device sweep
# is the slow subprocess test below — same assertions, pod counts > 1)
# ---------------------------------------------------------------------------

def _f32_tree(key):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (301, 17)),
            "b": {"c": jax.random.normal(ks[1], (520,)),
                  "d": jax.random.normal(ks[2], (33, 40))}}


def test_sharded_assimilate_matches_single_host_1dev():
    mesh = make_pod_mesh(1)
    tree = _f32_tree(jax.random.PRNGKey(3))
    fp = F.flatten_sharded(tree, 1)
    clients = jnp.stack([fp.buf + 0.01 * (i + 1) for i in range(3)])
    w = V.assimilation_weights(3, 0.9)
    single = V.assimilate_many_flat(fp, clients, 0.9)
    shard = S.sharded_assimilate_flat(fp.buf, clients, w, mesh, "pod")
    np.testing.assert_array_equal(np.asarray(single.buf), np.asarray(shard))


def test_sharded_adam_matches_single_host_1dev():
    mesh = make_pod_mesh(1)
    tree = _f32_tree(jax.random.PRNGKey(4))
    fp = F.flatten_sharded(tree, 1)
    opt = Adam(lr=1e-3, weight_decay=0.01)
    fos = opt.init_flat(fp)
    g = jax.random.normal(jax.random.PRNGKey(5), fp.buf.shape) * 0.01
    for _ in range(3):
        fp1, fos1 = opt.update_flat(g, fos, fp)
        fp2, fos2 = opt.update_flat_sharded(g, fos, fp, mesh=mesh,
                                            axis="pod")
        np.testing.assert_array_equal(np.asarray(fp1.buf),
                                      np.asarray(fp2.buf))
        np.testing.assert_array_equal(np.asarray(fos1.m), np.asarray(fos2.m))
        np.testing.assert_array_equal(np.asarray(fos1.v), np.asarray(fos2.v))
        assert int(fos1.step) == int(fos2.step)
        fp, fos = fp1, fos1


def test_sharded_easgd_and_lerp_match_1dev():
    from repro.kernels import ref as R
    mesh = make_pod_mesh(1)
    fp = F.flatten_sharded(_f32_tree(jax.random.PRNGKey(6)), 1)
    reps = jnp.stack([fp.buf + 0.1, fp.buf - 0.2])
    c1, x1 = R.easgd_elastic(fp.buf, reps, 0.05)
    c2, x2 = S.sharded_easgd_flat(fp.buf, reps, 0.05, mesh, "pod")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    l1 = R.vc_asgd_lerp(fp.buf, reps[0], 0.9)
    l2 = S.sharded_lerp_flat(fp.buf, reps[0], 0.9, mesh, "pod")
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_make_flat_train_step_mesh_matches_single_host():
    """The mesh-aware flat train step is bit-identical to the single-host
    one (the Adam update is per-shard elementwise)."""
    from repro.runtime.train import make_flat_train_step
    mesh = make_pod_mesh(1)
    tree = _f32_tree(jax.random.PRNGKey(7))
    opt = Adam(lr=1e-2)

    def loss_fn(p, batch):
        return sum(jnp.sum((x - 0.1) ** 2) for x in jax.tree.leaves(p))

    fp_a = F.flatten(tree)
    fp_b = F.flatten_sharded(tree, 1)
    step_a = make_flat_train_step(loss_fn, opt)
    step_b = make_flat_train_step(loss_fn, opt, mesh=mesh, shard_axis="pod")
    fos_a, fos_b = opt.init_flat(fp_a), opt.init_flat(fp_b)
    for _ in range(3):
        fp_a, fos_a, la = step_a(fp_a, fos_a, None)
        fp_b, fos_b, lb = step_b(fp_b, fos_b, None)
        assert float(la) == float(lb)
        # same logical prefix (padded tails differ only in layout length)
        n = fp_a.spec.n
        np.testing.assert_array_equal(np.asarray(fp_a.buf[:n]),
                                      np.asarray(fp_b.buf[:n]))


# ---------------------------------------------------------------------------
# vc_round assimilation: flat path vs the retained per-leaf oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dead", [None, 1])
def test_assimilate_flat_matches_per_leaf_oracle(dead):
    key = jax.random.PRNGKey(8)
    server = _f32_tree(key)
    n_pods = 3
    islands = jax.tree.map(
        lambda s: jnp.stack([s + 0.01 * (j + 1) for j in range(n_pods)]),
        server)
    surv = jnp.asarray([j != dead for j in range(n_pods)])
    if dead is not None:
        # a dead island may hold inf/nan — must not poison the server
        islands = jax.tree.map(
            lambda x: x.at[dead].set(jnp.inf), islands)
    w, w_s = island_weights(n_pods, 0.7, surv)
    oracle = assimilate_islands_per_leaf(server, islands, w, w_s)

    isl_buf, spec = F.flatten_batched(islands)
    s_buf = F.flatten_like(server, spec)
    out = F.unflatten(F.FlatParams(
        assimilate_flat(s_buf, isl_buf, w, w_s), spec))
    for a, b in zip(jax.tree.leaves(oracle), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_assimilate_flat_sharded_1dev_matches():
    mesh = make_pod_mesh(1)
    server = _f32_tree(jax.random.PRNGKey(9))
    islands = jax.tree.map(lambda s: jnp.stack([s + 0.1, s - 0.3]), server)
    surv = jnp.ones((2,), bool)
    w, w_s = island_weights(2, 0.8, surv)
    isl_buf, spec = F.flatten_batched(islands)
    s_buf = F.flatten_like(server, spec)
    plain = assimilate_flat(s_buf, isl_buf, w, w_s)
    sharded = assimilate_flat(s_buf, isl_buf, w, w_s, mesh=mesh,
                              shard_axis="pod")
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(sharded))


def test_assimilate_flat_kernel_close():
    """The fused Pallas route of the masked reduction stays numerically on
    top of the jnp form (bit-exactness is only pinned between the jnp
    forms — the kernel folds in a different order)."""
    server = _f32_tree(jax.random.PRNGKey(10))
    islands = jax.tree.map(lambda s: jnp.stack([s + 0.1, s - 0.3]), server)
    w, w_s = island_weights(2, 0.8, jnp.ones((2,), bool))
    isl_buf, spec = F.flatten_batched(islands)
    s_buf = F.flatten_like(server, spec)
    a = assimilate_flat(s_buf, isl_buf, w, w_s)
    b = assimilate_flat(s_buf, isl_buf, w, w_s, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# sharded one-pass train records
# ---------------------------------------------------------------------------

def test_sharded_train_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import (load_train_checkpoint,
                                  save_train_checkpoint)
    tree = _f32_tree(jax.random.PRNGKey(11))
    fp = F.flatten_sharded(tree, 4)
    opt = Adam(lr=1e-3)
    fos = opt.init_flat(fp)
    g = jax.random.normal(jax.random.PRNGKey(12), fp.buf.shape) * 0.01
    fp, fos = opt.update_flat(g, fos, fp)
    path = tmp_path / "train.msgpack"
    save_train_checkpoint(path, fp, fos, {"round": 3})
    fp2, fos2, extra = load_train_checkpoint(path, fp.spec)
    assert extra["round"] == 3
    assert isinstance(fp2.spec, F.ShardedTreeSpec)
    assert fp2.spec.n_shards == 4
    np.testing.assert_array_equal(np.asarray(fp.buf), np.asarray(fp2.buf))
    np.testing.assert_array_equal(np.asarray(fos.m), np.asarray(fos2.m))
    # a record written 4-way must not restore onto a 2-way layout
    with pytest.raises(ValueError, match="shard-layout"):
        load_train_checkpoint(path, F.shard_spec(F.tree_spec(tree), 2))


# ---------------------------------------------------------------------------
# multi-device parity sweep (subprocess, like test_sharding_multi.py)
# ---------------------------------------------------------------------------

def _run(py: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_shard_vs_whole_parity_every_pod_count():
    """assimilate_flat / adam_update_flat over the sharded bus are
    BIT-identical to the single-host flat path at every pod count the CPU
    mesh supports (1, 2, 4, 8), jnp and kernel routes.

    One fixed layout for the whole sweep (padded so 8 shards divide it):
    bit-exactness is a statement about the VALUES, so the buffers compared
    must be the same length — per-pod-count tail padding would compare
    different layouts, and XLA's elementwise codegen (FMA grouping) is
    length-dependent."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import flat as F
        from repro.core import vc_asgd as V
        from repro.launch.mesh import make_pod_mesh
        from repro.optim import Adam
        from repro.runtime import sharding as S
        from repro.kernels import ops as K

        key = jax.random.PRNGKey(0)
        tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i),
                                           (257, 31 + i))
                for i in range(5)}
        opt = Adam(lr=1e-3, weight_decay=0.01)
        # one layout every pod count shards evenly (ShardedTreeSpec
        # geometry for 8 pods == BLOCK*8-padded flatten)
        fp8 = F.flatten_sharded(tree, 8)
        assert fp8.spec.padded == F.flatten(tree, pad_to=F.BLOCK * 8).buf.size
        clients = jnp.stack([fp8.buf + 0.01 * (i + 1) for i in range(3)])
        w = V.assimilation_weights(3, 0.9)
        g = jax.random.normal(jax.random.fold_in(key, 99),
                              fp8.buf.shape) * 0.01

        # the single-host flat path (what the runtime executes unsharded)
        single = V.assimilate_many_flat(fp8, clients, 0.9).buf
        single_k = K.fused_assimilate_flat(fp8.buf, clients, w)
        fos0 = opt.init_flat(fp8)
        p1, o1 = opt.update_flat(g, fos0, fp8)

        for n_pods in (1, 2, 4, 8):
            mesh = make_pod_mesh(n_pods)
            spec = F.shard_spec(F.tree_spec(tree), n_pods,
                                pad_to=F.BLOCK * (8 // n_pods))
            assert spec.padded == fp8.spec.padded
            sh = S.shard_flat(F.FlatParams(fp8.buf, spec), mesh)
            # every device owns exactly one contiguous segment
            assert len(sh.buf.sharding.device_set) == n_pods

            shard = S.sharded_assimilate_flat(sh.buf, clients, w,
                                              mesh, "pod")
            shard_k = S.sharded_assimilate_flat(sh.buf, clients, w, mesh,
                                                "pod", use_kernel=True)
            np.testing.assert_array_equal(np.asarray(single),
                                          np.asarray(shard))
            np.testing.assert_array_equal(np.asarray(single_k),
                                          np.asarray(shard_k))

            fos = F.init_opt_state(sh.spec)
            p2, o2 = opt.update_flat_sharded(g, fos, sh, mesh=mesh,
                                             axis="pod")
            pk, ok_ = opt.update_flat_sharded(g, fos, sh, mesh=mesh,
                                              axis="pod", use_kernel=True)
            np.testing.assert_array_equal(np.asarray(p1.buf),
                                          np.asarray(p2.buf))
            np.testing.assert_array_equal(np.asarray(o1.m), np.asarray(o2.m))
            np.testing.assert_array_equal(np.asarray(o1.v), np.asarray(o2.v))
            np.testing.assert_allclose(np.asarray(p1.buf), np.asarray(pk.buf),
                                       atol=1e-6)
            print("POD", n_pods, "OK")
        print("DONE")
    """)
    assert "DONE" in out
    for n in (1, 2, 4, 8):
        assert f"POD {n} OK" in out


@pytest.mark.slow
def test_vc_round_flat_sharded_on_pod_mesh():
    """make_vc_round with flat_shard_axis on a real (2,1,2) pod mesh:
    per-shard assimilation == unsharded flat assimilation bit-for-bit,
    loss decreases, and a masked island does not corrupt the server."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.registry import build_model
        from repro.optim import Adam
        from repro.runtime.sharding import MeshPlan
        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.vc_runtime import make_vc_round

        cfg = get_reduced("internlm2-1.8b")
        model = build_model(cfg)
        mesh = compat_make_mesh((2, 1, 2), ("pod", "data", "model"))
        plan = MeshPlan.build(cfg, mesh)
        opt = Adam(lr=1e-3)
        key = jax.random.PRNGKey(0)
        toks = jax.random.randint(key, (2, 2, 4, 32), 0, cfg.vocab_size)

        def play(axis):
            vc = make_vc_round(model, plan, 2, 2, opt,
                               flat_shard_axis=axis)
            with mesh:
                server = model.init(key)
                islands = jax.tree.map(lambda s: jnp.stack([s, s]), server)
                opts = jax.vmap(opt.init)(islands)
                losses = []
                for rnd in range(3):
                    surv = jnp.asarray([rnd != 1, True])
                    server, islands, opts, m = vc(
                        server, islands, opts, {"tokens": toks},
                        jnp.asarray(0.6, jnp.float32), surv)
                    losses.append(float(m["loss"]))
            return server, losses

        s_plain, l_plain = play(None)
        s_shard, l_shard = play("model")
        assert l_shard == l_plain, (l_shard, l_plain)
        for a, b in zip(jax.tree.leaves(s_plain), jax.tree.leaves(s_shard)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        ok = all(np.isfinite(np.asarray(l, np.float32)).all()
                 for l in jax.tree.leaves(s_shard))
        assert l_shard[-1] < l_shard[0] and ok
        print("LOSSES", l_shard, ok)
    """)
    assert "LOSSES" in out
