"""The hierarchical aggregation tier (protocol/aggregator.py) and the
lease-lifecycle bugfix sweep that ships with it.

The tier's load-bearing claim is BIT identity: an aggregator that folds a
window's results and flushes ONE merged v3 frame upstream leaves the hub
in exactly the state a flat hub reaches folding the same arrivals — by
construction (same float op sequence, fold seeded from the decoded
upstream base), not by algebraic argument.  The failure-model claims are
the usual protocol trio one level up: exactly-once upstream, no leaks
when clients die mid-window, no leaks when the whole aggregator dies.

The bugfix regressions pinned here:
  * ``_lease_heap`` must stay empty under ``timeout_s=math.inf`` (it
    grew one dead entry per issue, unbounded in long-lived servers);
  * a mis-kinded frame on the upload leg must terminate the lease, not
    KeyError out of ``deliver`` leaving it IN_FLIGHT forever;
  * ``restore_checkpoint`` must drop live leases and reset the residual
    ledger (post-checkpoint mass must not survive a rollback);
  * a ``ProcessTransport`` whose broker never completes the handshake
    must kill AND reap the broker subprocess before raising.
"""
import math
import subprocess

import numpy as np
import pytest

from repro.core import flat as F
from repro.core.baselines import CompressedVCASGD, SyncBSP, VCASGD
from repro.protocol import (LEASE_DROPPED, Aggregator, Coordinator,
                            LeaseError)
from repro.transfer import wire
from repro.transfer.transport import ProcessTransport
import repro.transfer.transport as transport_mod

import jax


def _params(seed=0, shape=(64, 32)):
    return F.flatten({"w": jax.random.normal(jax.random.PRNGKey(seed),
                                             shape)})


# ---------------------------------------------------------------------------
# the tier: bit identity, exactly-once upstream, no-leak failure
# ---------------------------------------------------------------------------

def test_tier_protocol_bit_identical_to_flat():
    """Hub + aggregator folding a window then flushing == flat hub
    folding the same five arrivals directly, to the BIT (uint32 views),
    and the hub sees ONE upstream frame instead of five."""
    fp = _params()
    flat_hub = Coordinator(VCASGD(0.9), fp)
    hub = Coordinator(VCASGD(0.9), fp)
    agg = Aggregator(VCASGD(0.9), hub, agg_id=0)
    up = agg.open_window(round=0)
    for i in range(5):
        fl = flat_hub.issue(cid=i, uid=i, round=0,
                            base=flat_hub.state.params)
        flat_hub.submit(fl, fl.base.buf + (i + 1) * 0.25)
        flat_hub.assimilate(fl, flat_hub.deliver(fl), server_version=i)
        el = agg.issue(cid=i, uid=i, round=0, base=agg.state.params)
        agg.submit(el, el.base.buf + (i + 1) * 0.25)
        agg.assimilate(el, agg.deliver(el), server_version=i)
    assert agg.window_merged == 5
    assert agg.window_retention == pytest.approx(0.9 ** 5)
    assert agg.flush() is up
    assert not agg.window_open and agg.flushes == 1
    hub.assimilate(up, hub.deliver(up), server_version=0)
    np.testing.assert_array_equal(
        np.asarray(flat_hub.state.params.buf).view(np.uint32),
        np.asarray(hub.state.params.buf).view(np.uint32))
    assert hub.frames[wire.KIND_AGG] == 1 and hub.assimilated == 1
    assert up.frame_bytes == wire.agg_frame_bytes(fp.spec.padded)
    assert agg.transport.in_flight == 0 and hub.transport.in_flight == 0


def test_preempted_client_mid_window_exactly_once_upstream():
    """A client dies mid-upload inside a window: its lease drops, the
    survivors' folds still flush upstream exactly ONCE, and the merge
    equals a flat fold of only the surviving result."""
    fp = _params()
    hub = Coordinator(VCASGD(0.9), fp)
    agg = Aggregator(VCASGD(0.9), hub, agg_id=0)
    up = agg.open_window(round=0)
    keep = agg.issue(cid=0, uid=1, round=0, base=agg.state.params)
    dead = agg.issue(cid=1, uid=2, round=0, base=agg.state.params)
    agg.submit(keep, keep.base.buf + 1.0)
    agg.submit(dead, dead.base.buf + 99.0)    # uploaded, never delivered
    agg.drop_client(1)                        # preempted mid-upload
    assert dead.status == LEASE_DROPPED and dead.released
    agg.assimilate(keep, agg.deliver(keep), server_version=0)
    assert agg.window_merged == 1 and agg.in_flight == 0
    assert agg.flush() is up
    hub.assimilate(up, hub.deliver(up), server_version=0)
    assert hub.assimilated == 1 and hub.frames[wire.KIND_AGG] == 1
    with pytest.raises(LeaseError):           # the window is consumed
        agg.flush()
    ref = Coordinator(VCASGD(0.9), fp)
    rl = ref.issue(cid=0, uid=1, round=0, base=fp)
    ref.submit(rl, rl.base.buf + 1.0)
    ref.assimilate(rl, ref.deliver(rl), server_version=0)
    np.testing.assert_array_equal(
        np.asarray(hub.state.params.buf).view(np.uint32),
        np.asarray(ref.state.params.buf).view(np.uint32))
    assert agg.transport.in_flight == 0 and hub.transport.in_flight == 0


def test_empty_window_flush_never_counts_as_a_result():
    """A window that folded nothing (every client lost) flushes to None:
    the upstream lease is dropped, never submitted — an empty merge must
    not bump the hub's assimilation count or move its params."""
    fp = _params()
    hub = Coordinator(VCASGD(0.9), fp)
    agg = Aggregator(VCASGD(0.9), hub, agg_id=0)
    agg.open_window(round=0)
    lease = agg.issue(cid=0, uid=1, round=0, base=agg.state.params)
    agg.submit(lease, lease.base.buf + 1.0)
    agg.drop(lease)
    assert agg.flush() is None
    assert hub.assimilated == 0 and hub.dropped == 1
    assert hub.in_flight == 0 and hub.transport.in_flight == 0
    np.testing.assert_array_equal(np.asarray(hub.state.params.buf),
                                  np.asarray(fp.buf))


def test_aggregator_fail_releases_everything():
    """Losing the whole aggregator node: every downstream lease AND
    residual releases, the hub reclaims the upstream lease, and a fresh
    window can be issued immediately — nothing leaks at either level."""
    fp = _params()
    hub = Coordinator(CompressedVCASGD(0.9, density=0.05), fp)
    agg = Aggregator(CompressedVCASGD(0.9, density=0.05), hub, agg_id=7)
    agg.open_window(round=0)
    for i in range(3):
        lease = agg.issue(cid=i, uid=i, round=0, base=agg.state.params)
        agg.submit(lease, lease.base.buf + 1.0)
        if i == 0:
            # one fold leaves error-feedback residual behind at the edge
            agg.assimilate(lease, agg.deliver(lease), server_version=0)
    assert agg.residual_mass() > 0.0 and agg.in_flight == 2
    assert hub.in_flight == 1
    agg.fail()
    assert agg.in_flight == 0 and agg.residual_mass() == 0.0
    assert not agg.window_open and hub.in_flight == 0
    assert agg.transport.in_flight == 0 and hub.transport.in_flight == 0
    up2 = agg.open_window(round=1)
    assert up2.uid == 1                       # window uids stay monotone


def test_barrier_scheme_rejected_at_construction():
    """BSP/persistent-replica schemes need every client every round; a
    partial edge merge cannot represent them and must be refused."""
    hub = Coordinator(VCASGD(0.9), _params())
    with pytest.raises(ValueError, match="requires every client"):
        Aggregator(SyncBSP(4), hub, agg_id=0)


def test_fold_without_open_window_rejected():
    fp = _params()
    hub = Coordinator(VCASGD(0.9), fp)
    agg = Aggregator(VCASGD(0.9), hub, agg_id=0)
    lease = agg.issue(cid=0, uid=1, round=0, base=agg.state.params)
    agg.submit(lease, lease.base.buf + 1.0)
    with pytest.raises(LeaseError, match="no open window"):
        agg.assimilate(lease, agg.deliver(lease), server_version=0)
    with pytest.raises(LeaseError):
        agg.flush()
    up = agg.open_window(round=0)
    with pytest.raises(LeaseError, match="already holds"):
        agg.open_window(round=0)
    hub.drop(up)


# ---------------------------------------------------------------------------
# the tier inside the simulator: 2-level == flat, churn accounting
# ---------------------------------------------------------------------------

def test_sim_two_level_bit_identical_to_flat():
    """The whole point of fold relocation: a 2-level run (one aggregator
    in front of one strong parameter server) produces the SAME final
    bits as the flat run — identical float op sequence, not approximate
    equivalence."""
    from repro.core.simulator import SimConfig, run_simulation
    from repro.core.tasks import MLPTask, make_classification_data

    task = MLPTask()
    data = make_classification_data(n_train=600, n_val=150, seed=0)
    base = dict(n_param_servers=1, n_clients=3, tasks_per_client=3,
                n_shards=9, max_epochs=1, local_steps=2,
                consistency="strong", subtask_compute_s=120.0, seed=5)
    flat = run_simulation(task, data, VCASGD(0.9), SimConfig(**base))
    tier = run_simulation(task, data, VCASGD(0.9),
                          SimConfig(aggregators=1, **base))
    assert tier.final_accuracy == flat.final_accuracy   # bitwise
    assert tier.results_assimilated == flat.results_assimilated == 9
    assert tier.aggregators == 1 and tier.agg_flushes >= 1
    assert tier.wire_agg_frames == tier.agg_flushes
    # the hub's upstream leg shrinks from one frame per result to one
    # per flush window
    assert tier.wire.frames_sent < flat.wire.frames_sent


def test_sim_tier_fleet_churn_accounting():
    """A preemptible probe fleet behind 4 aggregators: every produced
    result is assimilated exactly once, every flush maps to exactly one
    hub KIND_AGG frame, and the tier survives client churn."""
    from repro.core.baselines import VCASGD as _V
    from repro.core.simulator import SimConfig, run_simulation
    from repro.scenarios.probe import ProbeTask, make_probe_data

    cfg = SimConfig(n_param_servers=2, n_clients=120, tasks_per_client=1,
                    n_shards=240, max_epochs=2, local_steps=1,
                    timeout_s=1800.0, preemptible=True,
                    mean_lifetime_s=5400.0, restart_delay_s=120.0,
                    subtask_compute_s=120.0, server_proc_s=0.05,
                    seed=7, aggregators=4)
    res = run_simulation(ProbeTask(), make_probe_data(cfg.n_shards, seed=7),
                         _V(0.95), cfg)
    assert res.epochs_done == 2
    assert res.results_assimilated == 480
    assert res.wire_agg_frames == res.agg_flushes > 0
    assert res.preemptions > 0
    # edge transports carried the per-client traffic the hub no longer sees
    assert res.edge_wire.frames_sent > res.wire.frames_sent


# ---------------------------------------------------------------------------
# bugfix 1: the deadline heap under infinite timeouts
# ---------------------------------------------------------------------------

def test_lease_heap_bounded_under_inf_timeout():
    """timeout_s=inf (vc_serve-style trusting runtimes): issue/renew must
    not push never-expiring entries — the heap grew one dead tuple per
    lease forever.  Finite deadlines still expire."""
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp, timeout_s=math.inf)
    for i in range(64):
        lease = coord.issue(cid=0, uid=i, round=0,
                            base=coord.state.params)
        coord.submit(lease, lease.base.buf + 1.0)
        coord.assimilate(lease, coord.deliver(lease), server_version=i)
    assert coord.in_flight == 0 and coord.assimilated == 64
    assert len(coord._lease_heap) == 0
    live = coord.issue(cid=0, uid=999, round=0, base=coord.state.params)
    coord.renew(live, deadline=math.inf)
    assert len(coord._lease_heap) == 0        # renew-to-inf doesn't push
    finite = coord.issue(cid=1, uid=1000, round=0,
                         base=coord.state.params, now=0.0, deadline=5.0)
    assert len(coord._lease_heap) == 1
    assert coord.expire(now=10.0) == [finite]
    coord.drop(live)


# ---------------------------------------------------------------------------
# bugfix 2: mis-kinded frames on the upload leg
# ---------------------------------------------------------------------------

def test_upload_leg_wrong_kind_terminates_lease():
    """A structurally valid SHARD frame arriving on the UPLOAD leg (shard
    frames are download-only) must raise WireError AND terminate the
    lease — before the fix the frame-counter lookup KeyError'd and the
    lease sat IN_FLIGHT forever, wedging its base and window."""
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp)
    coord.submit(lease, fp.buf + 1.0)
    evil = wire.encode_shard(np.asarray(fp.buf), shard=0, n_shards=1)
    coord.transport._inflight[lease.msg_id] = evil
    with pytest.raises(wire.WireError, match="upload"):
        coord.deliver(lease)
    assert lease.status == LEASE_DROPPED and lease.released
    assert coord.in_flight == 0 and coord.dropped == 1
    # the coordinator is not wedged: the next round works end to end
    l2 = coord.issue(cid=0, uid=2, round=0, base=coord.state.params)
    coord.submit(l2, l2.base.buf + 1.0)
    coord.assimilate(l2, coord.deliver(l2), server_version=0)
    assert coord.assimilated == 1


def test_upload_leg_agg_frame_rejected_at_plain_coordinator_lease():
    """KIND_AGG is only valid under an upstream (aggregator) submission:
    a client lease whose upload mutates into an aggregate frame is
    dropped the same way."""
    fp = _params()
    coord = Coordinator(VCASGD(0.9), fp)
    lease = coord.issue(cid=0, uid=1, round=0, base=fp)
    coord.submit(lease, fp.buf + 1.0)
    # an aggregate frame IS legal on this coordinator's upload leg (the
    # hub accepts merges) — but a DOWNLOAD-kind frame never is
    evil = wire.encode_shard(np.asarray(fp.buf), shard=0, n_shards=2)
    coord.transport._inflight[lease.msg_id] = evil
    with pytest.raises(wire.WireError):
        coord.deliver(lease)
    assert lease.released and coord.in_flight == 0


# ---------------------------------------------------------------------------
# bugfix 3: restore_checkpoint must not leak pre-restore protocol state
# ---------------------------------------------------------------------------

def test_restore_checkpoint_drops_leases_and_resets_ledger(tmp_path):
    from repro.checkpoint import CheckpointManager
    fp = _params()
    coord = Coordinator(CompressedVCASGD(0.9, density=0.05), fp)
    mgr = CheckpointManager(tmp_path, async_save=False)
    l1 = coord.issue(cid=0, uid=1, round=0, base=fp)
    coord.submit(l1, l1.base.buf + 1.0)
    coord.assimilate(l1, coord.deliver(l1), server_version=0)
    coord.save_checkpoint(mgr, step=1)
    # post-checkpoint: more residual mass and two live leases
    l2 = coord.issue(cid=1, uid=2, round=0, base=coord.state.params)
    coord.submit(l2, l2.base.buf + 2.0)
    coord.assimilate(l2, coord.deliver(l2), server_version=1)
    l3 = coord.issue(cid=1, uid=3, round=0, base=coord.state.params)
    coord.submit(l3, l3.base.buf + 3.0)
    l4 = coord.issue(cid=2, uid=4, round=0, base=coord.state.params)
    assert coord.in_flight == 2 and coord.residual_mass() > 0.0
    restored_version = coord.state.version
    assert coord.restore_checkpoint(mgr) == 1
    # the rollback is total: no live leases, no heap entries, no
    # in-flight frames, and the post-checkpoint residual mass is gone
    assert coord.in_flight == 0
    assert len(coord._lease_heap) == 0
    assert coord.transport.in_flight == 0
    assert coord.residual_mass() == 0.0
    assert l3.released and l4.released
    assert coord.state.version < restored_version
    # stale leases from before the restore can never assimilate
    with pytest.raises(LeaseError):
        coord.assimilate(l3, fp.buf + 3.0, server_version=0)
    # and the restored server runs fresh rounds cleanly
    l5 = coord.issue(cid=0, uid=5, round=1, base=coord.state.params)
    coord.submit(l5, l5.base.buf + 1.0)
    coord.assimilate(l5, coord.deliver(l5), server_version=0)


# ---------------------------------------------------------------------------
# bugfix 4: the broker process must never outlive a failed handshake
# ---------------------------------------------------------------------------

def test_broker_reaped_when_handshake_times_out(monkeypatch):
    """A broker that spawns but never connects: the constructor raises
    (accept timeout) and must kill AND reap its subprocess — an orphaned
    Popen handle leaks a live OS process per failed construction."""
    procs = []
    real_popen = subprocess.Popen

    def capturing_popen(*args, **kwargs):
        p = real_popen(*args, **kwargs)
        procs.append(p)
        return p

    monkeypatch.setattr(transport_mod.subprocess, "Popen", capturing_popen)
    monkeypatch.setattr(transport_mod, "_BROKER_SRC",
                        "import time; time.sleep(600)")
    with pytest.raises(OSError):
        ProcessTransport(timeout_s=0.5)
    assert len(procs) == 1
    # killed and waited on: returncode is populated, no zombie left
    assert procs[0].returncode is not None
