"""Per-architecture smoke tests (reduced configs) + numerical equivalences:
prefill+decode == full forward, CP chunking invariance, chunked linear
recurrences == step recurrences, blocked attention == plain softmax."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.shapes import SHAPES, cell_applicable
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.plan import NullPlan
from repro.models.registry import build_model

RNG = jax.random.PRNGKey(0)


def _batch_for(cfg, b, s, key=RNG):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.vision is not None:
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.vision.n_patches, cfg.vision.vit_dim), jnp.float32)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jax.random.normal(
            key, (b, cfg.encoder.n_frames, cfg.encoder.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step on CPU; shapes + finite."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 32
    batch = _batch_for(cfg, b, s)
    lg = model.forward(params, batch)
    prefix = cfg.vision.n_patches if cfg.vision is not None else 0
    assert lg.shape == (b, s + prefix, L.padded_vocab(cfg))
    assert np.isfinite(np.asarray(lg, np.float32)).all()

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """decode(t=s) logits == forward logits at position s (drop-free MoE)."""
    cfg = get_reduced(arch).replace(compute_dtype="float32", scan_chunk=8)
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 24
    batch = _batch_for(cfg, b, s + 1)
    full = model.forward(params, batch)
    b0 = dict(batch)
    b0["tokens"] = batch["tokens"][:, :s]
    lg_pref, caches = model.prefill(params, b0)
    prefix = cfg.vision.n_patches if cfg.vision is not None else 0
    lg_dec, _ = model.decode_step(params, caches, batch["tokens"][:, s],
                                  jnp.asarray(s + prefix, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_pref),
                               np.asarray(full[:, prefix + s - 1]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(full[:, prefix + s]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma3-4b"])
@pytest.mark.parametrize("cp", [2, 4])
def test_cp_chunking_invariance(arch, cp):
    """Context-parallel layout is numerically identical to local attention."""
    cfg = get_reduced(arch).replace(compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch_for(cfg, 2, 64)
    lg1 = model.forward(params, batch, plan=NullPlan())
    lg2 = model.forward(params, batch, plan=NullPlan(attn_mode="cp", cp=cp))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=2e-3, atol=2e-3)


def test_cp_window_gather_equals_full():
    """SWA via neighbor-chunk gather == SWA via full attention."""
    cfg = get_reduced("gemma3-4b").replace(compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch_for(cfg, 2, 64)
    a = model.forward(params, batch,
                      plan=NullPlan(attn_mode="cp", cp=4, window_gather=True))
    b = model.forward(params, batch,
                      plan=NullPlan(attn_mode="cp", cp=4, window_gather=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_vs_recurrent():
    cfg = get_reduced("rwkv6-1.6b").replace(compute_dtype="float32",
                                            scan_chunk=8)
    p = R.init_time_mix(RNG, cfg)
    x = jax.random.normal(RNG, (2, 37, cfg.d_model)) * 0.5   # odd length
    o1, _, _ = R.time_mix_chunked(p, x, cfg)
    o2 = R.time_mix_recurrent_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_vs_recurrent():
    cfg = get_reduced("jamba-v0.1-52b").replace(compute_dtype="float32",
                                                scan_chunk=8)
    p = M.init_mamba(RNG, cfg)
    x = jax.random.normal(RNG, (2, 29, cfg.d_model)) * 0.5
    y1, _ = M.mamba_chunked(p, x, cfg)
    y2 = M.mamba_recurrent_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_blocked_attention_vs_plain(causal, window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 1, 64, 4, 16)) * 0.4
    k = jax.random.normal(ks[1], (2, 64, 2, 16)) * 0.4
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    got = L.blocked_attention(q, k, v, causal=causal, window=window,
                              q_block=16, kv_block=16)
    # plain reference via kernels ref (layout adaptation)
    from repro.kernels import ref as KR
    want = KR.attention(q[:, 0].transpose(0, 2, 1, 3),
                        k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                        causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               rtol=2e-5, atol=2e-5)


def test_decode_two_tier_compaction():
    """Attention over (old tier + recent ring) == attention over a cache
    where the ring has been compacted into the old tier."""
    cfg = get_reduced("internlm2-1.8b").replace(compute_dtype="float32")
    b, kv, C, ln, hd = 2, 2, 2, 16, 16
    ks = jax.random.split(RNG, 8)
    cache = L.make_decode_cache(b, kv, C, ln, hd, jnp.float32, prefilled=20)
    cache = cache._replace(
        k_old=jax.random.normal(ks[0], cache.k_old.shape),
        v_old=jax.random.normal(ks[1], cache.v_old.shape))
    # append 3 tokens to the ring
    for i in range(3):
        kn = jax.random.normal(ks[2 + i], (b, kv, hd))
        vn = jax.random.normal(ks[5 + i], (b, kv, hd))
        cache = L.cache_append_recent(cache, kn, vn,
                                      jnp.asarray(20 + i, jnp.int32))
    q = jax.random.normal(ks[7], (b, 4, hd)) * 0.4
    pos = jnp.asarray(22, jnp.int32)
    out1 = L.decode_attention(q, cache, pos)
    compacted = L.compact_cache(cache, pos)
    out2 = L.decode_attention(q, compacted, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
    assert int(compacted.rec_pos.max()) == -1           # ring emptied


def test_cell_applicability_matrix():
    """long_500k runs for ssm/hybrid/bounded-window archs, skips for pure
    full-attention stacks; every other cell runs for every arch."""
    runs, skips = set(), set()
    for arch in ARCHS:
        cfg = get_config(arch)
        for cell in SHAPES.values():
            (skips if cell_applicable(cfg, cell) else runs).add(
                (arch, cell.name))
    assert len(runs) + len(skips) == 40
    expected_skips = {("stablelm-3b", "long_500k"),
                      ("internlm2-1.8b", "long_500k"),
                      ("qwen2.5-14b", "long_500k"),
                      ("internvl2-2b", "long_500k"),
                      ("whisper-tiny", "long_500k"),
                      # granite's MoE changes only the FFN — attention is
                      # dense-full, so 500k decode has no bounded mechanism
                      ("granite-moe-1b-a400m", "long_500k")}
    assert skips == expected_skips


def test_moe_ep_equals_dense_dispatch():
    """Expert-parallel dispatch (incl. virtual-expert f-splitting) is
    numerically identical to the dense capacity path."""
    import dataclasses
    cfg = get_reduced("mixtral-8x7b").replace(compute_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                              ep_virtual=2))
    p = L.init_moe(RNG, cfg)
    x = jax.random.normal(RNG, (4, 8, cfg.d_model), jnp.float32)
    o_ep, _ = L.apply_moe_ep(p, x, cfg, NullPlan(moe_ep=True, ep=2))
    o_ref, _ = jax.vmap(lambda t: L.apply_moe(p, t, cfg))(x)
    np.testing.assert_allclose(np.asarray(o_ep), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_moe_virtual_experts_forward_consistency():
    """A model built with ep_virtual=2 matches its own prefill/decode."""
    import dataclasses
    cfg = get_reduced("granite-moe-1b-a400m").replace(
        compute_dtype="float32", scan_chunk=8)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                              ep_virtual=2))
    model = build_model(cfg)
    params = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 17), 0, cfg.vocab_size)}
    full = model.forward(params, batch)
    lg_p, caches = model.prefill(params, {"tokens": batch["tokens"][:, :16]})
    lg_d, _ = model.decode_step(params, caches, batch["tokens"][:, 16],
                                jnp.asarray(16, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(full[:, 15]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(full[:, 16]),
                               rtol=2e-3, atol=2e-3)
