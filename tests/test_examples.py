"""The examples are executed, not decorative: each one runs in ``--smoke``
mode inside the fast gate, so API drift breaks the build instead of
silently rotting the entry points new users copy from."""
import importlib.util
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run_example(path: Path, argv):
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.main(argv) == 0


@pytest.mark.parametrize("name", ["quickstart.py", "asgd_comparison.py"])
def test_example_smoke(name, capsys):
    _run_example(ROOT / "examples" / name, ["--smoke"])
    out = capsys.readouterr().out
    assert "final" in out or "scheme" in out        # it really printed a run


def test_vc_serve_smoke(tmp_path, capsys):
    """The real-runtime coordinator driver (launch/vc_serve.py): a couple
    of VC rounds with payloads through the cross-process broker on BOTH
    legs (per-shard handout frames down, result frames up)."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.launch.vc_serve import main
    assert main(["--smoke", "--ckpt-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "results assimilated" in out
    assert "handout" in out                          # download leg is real
    assert list(tmp_path.glob("ckpt_*.msgpack"))    # checkpoint hooks ran


def test_vc_serve_smoke_tier(tmp_path, capsys):
    """vc_serve with an aggregation tier: clients lease from an edge
    aggregator over its own broker, the hub only ever sees merged
    KIND_AGG frames on the upstream leg — all three process boundaries
    (hub<->agg, agg<->client) are real."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.launch.vc_serve import main
    assert main(["--smoke", "--tier", "--ckpt-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "results assimilated" in out
    assert "upstream agg frames" in out             # merged leg is live
    assert "aggregators" in out
    assert list(tmp_path.glob("ckpt_*.msgpack"))


def test_vc_serve_resume_rounds_monotonic(tmp_path, capsys):
    """The resume bugfix: a killed-and-restarted vc_serve continues at the
    checkpointed round with the persisted uid — rounds, wire headers and
    checkpoint steps are monotone, steps 1..k are never overwritten."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.launch.vc_serve import main
    assert main(["--smoke", "--ckpt-dir", str(tmp_path)]) == 0
    first = capsys.readouterr().out
    assert "round 0:" in first and "round 1:" in first
    assert main(["--smoke", "--ckpt-dir", str(tmp_path)]) == 0
    second = capsys.readouterr().out
    assert "resumed" in second
    assert "round 2:" in second and "round 3:" in second
    assert "round 0:" not in second                  # never rewinds
    # smoke = 2 rounds x 2 clients per run: uid continues, not restarts
    assert "next uid 8" in second
    steps = sorted(int(p.stem.split("_")[1])
                   for p in tmp_path.glob("ckpt_*.msgpack"))
    assert steps[-1] == 4                            # advanced past run one
