"""The examples are executed, not decorative: each one runs in ``--smoke``
mode inside the fast gate, so API drift breaks the build instead of
silently rotting the entry points new users copy from."""
import importlib.util
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run_example(path: Path, argv):
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.main(argv) == 0


@pytest.mark.parametrize("name", ["quickstart.py", "asgd_comparison.py"])
def test_example_smoke(name, capsys):
    _run_example(ROOT / "examples" / name, ["--smoke"])
    out = capsys.readouterr().out
    assert "final" in out or "scheme" in out        # it really printed a run


def test_vc_serve_smoke(tmp_path, capsys):
    """The real-runtime coordinator driver (launch/vc_serve.py): a couple
    of VC rounds with payloads through the cross-process broker."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.launch.vc_serve import main
    assert main(["--smoke", "--ckpt-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "results assimilated" in out
    assert list(tmp_path.glob("ckpt_*.msgpack"))    # checkpoint hooks ran
