"""FlatParams (core/flat.py) — the contiguous parameter bus: round-trip
across mixed dtypes, flat Eq. 1/Eq. 2 vs the per-leaf tree.map forms
(bit-for-bit in f32 under matching compilation), single-launch fused
assimilation, global-vs-per-leaf compression quality, and flat
checkpointing with dtypes preserved."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.checkpoint import (CheckpointManager, load_flat_checkpoint,
                              save_flat_checkpoint)
from repro.core import compression as C
from repro.core import flat as F
from repro.core import vc_asgd as V
from repro.kernels import vc_asgd_update as VK


def mixed_tree(key):
    ks = jax.random.split(key, 4)
    return {"w": jax.random.normal(ks[0], (33, 17), jnp.float32),
            "b": (jax.random.normal(ks[1], (9,), jnp.bfloat16),
                  jnp.arange(-3, 11, dtype=jnp.int32)),
            "deep": {"m": jax.random.normal(ks[2], (2, 3, 4), jnp.float32),
                     "v": jax.random.normal(ks[3], (130,), jnp.bfloat16)}}


def f32_tree(key, n_extra=0):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (130, 7)) + n_extra,
            "b": {"c": jax.random.normal(ks[1], (55,)),
                  "d": jax.random.normal(ks[2], (3, 3))}}


# ---------------------------------------------------------------------------
# layout + round trip
# ---------------------------------------------------------------------------

def test_roundtrip_mixed_dtypes():
    tree = mixed_tree(jax.random.PRNGKey(0))
    fp = F.flatten(tree)
    back = F.unflatten(fp)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_layout_contract():
    """Leaves pack back-to-back; tail padded to a BLOCK multiple of zeros."""
    tree = mixed_tree(jax.random.PRNGKey(1))
    fp = F.flatten(tree)
    spec = fp.spec
    assert spec.padded % F.BLOCK == 0 and spec.padded >= spec.n
    for i in range(spec.num_leaves - 1):
        assert spec.offsets[i] + spec.sizes[i] == spec.offsets[i + 1]
    assert spec.offsets[0] == 0
    assert spec.offsets[-1] + spec.sizes[-1] == spec.n
    np.testing.assert_array_equal(np.asarray(fp.buf[spec.n:]), 0.0)
    # the buffer IS the concatenation of the raveled leaves
    cat = np.concatenate([np.asarray(l, np.float32).ravel()
                          for l in jax.tree.leaves(tree)])
    np.testing.assert_array_equal(np.asarray(fp.buf[:spec.n]), cat)


def test_flatten_batched_roundtrip():
    tree = f32_tree(jax.random.PRNGKey(2))
    islands = jax.tree.map(lambda x: jnp.stack([x, x + 1.0, x * 2.0]), tree)
    buf, spec = F.flatten_batched(islands)
    assert buf.shape == (3, spec.padded)
    back = F.unflatten_batched(buf, spec)
    for a, b in zip(jax.tree.leaves(islands), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flatten_like_rejects_mismatched_layout():
    fp = F.flatten(f32_tree(jax.random.PRNGKey(3)))
    with pytest.raises(ValueError):
        F.flatten_like({"a": jnp.zeros((2, 2))}, fp.spec)


def test_flatparams_is_a_pytree():
    fp = F.flatten(f32_tree(jax.random.PRNGKey(4)))
    doubled = jax.jit(lambda p: jax.tree.map(lambda x: 2 * x, p))(fp)
    assert isinstance(doubled, F.FlatParams)
    np.testing.assert_allclose(np.asarray(doubled.buf),
                               2 * np.asarray(fp.buf))


# ---------------------------------------------------------------------------
# property tier: round-trip + layout invariants over ARBITRARY trees
# (skips cleanly without hypothesis — tests/_hyp.py)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_prop_roundtrip_and_padding_invariants(data):
    """flatten -> unflatten is the identity (dtypes preserved) for trees of
    arbitrary leaf shapes/dtypes, and the layout contract holds: leaves
    back-to-back, zero tail, padded to a BLOCK multiple."""
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    n_leaves = data.draw(st.integers(1, 6), label="n_leaves")
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(n_leaves):
        shape = tuple(data.draw(st.lists(st.integers(1, 7), min_size=0,
                                         max_size=3), label=f"shape{i}"))
        dt = data.draw(st.sampled_from(["float32", "bfloat16", "int32"]),
                       label=f"dtype{i}")
        k = jax.random.fold_in(key, i)
        if dt == "int32":
            # |x| < 2**24: int leaves round-trip exactly through f32
            leaf = jax.random.randint(k, shape, -2 ** 20, 2 ** 20,
                                      dtype=jnp.int32)
        else:
            leaf = jax.random.normal(k, shape, jnp.dtype(dt))
        tree[f"leaf{i}"] = leaf
    fp = F.flatten(tree)
    spec = fp.spec
    # layout invariants
    assert spec.padded % F.BLOCK == 0 and spec.padded >= spec.n
    assert spec.offsets[0] == 0
    for i in range(spec.num_leaves - 1):
        assert spec.offsets[i] + spec.sizes[i] == spec.offsets[i + 1]
    assert spec.offsets[-1] + spec.sizes[-1] == spec.n
    np.testing.assert_array_equal(np.asarray(fp.buf[spec.n:]), 0.0)
    # round trip with dtypes preserved
    back = F.unflatten(fp)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_prop_flat_eq1_matches_treemap(data):
    seed = data.draw(st.integers(0, 2 ** 16))
    alpha = data.draw(st.floats(0.0, 1.0, allow_nan=False))
    key = jax.random.PRNGKey(seed)
    server = f32_tree(key)
    client = f32_tree(jax.random.fold_in(key, 1))
    ref = V.vc_asgd_update(server, client, alpha)
    fp = F.flatten(server)
    out = F.unflatten(V.vc_asgd_update_flat(
        fp, F.flatten_like(client, fp.spec), alpha))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flat Eq. 1 / Eq. 2 vs per-leaf forms
# ---------------------------------------------------------------------------

def test_flat_eq1_matches_treemap():
    key = jax.random.PRNGKey(5)
    server = mixed_tree(key)
    client = mixed_tree(jax.random.fold_in(key, 1))
    ref = V.vc_asgd_update(server, client, 0.9)
    fp = F.flatten(server)
    out = F.unflatten(V.vc_asgd_update_flat(fp, F.flatten_like(client, fp.spec),
                                            0.9))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_flat_eq1_delta_matches_treemap():
    key = jax.random.PRNGKey(6)
    server = f32_tree(key)
    delta = f32_tree(jax.random.fold_in(key, 1))
    ref = V.vc_asgd_update_delta(server, delta, 0.8)
    fp = F.flatten(server)
    out = F.unflatten(V.vc_asgd_update_delta_flat(
        fp, F.flatten_like(delta, fp.spec), 0.8))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_eq2_bit_exact_vs_per_leaf_fold():
    """assimilate_many_flat (jnp) == per-leaf assimilate_many bit-for-bit
    in f32 — identical accumulation order, same elementwise ops."""
    key = jax.random.PRNGKey(7)
    server = f32_tree(key)
    clients = [f32_tree(jax.random.fold_in(key, i + 1)) for i in range(4)]
    ref = V.assimilate_many(server, clients, 0.83)
    fp = F.flatten(server)
    cbuf = jnp.stack([F.flatten_like(c, fp.spec) for c in clients])
    out = F.unflatten(V.assimilate_many_flat(fp, cbuf, 0.83))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_eq2_kernel_single_launch_and_bit_exact():
    """The fused Pallas path: ONE launch for the whole multi-leaf model,
    bit-for-bit equal to the per-leaf Eq. 2 fold compiled the same way
    (both jitted — XLA contracts mul+add to FMA under jit)."""
    key = jax.random.PRNGKey(8)
    server = f32_tree(key)
    clients = [f32_tree(jax.random.fold_in(key, i + 1)) for i in range(3)]
    fp = F.flatten(server)
    cbuf = jnp.stack([F.flatten_like(c, fp.spec) for c in clients])

    VK.reset_launch_count()
    out_k = V.assimilate_many_flat(fp, cbuf, 0.77, use_kernel=True)
    assert VK.launch_count() == 1          # whole model, one pallas_call

    # per-leaf path through the kernel: one launch per leaf
    VK.reset_launch_count()
    V.vc_asgd_update(server, clients[0], 0.77, use_kernel=True)
    assert VK.launch_count() == len(jax.tree.leaves(server))

    ref = jax.jit(lambda s, cs: V.assimilate_many(s, cs, 0.77))(server, clients)
    out_tree = F.unflatten(out_k)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out_tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staleness_weights_match_damped_fold():
    key = jax.random.PRNGKey(9)
    server = f32_tree(key)
    clients = [f32_tree(jax.random.fold_in(key, i + 1)) for i in range(3)]
    staleness = [0, 2, 1]
    folded = server
    for c, s in zip(clients, staleness):
        folded = V.vc_asgd_update(folded, c, V.staleness_alpha(0.9, s))
    w = V.staleness_weights(3, 0.9, staleness)
    assert abs(sum(w) - 1.0) < 1e-9
    fp = F.flatten(server)
    cbuf = jnp.stack([F.flatten_like(c, fp.spec) for c in clients])
    out = F.unflatten(V.assimilate_many_flat(fp, cbuf, 0.9, weights=w))
    for a, b in zip(jax.tree.leaves(folded), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# global compression on the flat bus
# ---------------------------------------------------------------------------

def test_global_topk_ratio_at_least_per_leaf():
    """Global top-k at density d retains >= the |mass| of per-leaf top-k at
    the same density (per-leaf selection is feasible for the global
    problem), so its residual is no larger."""
    key = jax.random.PRNGKey(10)
    # heterogeneous leaf scales: per-leaf top-k wastes budget on small leaves
    tree = {"big": 5.0 * jax.random.normal(key, (300,)),
            "small": 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                              (300,))}
    density = 0.1
    # per-leaf reference
    per_leaf_res = 0.0
    for leaf in jax.tree.leaves(tree):
        _, res = C.compress_delta(leaf, density=density)
        per_leaf_res += float(jnp.sum(jnp.square(res)))
    fp = F.flatten(tree)
    _, res_flat = C.compress_flat(fp.buf, density=density, logical_n=fp.spec.n)
    global_res = float(jnp.sum(jnp.square(res_flat)))
    assert global_res <= per_leaf_res + 1e-6


def test_compress_flat_error_feedback_conserves():
    """delta - residual == dequant(payload), exactly as the per-leaf form."""
    key = jax.random.PRNGKey(11)
    fp = F.flatten(f32_tree(key))
    delta = jax.random.normal(jax.random.fold_in(key, 1), fp.buf.shape)
    delta = delta.at[fp.spec.n:].set(0.0)          # padding carries nothing
    payload, res = C.compress_flat(delta, density=0.2, logical_n=fp.spec.n)
    deq = C.decompress_flat(payload)
    np.testing.assert_allclose(np.asarray(delta - res), np.asarray(deq),
                               rtol=1e-5, atol=1e-6)
    # residual carry is applied before selection on the next round
    payload2, res2 = C.compress_flat(jnp.zeros_like(delta), density=0.2,
                                     logical_n=fp.spec.n, residual=res)
    np.testing.assert_allclose(np.asarray(res - res2),
                               np.asarray(C.decompress_flat(payload2)),
                               rtol=1e-5, atol=1e-6)


def test_compress_tree_global_roundtrip_shape():
    tree = f32_tree(jax.random.PRNGKey(12))
    payload, res, spec = C.compress_tree_global(tree, density=0.3)
    dense = C.decompress_flat(payload)
    assert dense.shape == (spec.padded,)
    back = F.unflatten(F.FlatParams(dense, spec))
    assert jax.tree.structure(back) == jax.tree.structure(tree)


# ---------------------------------------------------------------------------
# flat checkpointing
# ---------------------------------------------------------------------------

def test_flat_checkpoint_roundtrip(tmp_path):
    tree = mixed_tree(jax.random.PRNGKey(13))
    fp = F.flatten(tree)
    save_flat_checkpoint(tmp_path / "f.msgpack", fp, {"round": 3})
    fp2, extra = load_flat_checkpoint(tmp_path / "f.msgpack", fp)
    assert extra["round"] == 3
    assert fp2.buf.dtype == fp.buf.dtype
    np.testing.assert_array_equal(np.asarray(fp.buf), np.asarray(fp2.buf))
    # dtypes preserved through the full unflatten
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(F.unflatten(fp2))):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flat_checkpoint_layout_mismatch_raises(tmp_path):
    fp = F.flatten(f32_tree(jax.random.PRNGKey(14)))
    save_flat_checkpoint(tmp_path / "f.msgpack", fp)
    other = F.flatten({"z": jnp.zeros((7,))})
    with pytest.raises(ValueError):
        load_flat_checkpoint(tmp_path / "f.msgpack", other)


def test_manager_routes_flatparams(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    fp = F.flatten(mixed_tree(jax.random.PRNGKey(15)))
    mgr.save(1, fp, {"round": 1})
    restored, extra, step = mgr.restore_or_init(fp, lambda: None)
    assert step == 1 and extra["round"] == 1
    assert isinstance(restored, F.FlatParams)
    np.testing.assert_array_equal(np.asarray(restored.buf), np.asarray(fp.buf))
