"""Scheduler + work-generator invariants, hypothesis-driven: no subtask is
ever lost, timeouts requeue, epochs complete, sticky affinity holds."""
import math

import pytest
from _hyp import given, settings, st

from repro.core.scheduler import Scheduler
from repro.core.work_generator import WorkGenerator, auto_split, split_dataset


def test_split_dataset_partition():
    sp = split_dataset(1000, 7, seed=3)
    assert sp.shard_sizes.sum() == 1000
    assert sp.shard_sizes.min() >= 1000 // 7
    assert len(set(range(7)) - set(sp.shard_index.tolist())) == 0


def test_auto_split_bounds():
    assert auto_split(50_000, 5, 2) == 20
    assert auto_split(100, 50, 8, min_shard=10) == 10   # capped by min shard


def test_epoch_rollover_and_completion():
    gen = WorkGenerator(n_shards=3, max_epochs=2)
    sched = Scheduler(gen, timeout_s=100, tasks_per_client=3)
    done_epochs = 0
    t = 0.0
    while not gen.exhausted and t < 1000:
        units = sched.request_work(0, t)
        for u in units:
            sched.complete(u.uid, t + 1)
            if gen.complete(u):
                done_epochs += 1
        t += 2
    assert done_epochs == 2
    assert gen.exhausted


def test_timeout_reassignment():
    gen = WorkGenerator(n_shards=2, max_epochs=1)
    sched = Scheduler(gen, timeout_s=10, tasks_per_client=2)
    units = sched.request_work(0, 0.0)
    assert len(units) == 2 and not gen.pending
    expired = sched.expire_timeouts(11.0)
    assert len(expired) == 2
    assert len(gen.pending) == 2                     # requeued
    assert sched.reassignments == 2
    # a late result for an expired unit is ignored
    assert sched.complete(units[0].uid, 12.0) is None


def test_client_failure_requeues_all():
    gen = WorkGenerator(n_shards=4, max_epochs=1)
    sched = Scheduler(gen, timeout_s=100, tasks_per_client=4)
    sched.request_work(7, 0.0)
    lost = sched.fail_client(7, 1.0)
    assert len(lost) == 4
    assert len(gen.pending) == 4
    assert sched.client_load[7] == 0
    assert sched.client_rel[7] < 1.0                 # reliability decayed


def test_sticky_affinity_prefers_cached_shards():
    gen = WorkGenerator(n_shards=4, max_epochs=3)
    sched = Scheduler(gen, timeout_s=100, tasks_per_client=1)
    u1 = sched.request_work(0, 0.0)[0]
    sched.complete(u1.uid, 1.0)
    gen.complete(u1)
    # next epoch: other shards pending too, but client 0 cached u1.shard
    # complete the rest of epoch 1 via client 1
    sched2 = sched
    while gen.epoch == 1:
        u = sched2.request_work(1, 2.0)
        if not u:
            break
        sched2.complete(u[0].uid, 3.0)
        gen.complete(u[0])
    got = sched.request_work(0, 4.0)[0]
    assert got.shard == u1.shard                      # sticky preference


@settings(max_examples=25, deadline=None)
@given(n_shards=st.integers(1, 6), n_clients=st.integers(1, 4),
       tpc=st.integers(1, 3), fail_every=st.integers(3, 9),
       seed=st.integers(0, 99))
def test_no_subtask_lost_under_random_failures(n_shards, n_clients, tpc,
                                               fail_every, seed):
    """Whatever the failure pattern, every epoch eventually completes with
    every shard assimilated exactly (fault tolerance, §III-B)."""
    import random
    rng = random.Random(seed)
    gen = WorkGenerator(n_shards=n_shards, max_epochs=2)
    sched = Scheduler(gen, timeout_s=50, tasks_per_client=tpc)
    t, it = 0.0, 0
    shards_done = set()
    while not gen.exhausted and it < 3000:
        it += 1
        cid = rng.randrange(n_clients)
        if it % fail_every == 0:
            sched.fail_client(cid, t)
            t += 1
            continue
        sched.expire_timeouts(t)
        for u in sched.request_work(cid, t):
            if rng.random() < 0.3:
                continue                              # lost in flight: times out
            sched.complete(u.uid, t + 1)
            if u.epoch == gen.epoch:
                shards_done.add((u.epoch, u.shard))
            gen.complete(u)
        t += 60 if it % 5 == 0 else 1                 # advance past timeouts
    assert gen.exhausted, "epochs must complete despite failures"
