"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode on
CPU) against its ref.py pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat import BLOCK
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels import vc_asgd_update as VK

RNG = jax.random.PRNGKey(42)


def keys(n):
    return jax.random.split(RNG, n)


TOL = {jnp.float32: 2e-6, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("shape", [(17,), (255, 9), (1024, 64), (3, 5, 7, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 0.95, 1.0])
def test_fused_lerp(shape, dtype, alpha):
    k1, k2 = keys(2)
    s = jax.random.normal(k1, shape, dtype)
    c = jax.random.normal(k2, shape, dtype)
    got = K.fused_lerp(s, c, alpha)
    want = R.vc_asgd_lerp(s, c, alpha)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("shape", [(513,), (64, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_dc_lerp(shape, dtype):
    k1, k2, k3, k4 = keys(4)
    s = jax.random.normal(k1, shape, dtype)
    c = jax.random.normal(k2, shape, dtype)
    g = jax.random.normal(k3, shape, dtype)
    b = jax.random.normal(k4, shape, dtype)
    got = K.fused_dc_lerp(s, c, g, b, 0.9, 0.05)
    want = R.vc_asgd_dc_lerp(s, c, g, b, 0.9, 0.05)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4 * TOL[dtype], atol=4 * TOL[dtype])


@pytest.mark.parametrize("nb", [1, 3])
@pytest.mark.parametrize("wd", [0.0, 0.01])
@pytest.mark.parametrize("jitted", [False, True])
def test_fused_adam_flat(nb, wd, jitted):
    """Fused whole-model Adam vs the ref.py oracle, in raw interpret mode
    and under jit (compiled XLA graph of the interpreted kernel — the same
    call compiles to Mosaic on TPU)."""
    n = nb * BLOCK
    ks = keys(4)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    m = jax.random.normal(ks[2], (n,)) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (n,))) * 0.01
    lr, b1, b2, eps = 3e-3, 0.9, 0.999, 1e-8
    c1, c2 = 1 - b1 ** 4, 1 - b2 ** 4          # as if at step t=4

    def call(p, g, m, v):
        return K.fused_adam_flat(p, g, m, v, lr, b1, b2, eps, wd, c1, c2)

    fn = jax.jit(call) if jitted else call
    VK.reset_launch_count()
    po, mo, vo = fn(p, g, m, v)
    assert VK.launch_count() == 1              # ONE launch, whole buffer
    pr, mr, vr = R.adam_update(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                               c1=c1, c2=c2, weight_decay=wd)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(vo), np.asarray(vr),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("n_replicas", [1, 4])
@pytest.mark.parametrize("jitted", [False, True])
def test_fused_easgd_flat(n_replicas, jitted):
    nb = 2
    ks = keys(2)
    c = jax.random.normal(ks[0], (nb * BLOCK,))
    x = jax.random.normal(ks[1], (n_replicas, nb * BLOCK))
    beta = 0.07

    def call(c, x):
        return K.fused_easgd_flat(c, x, beta)

    fn = jax.jit(call) if jitted else call
    VK.reset_launch_count()
    co, xo = fn(c, x)
    assert VK.launch_count() == 1              # center + ALL replicas, fused
    cr, xr = R.easgd_elastic(c, x, beta)
    np.testing.assert_allclose(np.asarray(co), np.asarray(cr),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr),
                               rtol=2e-6, atol=2e-6)


def test_fused_adam_flat_rejects_bad_shapes():
    p = jnp.zeros((BLOCK,))
    with pytest.raises(ValueError):
        K.fused_adam_flat(jnp.zeros((BLOCK + 1,)), p, p, p,
                          1e-3, 0.9, 0.999, 1e-8, 0.0, 0.1, 0.001)
    with pytest.raises(ValueError):
        K.fused_adam_flat(p, jnp.zeros((2 * BLOCK,)), p, p,
                          1e-3, 0.9, 0.999, 1e-8, 0.0, 0.1, 0.001)


@pytest.mark.parametrize("hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=True, window=64),
    dict(causal=False), dict(causal=True, softcap=20.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(hkv, kwargs, dtype):
    h, kv = hkv
    k1, k2, k3 = keys(3)
    q = (jax.random.normal(k1, (2, h, 256, 32), jnp.float32) * 0.3).astype(dtype)
    k = (jax.random.normal(k2, (2, kv, 256, 32), jnp.float32) * 0.3).astype(dtype)
    v = jax.random.normal(k3, (2, kv, 256, 32), jnp.float32).astype(dtype)
    got = K.flash_attention(q, k, v, q_block=128, kv_block=64, **kwargs)
    want = R.attention(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05 if dtype == jnp.bfloat16 else 2e-5,
                               atol=0.05 if dtype == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("T", [1, 7, 64])
@pytest.mark.parametrize("hd", [8, 64])
def test_wkv6(T, hd):
    k1, k2, k3, k4, k5 = keys(5)
    b, h = 2, 3
    r = jax.random.normal(k1, (b, h, T, hd)) * 0.4
    k = jax.random.normal(k2, (b, h, T, hd)) * 0.4
    v = jax.random.normal(k3, (b, h, T, hd))
    w = jax.nn.sigmoid(jax.random.normal(k4, (b, h, T, hd))) * 0.6 + 0.35
    u = jax.random.normal(k5, (h, hd)) * 0.2
    got = K.wkv6(r, k, v, w, u)
    want = R.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("di,ds,T", [(128, 8, 16), (256, 16, 33), (128, 4, 5)])
def test_mamba_scan(di, ds, T):
    ks = keys(6)
    b = 2
    u = jax.random.normal(ks[0], (b, T, di)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, di)))
    B = jax.random.normal(ks[2], (b, T, ds)) * 0.4
    C = jax.random.normal(ks[3], (b, T, ds)) * 0.4
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.3)
    D = jnp.ones((di,))
    got = K.mamba_scan(u, dt, B, C, A, D, d_block=128)
    want = R.mamba_scan(u, dt, B, C, A, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n", [7, 256, 8191, 100_000])
def test_quantize_roundtrip(n):
    x = jax.random.normal(keys(1)[0], (n,)) * 5.0
    q1, s1 = K.quantize_int8(x)
    q2, s2 = R.quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    d1 = K.dequantize_int8(q1, s1, n)
    d2 = R.dequantize_int8(q2, s2, n)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    # quantization error bounded by half a scale step per block
    err = np.abs(np.asarray(d1) - np.asarray(x))
    smax = np.asarray(s1).max()
    assert err.max() <= smax * 0.5 + 1e-6


@pytest.mark.parametrize("n,tau", [(100, 0.5), (9000, 1.5)])
def test_threshold_sparsify(n, tau):
    x = jax.random.normal(keys(1)[0], (n,)) * 2
    k1, r1 = K.threshold_sparsify(x, tau)
    k2, r2 = R.threshold_sparsify(x, tau)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2))
    # exact error-feedback identity
    np.testing.assert_allclose(np.asarray(k1 + r1), np.asarray(x))
