"""Functional optimizers (pytree in/out, fully shardable — every state leaf
inherits its parameter's sharding, so FSDP covers optimizer state too).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


@dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState]:
        t = state.step + 1
        lr = self.lr(t) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        def upd(g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            return m, v

        mv = jax.tree.map(upd, grads, state.m, state.v)
        m = jax.tree.map(lambda x: x[0], mv,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda x: x[1], mv,
                         is_leaf=lambda x: isinstance(x, tuple))
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def delta(p, mm, vv):
            step = lr * (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay:
                step = step + lr * self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype)

        new_params = jax.tree.map(delta, params, m, v)
        return new_params, OptState(step=t, m=m, v=v)

    # -- flat path (core/flat.py): m/v as two extra lanes of the bus --------

    def init_flat(self, fp) -> "FlatOptState":
        """Zero moments sharing ``fp``'s TreeSpec (one bus, three lanes)."""
        from repro.core.flat import init_opt_state
        return init_opt_state(fp.spec)

    def update_flat(self, grad_buf, state: "FlatOptState", fp, *,
                    use_kernel: bool = False):
        """Adam over the whole model as ONE pass over the flat bus.

        ``grad_buf`` is a [spec.padded] buffer (flatten_like of the grad
        tree, or the autodiff gradient of a loss taken w.r.t. the buffer —
        either way the tail is zero, which the update preserves).  The op
        order matches ``update`` exactly, so for f32 trees the result is
        bit-identical to the per-leaf path.  With ``use_kernel=True`` the
        fused Pallas kernel performs p/m/v in a single launch for the
        whole model (one HBM pass over four streams instead of one
        pallas_call per leaf)."""
        from repro.core.flat import FlatOptState
        t = state.step + 1
        lr = self.lr(t) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)
        if use_kernel:
            from repro.kernels import ops as K
            new_buf, m, v = K.fused_adam_flat(
                fp.buf, grad_buf, state.m, state.v, lr, b1, b2, self.eps,
                self.weight_decay, c1, c2)
        else:
            # the jnp path IS the ref.py oracle (one definition, no drift)
            from repro.kernels import ref as R
            new_buf, m, v = R.adam_update(
                fp.buf, grad_buf, state.m, state.v, lr=lr, b1=b1, b2=b2,
                eps=self.eps, c1=c1, c2=c2,
                weight_decay=self.weight_decay)
        return fp.with_buf(new_buf), FlatOptState(m=m, v=v, step=t,
                                                  spec=state.spec)

    def update_flat_sharded(self, grad_buf, state: "FlatOptState", fp, *,
                            mesh, axis: str = "pod",
                            use_kernel: bool = False):
        """``update_flat`` on the pod mesh: the (p, g, m, v) lanes are
        partitioned into contiguous per-device segments (ShardedTreeSpec)
        and the fused Adam update runs per shard under shard_map — no
        gather, and bit-identical to the single-host flat pass (the update
        is elementwise over the bus; scalars are replicated)."""
        from repro.core.flat import FlatOptState
        from repro.runtime.sharding import sharded_adam_update_flat
        t = state.step + 1
        lr = self.lr(t) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)
        new_buf, m, v = sharded_adam_update_flat(
            fp.buf, grad_buf, state.m, state.v, lr, b1, b2, self.eps,
            self.weight_decay, c1, c2, mesh, axis, use_kernel=use_kernel)
        return fp.with_buf(new_buf), FlatOptState(m=m, v=v, step=t,
                                                  spec=state.spec)


def flat_opt_from_tree(state: OptState, spec) -> "FlatOptState":
    """Lift a per-leaf OptState onto the bus layout ``spec`` (checkpoint /
    migration boundary; m and v must share the params' tree structure)."""
    from repro.core.flat import FlatOptState, flatten_like
    return FlatOptState(m=flatten_like(state.m, spec),
                        v=flatten_like(state.v, spec),
                        step=state.step, spec=spec)


def flat_opt_to_tree(fos: "FlatOptState") -> OptState:
    """Inverse boundary: per-leaf OptState view of the flat lanes."""
    return OptState(step=fos.step, m=fos.leaf_m(), v=fos.leaf_v())


@dataclass(frozen=True)
class Sgd:
    lr: float | Callable = 1e-2
    momentum: float = 0.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        m = jax.tree.map(zeros, params) if self.momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), m=m, v=None)

    def update(self, grads, state: OptState, params):
        t = state.step + 1
        lr = self.lr(t) if callable(self.lr) else self.lr
        if self.momentum:
            m = jax.tree.map(lambda mm, g: self.momentum * mm
                             + g.astype(jnp.float32), state.m, grads)
            new = jax.tree.map(lambda p, mm: (p.astype(jnp.float32) - lr * mm
                                              ).astype(p.dtype), params, m)
            return new, OptState(step=t, m=m, v=None)
        new = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                         - lr * g.astype(jnp.float32)
                                         ).astype(p.dtype), params, grads)
        return new, OptState(step=t, m=None, v=None)


def linear_warmup(base_lr: float, warmup: int) -> Callable:
    def f(t):
        return base_lr * jnp.minimum(1.0, t.astype(jnp.float32) / warmup)
    return f


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def f(t):
        t = t.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, t / warmup)
        prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(t < warmup, warm, base_lr * cos)
    return f
