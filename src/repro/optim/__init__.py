from repro.optim.optimizers import (Adam, OptState, Sgd, clip_by_global_norm,
                                    cosine_schedule, linear_warmup)

__all__ = ["Adam", "Sgd", "OptState", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup"]
