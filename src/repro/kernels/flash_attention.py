"""Blocked online-softmax attention (flash) forward kernel.

Supports GQA (kv-head mapping via BlockSpec index maps — no KV repeat in
memory), causal masking, sliding windows and logit soft-capping.  Grid is
(batch, q_heads, q_blocks); K/V rides fully in VMEM per (batch, kv_head)
(whole-context tiles are fine to ~16k x 128 bf16; longer contexts use the
XLA blocked path — see models/layers.py — or a multi-pass variant).

MXU alignment: q/kv blocks are multiples of 128; accumulation in f32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, causal: bool,
                 window, softcap, scale: float, seq_kv: int, q_block: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale             # [qb, hd]
    qb, hd = q.shape
    nk = seq_kv // kv_block
    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)

    if causal:
        # only kv blocks whose start <= last query position
        nk_needed = jnp.minimum(
            nk, ((qi + 1) * q_block + kv_block - 1) // kv_block)
    else:
        nk_needed = nk

    def body(j, carry):
        m, l, acc = carry
        # scalar positions must be pl.dslice(0, 1), not bare Python ints —
        # the state-discharge rule only accepts Slice/array indices
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.ds(j * kv_block, kv_block),
                            slice(None)))[0, 0].astype(jnp.float32)  # [kb, hd]
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.ds(j * kv_block, kv_block),
                            slice(None)))[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = j * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1)
        mask = None
        if causal:
            mask = q_pos >= k_pos
        if window is not None:
            wm = k_pos > q_pos - window
            mask = wm if mask is None else mask & wm
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + p.sum(axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    m0 = jnp.full((qb, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb, 1), jnp.float32)
    a0 = jnp.zeros((qb, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk_needed, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window=None, softcap=None,
                    q_block: int = 256, kv_block: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [b, h, sq, hd]; k, v: [b, kvh, skv, hd] -> [b, h, sq, hd]."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    assert h % kvh == 0
    group = h // kvh
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0 and skv % kv_block == 0
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(
        _attn_kernel, kv_block=kv_block, causal=causal, window=window,
        softcap=softcap, scale=scale, seq_kv=skv, q_block=q_block)
    return pl.pallas_call(
        kern,
        grid=(b, h, sq // q_block),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, skv, hd),
                         lambda bi, hi, qi, g=group: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, skv, hd),
                         lambda bi, hi, qi, g=group: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
