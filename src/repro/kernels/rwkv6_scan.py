"""WKV6 recurrence kernel (RWKV-6 "Finch" time-mix core).

    out_t[j] = sum_i r_t[i] * (S[i,j] + u[i] k_t[i] v_t[j])
    S[i,j]  <- w_t[i] * S[i,j] + k_t[i] v_t[j]

Grid is (batch, heads); the [hd, hd] matrix state lives in registers/VMEM
for the whole sequence — the recurrence never round-trips HBM (the CUDA
kernel the paper's family uses does the same in shared memory; on TPU the
VPU processes the rank-1 updates).  Time is walked with a fori_loop; r/k/v
and the per-step decay arrive as whole-sequence VMEM tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, *, seq: int):
    u = u_ref[0].astype(jnp.float32)                         # [hd]
    hd = u.shape[0]

    # NOTE: scalar positions must be pl.dslice(0, 1), not bare Python ints —
    # the state-discharge rule only accepts Slice/array indices.
    _01 = (pl.dslice(0, 1), pl.dslice(0, 1))

    def step(t, S):
        r = pl.load(r_ref, _01 + (pl.ds(t, 1), slice(None)))[0, 0, 0] \
            .astype(jnp.float32)                             # [hd]
        k = pl.load(k_ref, _01 + (pl.ds(t, 1), slice(None)))[0, 0, 0] \
            .astype(jnp.float32)
        v = pl.load(v_ref, _01 + (pl.ds(t, 1), slice(None)))[0, 0, 0] \
            .astype(jnp.float32)
        w = pl.load(w_ref, _01 + (pl.ds(t, 1), slice(None)))[0, 0, 0] \
            .astype(jnp.float32)                             # decay in (0,1)
        kv = k[:, None] * v[None, :]                         # [hd, hd]
        out = ((S + u[:, None] * kv) * r[:, None]).sum(axis=0)
        pl.store(o_ref, _01 + (pl.ds(t, 1), slice(None)),
                 out[None, None, None, :].astype(o_ref.dtype))
        return w[:, None] * S + kv

    S0 = jnp.zeros((hd, hd), jnp.float32)
    jax.lax.fori_loop(0, seq, step, S0)


def wkv6(r, k, v, w, u, *, interpret: bool = True) -> jnp.ndarray:
    """r/k/v: [b, h, T, hd]; w: [b, h, T, hd] decay in (0,1); u: [h, hd].
    Returns out [b, h, T, hd]."""
    b, h, T, hd = r.shape
    import functools
    kern = functools.partial(_wkv6_kernel, seq=T)
    return pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, T, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, hd), lambda bi, hi: (hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, hd), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, T, hd), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
