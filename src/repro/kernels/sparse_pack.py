"""Fused quantize+pack: sparse wire-frame body in ONE launch.

The encode leg of the compressed upload path used to materialize three
intermediate host arrays (int8 values, f32 scales, int32 indices) and
concatenate their bytes in Python.  This kernel writes the wire-frame
body layout directly:

    values(int8)[k] || scales(f32)[ceil(k/block)] || indices(int32)[k]

as ONE uint8 buffer, quantizing on the way (per-block symmetric int8, the
same math as kernels/quantize.py), so transfer/wire.py::encode_sparse
does a single device->host transfer and computes crc32 over the packed
buffer.  The int8 q and f32 scales also come back as device arrays — the
compress path needs them for the error-feedback dequantize, so one launch
serves both legs.

Byte layout relies on bitcast_convert_type's trailing-byte-dim semantics,
which is the host's endianness (little-endian everywhere we run) — the
same bytes numpy ``.tobytes()`` produces, which is what the frame format
pins (transfer/wire.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.vc_asgd_update import _note_launch

QBLOCK = 256


def _pack_kernel(sel_ref, idx_ref, body_ref, q_ref, s_ref, *, k, block):
    ng = sel_ref.shape[0]
    sel = sel_ref[...].astype(jnp.float32)                 # [ng, block]
    scale = jnp.maximum(jnp.max(jnp.abs(sel), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(sel / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale[:, 0]
    qb = jax.lax.bitcast_convert_type(q.reshape(-1)[:k], jnp.uint8)
    sb = jax.lax.bitcast_convert_type(scale[:, 0], jnp.uint8).reshape(-1)
    ib = jax.lax.bitcast_convert_type(idx_ref[...], jnp.uint8).reshape(-1)
    body_ref[0:k] = qb
    body_ref[k:k + 4 * ng] = sb
    body_ref[k + 4 * ng:k + 4 * ng + 4 * k] = ib


def _pack_only_kernel(q_ref, s_ref, idx_ref, body_ref, *, k, ng):
    qb = jax.lax.bitcast_convert_type(q_ref[...], jnp.uint8)
    sb = jax.lax.bitcast_convert_type(s_ref[...], jnp.uint8).reshape(-1)
    ib = jax.lax.bitcast_convert_type(idx_ref[...], jnp.uint8).reshape(-1)
    body_ref[0:k] = qb
    body_ref[k:k + 4 * ng] = sb
    body_ref[k + 4 * ng:k + 4 * ng + 4 * k] = ib


def pack_body(q: jnp.ndarray, scales: jnp.ndarray, idx: jnp.ndarray, *,
              interpret: bool = True):
    """Pack an EXISTING payload (q int8 [k], scales f32 [ng], idx int32
    [k]) into the wire body in one launch.  Pure bitcast+copy — zero
    arithmetic, so the bytes are exactly the payload arrays' bytes (the
    encode leg must ship compress_flat's own scales bit-for-bit; any
    re-quantize can drift a ULP across compilation contexts)."""
    k = int(q.size)
    ng = int(scales.size)
    nbytes = k + 4 * ng + 4 * k
    _note_launch()
    (body,) = pl.pallas_call(
        functools.partial(_pack_only_kernel, k=k, ng=ng),
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_shape=[jax.ShapeDtypeStruct((nbytes,), jnp.uint8)],
        interpret=interpret,
    )(q.astype(jnp.int8), scales.astype(jnp.float32), idx.astype(jnp.int32))
    return body


def quantize_pack(sel: jnp.ndarray, idx: jnp.ndarray, *, block: int = QBLOCK,
                  interpret: bool = True):
    """Quantize the selected values and pack the full sparse frame body in
    one launch.  Returns (body uint8 [k + 4*ng + 4*k], q int8 [ng*block]
    padded, scales f32 [ng]) — slice q to [:k] for payload use."""
    k = int(sel.size)
    ng = -(-k // block)
    pad = ng * block - k
    sf = sel.reshape(-1).astype(jnp.float32)
    if pad:
        sf = jnp.pad(sf, (0, pad))
    sf = sf.reshape(ng, block)
    nbytes = k + 4 * ng + 4 * k
    _note_launch()
    body, q, scales = pl.pallas_call(
        functools.partial(_pack_kernel, k=k, block=block),
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY),
                   pl.BlockSpec(memory_space=pl.ANY),
                   pl.BlockSpec(memory_space=pl.ANY)],
        out_shape=[jax.ShapeDtypeStruct((nbytes,), jnp.uint8),
                   jax.ShapeDtypeStruct((ng, block), jnp.int8),
                   jax.ShapeDtypeStruct((ng,), jnp.float32)],
        interpret=interpret,
    )(sf, idx.astype(jnp.int32))
    return body, q.reshape(-1), scales
