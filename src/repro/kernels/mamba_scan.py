"""Selective-scan (Mamba) recurrence kernel.

    h_t = exp(dt_t * A) (.) h_{t-1} + (dt_t * u_t) B_t
    y_t = C_t . h_t + D (.) u_t

Grid is (batch, d_inner blocks); each program keeps its [dblk, ds] state
slab resident and walks time with a fori_loop — the state never leaves
VMEM, matching the CUDA kernel's SRAM-resident design on TPU terms.
dblk is a multiple of 128 (lane width); ds = 16 for the assigned configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mamba_kernel(u_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, o_ref, *,
                  seq: int):
    A = A_ref[...].astype(jnp.float32)                     # [dblk, ds]
    D = D_ref[...].reshape(-1).astype(jnp.float32)         # [dblk]
    dblk, ds = A.shape

    # NOTE: scalar positions must be pl.dslice(0, 1), not bare Python ints —
    # the state-discharge rule only accepts Slice/array indices.
    def step(t, h):
        u = pl.load(u_ref, (pl.dslice(0, 1), pl.ds(t, 1), slice(None)))[0, 0] \
            .astype(jnp.float32)                           # [dblk]
        dt = pl.load(dt_ref, (pl.dslice(0, 1), pl.ds(t, 1), slice(None)))[0, 0] \
            .astype(jnp.float32)
        B = pl.load(B_ref, (pl.dslice(0, 1), pl.ds(t, 1), slice(None)))[0, 0] \
            .astype(jnp.float32)                           # [ds]
        C = pl.load(C_ref, (pl.dslice(0, 1), pl.ds(t, 1), slice(None)))[0, 0] \
            .astype(jnp.float32)
        a_bar = jnp.exp(dt[:, None] * A)                   # [dblk, ds]
        h = a_bar * h + (dt * u)[:, None] * B[None, :]
        y = (h * C[None, :]).sum(axis=1) + D * u
        pl.store(o_ref, (pl.dslice(0, 1), pl.ds(t, 1), slice(None)),
                 y[None, None, :].astype(o_ref.dtype))
        return h

    jax.lax.fori_loop(0, seq, step, jnp.zeros((dblk, ds), jnp.float32))


def mamba_scan(u, dt, B, C, A, D, *, d_block: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """u/dt: [b, T, di]; B/C: [b, T, ds]; A: [di, ds]; D: [di] -> y [b, T, di]."""
    b, T, di = u.shape
    ds = B.shape[-1]
    d_block = min(d_block, di)
    assert di % d_block == 0
    kern = functools.partial(_mamba_kernel, seq=T)
    return pl.pallas_call(
        kern,
        grid=(b, di // d_block),
        in_specs=[
            pl.BlockSpec((1, T, d_block), lambda bi, ci: (bi, 0, ci)),
            pl.BlockSpec((1, T, d_block), lambda bi, ci: (bi, 0, ci)),
            pl.BlockSpec((1, T, ds), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, T, ds), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((d_block, ds), lambda bi, ci: (ci, 0)),
            pl.BlockSpec((1, d_block), lambda bi, ci: (0, ci)),
        ],
        out_specs=pl.BlockSpec((1, T, d_block), lambda bi, ci: (bi, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((b, T, di), u.dtype),
        interpret=interpret,
    )(u, dt, B, C, A, D[None])
