"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel shape/dtype sweeps assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def vc_asgd_lerp(server, client, alpha):
    a = jnp.asarray(alpha, jnp.float32)
    return (a * server.astype(jnp.float32)
            + (1 - a) * client.astype(jnp.float32)).astype(server.dtype)


def vc_asgd_dc_lerp(server, client, grad, backup, alpha, lam=0.04):
    a = jnp.asarray(alpha, jnp.float32)
    s = server.astype(jnp.float32)
    c = client.astype(jnp.float32)
    g = grad.astype(jnp.float32)
    b = backup.astype(jnp.float32)
    c_comp = c + lam * g * g * (s - b)
    return (a * s + (1 - a) * c_comp).astype(server.dtype)


def adam_update(p, g, m, v, *, lr, b1, b2, eps, c1, c2, weight_decay=0.0):
    """One Adam step (bias-corrected; c1 = 1-b1^t, c2 = 1-b2^t precomputed
    by the caller, like the fused kernel's scalar lane).  Returns
    (p', m', v') with m/v in f32 and p' in p's dtype."""
    g = g.astype(jnp.float32)
    m = b1 * m.astype(jnp.float32) + (1 - b1) * g
    v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
    step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - step).astype(p.dtype), m, v


def easgd_elastic(center, replicas, beta):
    """Simultaneous elastic update (Zhang et al. [17], pod-scale round):
    center [N], replicas [n, N] ->
      center' = center + beta * sum_j (x_j - center)
      x_j'    = x_j    - beta * (x_j - center)
    """
    c = center.astype(jnp.float32)
    x = replicas.astype(jnp.float32)
    diff = x - c[None, :]
    c_new = c + beta * diff.sum(axis=0)
    x_new = x - beta * diff
    return c_new.astype(center.dtype), x_new.astype(replicas.dtype)


def attention(q, k, v, *, causal=True, window=None, softcap=None):
    """q: [b, h, sq, hd]; k/v: [b, kvh, skv, hd] (GQA repeat)."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (qp >= kp)
    if window is not None:
        mask = mask & (kp > qp - window)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def wkv6(r, k, v, w, u):
    """Sequential reference. r/k/v/w: [b, h, T, hd]; u: [h, hd]."""
    b, h, T, hd = r.shape
    S = jnp.zeros((b, h, hd, hd), jnp.float32)
    outs = []
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    for t in range(T):
        kv = kf[:, :, t, :, None] * vf[:, :, t, None, :]
        out = ((S + uf[None, :, :, None] * kv)
               * rf[:, :, t, :, None]).sum(axis=2)
        outs.append(out)
        S = wf[:, :, t, :, None] * S + kv
    return jnp.stack(outs, axis=2).astype(r.dtype)


def mamba_scan(u, dt, B, C, A, D):
    """Sequential reference. u/dt: [b, T, di]; B/C: [b, T, ds]; A: [di, ds]."""
    b, T, di = u.shape
    h = jnp.zeros((b, di, A.shape[1]), jnp.float32)
    uf, dtf, Bf, Cf = (t.astype(jnp.float32) for t in (u, dt, B, C))
    outs = []
    for t in range(T):
        a_bar = jnp.exp(dtf[:, t, :, None] * A)
        h = a_bar * h + (dtf[:, t] * uf[:, t])[:, :, None] * Bf[:, t, None, :]
        y = (h * Cf[:, t, None, :]).sum(-1) + D * uf[:, t]
        outs.append(y)
    return jnp.stack(outs, axis=1).astype(u.dtype)


def quantize_int8(x, block: int = 256):
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_int8(q, scales, n, block: int = 256):
    pad = (-n) % block
    qf = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (qf * scales[:, None]).reshape(-1)[:n]


def threshold_sparsify(x, tau):
    keep = jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))
    return keep, x - keep


def blocked_topk_stats(x, lo, block: int = 8 * 1024):
    """Per-block packed candidate words + counts (kernels/topk_mask.py).
    lo is a uint32 magnitude-bits bracket, > 0."""
    n = x.size
    nb = -(-n // block)
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, nb * block - n))
    bits = jax.lax.bitcast_convert_type(jnp.abs(xf), jnp.uint32)
    keep = (bits >= jnp.uint32(lo)).reshape(nb, block // 32, 32)
    pow2 = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    words = jnp.sum(jnp.where(keep, pow2[None, None, :], jnp.uint32(0)),
                    axis=2, dtype=jnp.uint32)
    return words, keep.reshape(nb, -1).sum(axis=1).astype(jnp.int32)


def threshold_sparsify_exact(x, tau, tie_start, tie_budget,
                             block: int = 8 * 1024):
    """Exact-k sparsify: |x| > tau always kept; |x| == tau kept while the
    global tie rank (block prefix + within-block rank) < tie_budget."""
    n = x.size
    nb = -(-n // block)
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32),
                 (0, nb * block - n)).reshape(nb, block)
    mag = jnp.abs(xf)
    tau = jnp.float32(tau)
    gt = mag > tau
    tie = mag == tau
    tie_i = tie.astype(jnp.int32)
    rank = (jnp.asarray(tie_start, jnp.int32)[:, None]
            + jnp.cumsum(tie_i, axis=1) - tie_i)
    keep_m = gt | (tie & (rank < jnp.int32(tie_budget)))
    kept = jnp.where(keep_m, xf, 0.0)
    unpad = lambda t: t.reshape(-1)[:n].reshape(x.shape)
    return unpad(kept), unpad(xf - kept)


def pack_body(q, scales, idx):
    """Sparse wire-frame body bytes: values(int8) || scales(f32) ||
    indices(int32), the layout transfer/wire.py pins (little-endian)."""
    qb = jax.lax.bitcast_convert_type(q.astype(jnp.int8), jnp.uint8)
    sb = jax.lax.bitcast_convert_type(scales.astype(jnp.float32),
                                      jnp.uint8).reshape(-1)
    ib = jax.lax.bitcast_convert_type(idx.astype(jnp.int32),
                                      jnp.uint8).reshape(-1)
    return jnp.concatenate([qb, sb, ib])


def quantize_pack(sel, idx, block: int = 256):
    """Fused quantize+pack oracle (kernels/sparse_pack.py): returns
    (body uint8, q int8 padded to ng*block, scales f32 [ng])."""
    k = sel.size
    ng = -(-k // block)
    q, scales = quantize_int8(sel, block)
    qpad = jnp.pad(q, (0, ng * block - k))
    return pack_body(q, scales, idx), qpad, scales
