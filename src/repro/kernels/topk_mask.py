"""Fused threshold-sparsify + error-feedback kernel.

Top-k selection itself is a global op (jnp.lax.top_k over the flat delta);
given the resulting magnitude threshold tau this kernel does the two
memory-bound passes in one: the transmitted (masked) values and the
error-feedback residual (what stays behind for the next round).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _mask_kernel(scal_ref, x_ref, keep_ref, res_ref):
    tau = scal_ref[0]
    x = x_ref[...].astype(jnp.float32)
    keep = jnp.where(jnp.abs(x) >= tau, x, 0.0)
    keep_ref[...] = keep.astype(keep_ref.dtype)
    res_ref[...] = (x - keep).astype(res_ref.dtype)


def threshold_sparsify(x: jnp.ndarray, tau, *, interpret: bool = True):
    """Returns (kept, residual): kept has |x| >= tau entries, residual the
    rest; kept + residual == x exactly."""
    n = x.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    xf = x.reshape(-1)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xf = xf.reshape(nb, BLOCK)
    scal = jnp.asarray([tau], jnp.float32)
    kept, res = pl.pallas_call(
        _mask_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, BLOCK), x.dtype),
                   jax.ShapeDtypeStruct((nb, BLOCK), x.dtype)],
        interpret=interpret,
    )(scal, xf)
    unpad = lambda t: t.reshape(-1)[:n].reshape(x.shape)
    return unpad(kept), unpad(res)
