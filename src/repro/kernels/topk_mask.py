"""Blocked top-k: magnitude statistics + threshold-sparsify kernels.

The blocked top-k pipeline (core/compression.py::select_topk documents the
algorithm) splits global magnitude top-k into three stages:

  1. ``blocked_topk_stats`` — ONE memory-bound pass over the flat delta:
     each grid block packs its ``|x| >= lo`` candidate mask into uint32
     words (bit i of word w == element w*32+i survives the bracket) and
     emits its candidate count.  The packed words are the per-block
     magnitude statistics everything downstream runs on — N/32 words
     instead of N floats.
  2. a tiny host-side refinement (jnp over <= k + margin candidates) that
     extracts candidate positions from the packed words and picks the
     EXACT global threshold tau plus the tie budget,
  3. ``threshold_sparsify_exact`` — the kept/residual emit pass, exact-k
     under ties: a block keeps ``|x| > tau`` always and ``|x| == tau``
     only while the global tie rank (per-block tie prefix ``tie_start``
     plus the within-block rank) stays below ``tie_budget``.

``threshold_sparsify`` (the original ``|x| >= tau`` form) stays as the
thresh-only pass; it keeps MORE than k entries when magnitudes tie at tau,
which is why the exact-k variant exists.

``blocked_topk_sparsify`` chains the three stages end to end (two kernel
launches + the tiny refinement) and falls back to a dense ``lax.top_k``
mask when the sampled bracket misses — exact either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.vc_asgd_update import _note_launch

BLOCK = 8 * 1024
WORDS = BLOCK // 32        # packed uint32 candidate words per block


def _mask_kernel(scal_ref, x_ref, keep_ref, res_ref):
    tau = scal_ref[0]
    x = x_ref[...].astype(jnp.float32)
    keep = jnp.where(jnp.abs(x) >= tau, x, 0.0)
    keep_ref[...] = keep.astype(keep_ref.dtype)
    res_ref[...] = (x - keep).astype(res_ref.dtype)


def threshold_sparsify(x: jnp.ndarray, tau, *, interpret: bool = True):
    """Returns (kept, residual): kept has |x| >= tau entries, residual the
    rest; kept + residual == x exactly.  NOT exact-k under ties at tau —
    use threshold_sparsify_exact for deterministic-k."""
    n = x.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    xf = x.reshape(-1)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xf = xf.reshape(nb, BLOCK)
    scal = jnp.asarray([tau], jnp.float32)
    _note_launch()
    kept, res = pl.pallas_call(
        _mask_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, BLOCK), x.dtype),
                   jax.ShapeDtypeStruct((nb, BLOCK), x.dtype)],
        interpret=interpret,
    )(scal, xf)
    unpad = lambda t: t.reshape(-1)[:n].reshape(x.shape)
    return unpad(kept), unpad(res)


def _stats_kernel(scal_ref, x_ref, words_ref, cnt_ref):
    lo = scal_ref[0]
    x = x_ref[...].astype(jnp.float32)                       # [1, BLOCK]
    bits = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.uint32)
    keep = bits >= lo
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, WORDS, 32), 2)
    packed = jnp.sum(jnp.where(keep.reshape(1, WORDS, 32),
                               jnp.uint32(1) << lane, jnp.uint32(0)),
                     axis=2, dtype=jnp.uint32)
    words_ref[...] = packed
    cnt_ref[...] = jnp.sum(keep.astype(jnp.int32), axis=1)


def blocked_topk_stats(x: jnp.ndarray, lo, *, interpret: bool = True):
    """ONE pass of per-block magnitude statistics for blocked top-k.

    ``lo`` is a uint32 magnitude-bits bracket (bitcast of a non-negative
    f32 — monotone, so bit compares == magnitude compares); it must be
    > 0 so zero tail padding never counts as a candidate.  Returns
    (words [nb, BLOCK//32] uint32 packed candidate masks,
     counts [nb] int32 per-block candidate counts)."""
    n = x.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    xf = x.reshape(-1)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xf = xf.reshape(nb, BLOCK)
    scal = jnp.asarray([lo], jnp.uint32)
    _note_launch()
    words, counts = pl.pallas_call(
        _stats_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, WORDS), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, WORDS), jnp.uint32),
                   jax.ShapeDtypeStruct((nb,), jnp.int32)],
        interpret=interpret,
    )(scal, xf)
    return words, counts


def _exact_kernel(tau_ref, bud_ref, ts_ref, x_ref, keep_ref, res_ref):
    tau = tau_ref[0]
    budget = bud_ref[0]
    start = ts_ref[0]
    x = x_ref[...].astype(jnp.float32)
    mag = jnp.abs(x)
    gt = mag > tau
    tie = mag == tau
    tie_i = tie.astype(jnp.int32)
    rank = start + jnp.cumsum(tie_i, axis=1) - tie_i   # global tie rank
    keep_m = gt | (tie & (rank < budget))
    kept = jnp.where(keep_m, x, 0.0)
    keep_ref[...] = kept.astype(keep_ref.dtype)
    res_ref[...] = (x - kept).astype(res_ref.dtype)


def threshold_sparsify_exact(x: jnp.ndarray, tau, tie_start, tie_budget, *,
                             interpret: bool = True):
    """Exact-k kept/residual emit: keeps |x| > tau unconditionally and
    |x| == tau only while the global tie rank stays below ``tie_budget``
    (``tie_start[b]`` = ties in blocks before b; lowest flat index wins,
    lax.top_k's tie rule).  kept + residual == x exactly."""
    n = x.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    xf = x.reshape(-1)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xf = xf.reshape(nb, BLOCK)
    tau_s = jnp.asarray([tau], jnp.float32)
    bud_s = jnp.asarray([tie_budget], jnp.int32)
    ts = jnp.asarray(tie_start, jnp.int32).reshape(nb)
    _note_launch()
    kept, res = pl.pallas_call(
        _exact_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, BLOCK), x.dtype),
                   jax.ShapeDtypeStruct((nb, BLOCK), x.dtype)],
        interpret=interpret,
    )(tau_s, bud_s, ts, xf)
    unpad = lambda t: t.reshape(-1)[:n].reshape(x.shape)
    return unpad(kept), unpad(res)


@functools.partial(jax.jit, static_argnames=("k", "cap", "nb"))
def _refine(x, words, counts, k: int, cap: int, nb: int):
    """Tiny refinement: candidate positions from the packed words, exact
    tau + tie budget + per-block tie prefix, then the exact emit pass.
    All O(cap) work besides the emit launch."""
    bits = jax.lax.bitcast_convert_type(jnp.abs(x.reshape(-1)), jnp.uint32)
    flat_words = words.reshape(-1)
    nw = flat_words.shape[0]
    cum = jnp.cumsum(jax.lax.population_count(flat_words).astype(jnp.int32))
    c_lo = cum[-1]
    ranks = jnp.arange(1, cap + 1, dtype=jnp.int32)
    widx = jnp.minimum(jnp.searchsorted(cum, ranks, side="left"), nw - 1)
    base = jnp.where(widx > 0, cum[jnp.maximum(widx - 1, 0)], 0)
    r_in = ranks - base
    word = flat_words[widx]
    pos = jnp.zeros_like(r_in)
    for shift in (16, 8, 4, 2, 1):
        trial = pos + shift
        below = jax.lax.population_count(
            word & ((jnp.uint32(1) << trial.astype(jnp.uint32))
                    - jnp.uint32(1))).astype(jnp.int32)
        pos = jnp.where(below < r_in, trial, pos)
    ext = widx * 32 + pos                                 # [cap] ascending
    valid = ranks <= c_lo
    xbits = jnp.where(valid, bits[ext], jnp.uint32(0xFFFFFFFF))
    srt = jnp.sort(xbits)
    tau_bits = srt[c_lo - k]
    c_le = jnp.searchsorted(srt, tau_bits, side="right")
    budget = k - (c_lo - c_le)
    tau = jax.lax.bitcast_convert_type(tau_bits, jnp.float32)
    # per-block tie prefix from the candidate set (ties of tau are always
    # candidates: tau >= lo)
    tie = valid & (xbits == tau_bits)
    blk = ext // BLOCK
    per_blk = jnp.zeros((nb,), jnp.int32).at[blk].add(tie.astype(jnp.int32),
                                                      mode="drop")
    tie_start = jnp.cumsum(per_blk) - per_blk
    return tau, budget, tie_start


def blocked_topk_sparsify(x: jnp.ndarray, k: int, *, interpret: bool = True):
    """Exact global top-k (kept, residual) via the blocked pipeline:
    stats launch -> tiny refinement -> exact-k emit launch.  Falls back
    to a dense lax.top_k mask when the sampled bracket misses."""
    from repro.core import compression as C
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = int(k)
    if k + C._MARGIN >= n or n < C._MIN_FAST_N:
        idx = C.select_topk(flat, k)
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return (kept.reshape(x.shape), (flat - kept).reshape(x.shape))
    bits = jax.lax.bitcast_convert_type(jnp.abs(flat), jnp.uint32)
    stride = n // C._SAMPLE
    sample = jnp.sort(bits[::stride][:C._SAMPLE])
    frac = k / n
    sigma = int((C._SAMPLE * frac * (1.0 - frac)) ** 0.5) + 1
    off = min(C._SAMPLE - 1, (C._SAMPLE * k) // n + 6 * sigma + 64)
    lo = sample[C._SAMPLE - 1 - off]
    words, counts = blocked_topk_stats(flat, lo, interpret=interpret)
    c_lo = int(jnp.sum(counts))
    cap = k + C._MARGIN
    if not (k <= c_lo <= cap and int(lo) > 0):
        idx = C.select_topk(flat, k)                      # exact fallback
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return (kept.reshape(x.shape), (flat - kept).reshape(x.shape))
    nb = words.shape[0]
    tau, budget, tie_start = _refine(flat, words, counts, k, cap, nb)
    kept, res = threshold_sparsify_exact(flat, tau, tie_start, budget,
                                         interpret=interpret)
    return kept.reshape(x.shape), res.reshape(x.shape)
