"""Fused VC-ASGD server assimilation kernel (Eq. 1) — the paper's hot op.

The server update ``W_s <- a*W_s + (1-a)*W_c`` is purely memory-bound: at
LLM scale the whole parameter set must stream through the chip once per
assimilation.  The fusion opportunities are (a) the lerp itself, (b) the
optional DC-ASGD delay-compensation term, and (c) the staleness-damped
effective alpha — one HBM pass for all streams instead of several.

TPU adaptation (DESIGN.md §2): parameters are flattened to 1-D and tiled
into (1, 8192)-element VMEM blocks (multiples of the 8x128 vector tile);
the grid walks the flat buffer.  Scalars (alpha, lam) ride in ANY memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024            # elements per grid step; multiple of 8*128


def _lerp_kernel(scal_ref, s_ref, c_ref, o_ref):
    a = scal_ref[0]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (a * s + (1.0 - a) * c).astype(o_ref.dtype)


def _dc_lerp_kernel(scal_ref, s_ref, c_ref, g_ref, b_ref, o_ref):
    """Delay-compensated lerp; scal = [alpha, lam].  The client copy is
    first corrected by the diagonal-Hessian term lam*g*g*(W_s - W_backup)
    (Zheng et al. [18]), then assimilated."""
    a, lam = scal_ref[0], scal_ref[1]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    c_comp = c + lam * g * g * (s - b)
    o_ref[...] = (a * s + (1.0 - a) * c_comp).astype(o_ref.dtype)


def _blocked_call(kernel, scalars, arrays, *, interpret: bool):
    """Flatten every operand to [nb, BLOCK] (zero-padded) and run the grid."""
    x0 = arrays[0]
    n = x0.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n

    def prep(x):
        f = x.reshape(-1)
        if pad:
            f = jnp.pad(f, (0, pad))
        return f.reshape(nb, BLOCK)

    flats = [prep(x) for x in arrays]
    scal = jnp.stack([jnp.asarray(s, jnp.float32).reshape(()) for s in scalars])
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)) for _ in flats],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), x0.dtype),
        interpret=interpret,
    )(scal, *flats)
    out = out.reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(x0.shape)


def vc_asgd_lerp(server: jnp.ndarray, client: jnp.ndarray, alpha,
                 *, interpret: bool = True) -> jnp.ndarray:
    """W_s <- alpha*W_s + (1-alpha)*W_c, one fused pass."""
    return _blocked_call(_lerp_kernel, [alpha], [server, client],
                         interpret=interpret)


def vc_asgd_dc_lerp(server, client, grad, backup, alpha, lam=0.04,
                    *, interpret: bool = True) -> jnp.ndarray:
    """Fused DC-ASGD + lerp (one HBM pass over four streams)."""
    return _blocked_call(_dc_lerp_kernel, [alpha, lam],
                         [server, client, grad, backup], interpret=interpret)
