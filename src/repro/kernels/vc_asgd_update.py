"""Fused VC-ASGD server assimilation kernels (Eq. 1/2) — the paper's hot op.

The server update ``W_s <- a*W_s + (1-a)*W_c`` is purely memory-bound: at
LLM scale the whole parameter set must stream through the chip once per
assimilation.  The fusion opportunities are (a) the lerp itself, (b) the
optional DC-ASGD delay-compensation term, (c) the staleness-damped
effective alpha, and (d) the whole Eq. 2 multi-client reduction — one HBM
pass for all streams instead of several.

TPU adaptation (DESIGN.md §2): parameters ride the flat bus
(core/flat.py): one contiguous 1-D buffer, zero-padded to a BLOCK
multiple, tiled into (1, BLOCK)-element VMEM blocks (multiples of the
8x128 vector tile); the grid walks the flat buffer.  Scalars (alpha, lam,
Eq. 2 weights) ride in ANY memory.  The ``*_flat`` entry points take
pre-padded buffers and launch exactly ONE ``pallas_call`` for the whole
model; the legacy per-tensor entry points pad-and-reshape on the way in.

``launch_count()`` counts ``pallas_call`` invocations (trace-time) — the
benchmark/test evidence that the flat path is one launch per assimilation
while the per-leaf path is one per leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import BLOCK

_launches = 0


def launch_count() -> int:
    return _launches


def reset_launch_count() -> None:
    global _launches
    _launches = 0


def _note_launch() -> None:
    global _launches
    _launches += 1


def _lerp_kernel(scal_ref, s_ref, c_ref, o_ref):
    a = scal_ref[0]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (a * s + (1.0 - a) * c).astype(o_ref.dtype)


def _dc_lerp_kernel(scal_ref, s_ref, c_ref, g_ref, b_ref, o_ref):
    """Delay-compensated lerp; scal = [alpha, lam].  The client copy is
    first corrected by the diagonal-Hessian term lam*g*g*(W_s - W_backup)
    (Zheng et al. [18]), then assimilated."""
    a, lam = scal_ref[0], scal_ref[1]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    c_comp = c + lam * g * g * (s - b)
    o_ref[...] = (a * s + (1.0 - a) * c_comp).astype(o_ref.dtype)


def _assimilate_kernel(w_ref, s_ref, c_ref, o_ref, *, n_clients: int):
    """Eq. 2: acc = w0*s + sum_j w_{j+1}*c_j, accumulated in arrival order
    (bit-identical to folding Eq. 1) over one [n_clients, 1, BLOCK] tile."""
    acc = w_ref[0] * s_ref[...].astype(jnp.float32)          # [1, BLOCK]
    for j in range(n_clients):
        cj = pl.load(c_ref, (pl.dslice(j, 1), pl.dslice(0, 1),
                             slice(None)))[0]                # [1, BLOCK]
        acc = acc + w_ref[j + 1] * cj.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _adam_kernel(scal_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref,
                 vo_ref):
    """Fused Adam: m/v moment update + bias-corrected step + weight decay in
    one pass over four streams; scal = [lr, b1, b2, eps, wd, c1, c2] with
    c1 = 1-b1^t, c2 = 1-b2^t precomputed at trace time."""
    lr, b1, b2 = scal_ref[0], scal_ref[1], scal_ref[2]
    eps, wd = scal_ref[3], scal_ref[4]
    c1, c2 = scal_ref[5], scal_ref[6]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32)
    step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps) + lr * wd * p
    po_ref[...] = (p - step).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


def _easgd_kernel(scal_ref, c_ref, x_ref, co_ref, xo_ref, *, n_replicas: int):
    """Simultaneous elastic update over one [n_replicas, 1, BLOCK] tile:
    center moves by beta * sum_j (x_j - c), every replica moves toward the
    center by beta * (x_j - c) — one pass for the whole pod."""
    beta = scal_ref[0]
    c = c_ref[...].astype(jnp.float32)                       # [1, BLOCK]
    acc = jnp.zeros_like(c)
    for j in range(n_replicas):
        xj = pl.load(x_ref, (pl.dslice(j, 1), pl.dslice(0, 1),
                             slice(None)))[0].astype(jnp.float32)
        diff = xj - c
        acc = acc + diff
        pl.store(xo_ref, (pl.dslice(j, 1), pl.dslice(0, 1), slice(None)),
                 (xj - beta * diff).astype(xo_ref.dtype)[None])
    co_ref[...] = (c + beta * acc).astype(co_ref.dtype)


def _blocked_call(kernel, scalars, arrays, *, interpret: bool):
    """Flatten every operand to [nb, BLOCK] (zero-padded) and run the grid."""
    x0 = arrays[0]
    n = x0.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n

    def prep(x):
        f = x.reshape(-1)
        if pad:
            f = jnp.pad(f, (0, pad))
        return f.reshape(nb, BLOCK)

    flats = [prep(x) for x in arrays]
    scal = jnp.stack([jnp.asarray(s, jnp.float32).reshape(()) for s in scalars])
    _note_launch()
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)) for _ in flats],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), x0.dtype),
        interpret=interpret,
    )(scal, *flats)
    out = out.reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(x0.shape)


def vc_asgd_lerp(server: jnp.ndarray, client: jnp.ndarray, alpha,
                 *, interpret: bool = True) -> jnp.ndarray:
    """W_s <- alpha*W_s + (1-alpha)*W_c, one fused pass over one tensor."""
    return _blocked_call(_lerp_kernel, [alpha], [server, client],
                         interpret=interpret)


def vc_asgd_dc_lerp(server, client, grad, backup, alpha, lam=0.04,
                    *, interpret: bool = True) -> jnp.ndarray:
    """Fused DC-ASGD + lerp (one HBM pass over four streams)."""
    return _blocked_call(_dc_lerp_kernel, [alpha, lam],
                         [server, client, grad, backup], interpret=interpret)


# ---------------------------------------------------------------------------
# flat-bus entry points: pre-padded contiguous buffers, ONE launch each
# ---------------------------------------------------------------------------

def _check_flat(buf: jnp.ndarray) -> int:
    if buf.ndim != 1 or buf.size % BLOCK:
        raise ValueError(
            f"flat buffer must be 1-D and a BLOCK({BLOCK}) multiple, "
            f"got shape {buf.shape}")
    return buf.size // BLOCK


def vc_asgd_lerp_flat(server: jnp.ndarray, client: jnp.ndarray, alpha,
                      *, interpret: bool = True) -> jnp.ndarray:
    """Eq. 1 over the whole flat bus in one blocked grid (no pad/reshape)."""
    _check_flat(server)
    return _blocked_call(_lerp_kernel, [alpha], [server, client],
                         interpret=interpret)


def vc_asgd_dc_lerp_flat(server, client, grad, backup, alpha, lam=0.04,
                         *, interpret: bool = True) -> jnp.ndarray:
    """DC-ASGD variant riding the same single-launch flat pass."""
    _check_flat(server)
    return _blocked_call(_dc_lerp_kernel, [alpha, lam],
                         [server, client, grad, backup], interpret=interpret)


def assimilate_flat(server: jnp.ndarray, clients: jnp.ndarray, weights,
                    *, interpret: bool = True) -> jnp.ndarray:
    """Eq. 2 as ONE fused weighted reduction: server [N] + clients [n, N]
    -> [N] in a single ``pallas_call`` whose grid walks the flat buffer;
    each tile reduces all n client streams in arrival order (bit-identical
    to the per-leaf fold in f32).  ``weights`` = [w_server, w_0..w_{n-1}]
    (assimilation_weights or the staleness-damped variant)."""
    nb = _check_flat(server)
    n_clients = int(clients.shape[0])
    if clients.shape != (n_clients, server.size):
        raise ValueError(f"clients must be [n, {server.size}], "
                         f"got {clients.shape}")
    if len(weights) != n_clients + 1:
        raise ValueError(f"need {n_clients + 1} weights, got {len(weights)}")
    w = jnp.stack([jnp.asarray(x, jnp.float32).reshape(()) for x in weights])
    s2 = server.reshape(nb, BLOCK)
    c3 = clients.reshape(n_clients, nb, BLOCK)
    kern = functools.partial(_assimilate_kernel, n_clients=n_clients)
    _note_launch()
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((n_clients, 1, BLOCK), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), server.dtype),
        interpret=interpret,
    )(w, s2, c3)
    return out.reshape(-1)


def adam_update_flat(p, g, m, v, lr, b1, b2, eps, weight_decay, c1, c2,
                     *, interpret: bool = True):
    """Fused Adam over the whole flat bus: ONE ``pallas_call`` updates
    params + both moment lanes (optim/optimizers.py Adam.update_flat rides
    this).  All four operands are [padded] buffers sharing one TreeSpec;
    returns (p', m', v') buffers."""
    nb = _check_flat(p)
    for name, buf in (("grad", g), ("m", m), ("v", v)):
        if buf.shape != p.shape:
            raise ValueError(f"{name} lane must match params lane "
                             f"{p.shape}, got {buf.shape}")
    scal = jnp.stack([jnp.asarray(x, jnp.float32).reshape(())
                      for x in (lr, b1, b2, eps, weight_decay, c1, c2)])
    blk = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    _note_launch()
    po, mo, vo = pl.pallas_call(
        _adam_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY), blk, blk, blk, blk],
        out_specs=(blk, blk, blk),
        out_shape=(jax.ShapeDtypeStruct((nb, BLOCK), p.dtype),
                   jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
                   jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32)),
        interpret=interpret,
    )(scal, p.reshape(nb, BLOCK), g.reshape(nb, BLOCK),
      m.reshape(nb, BLOCK), v.reshape(nb, BLOCK))
    return po.reshape(-1), mo.reshape(-1), vo.reshape(-1)


def easgd_elastic_flat(center, replicas, beta, *, interpret: bool = True):
    """Fused elastic EASGD round: center [N] + replicas [n, N] -> updated
    (center', replicas') in ONE ``pallas_call`` over the flat bus (the pod
    baseline in core/baselines.py::EASGDFlatPod rides this)."""
    nb = _check_flat(center)
    n = int(replicas.shape[0])
    if replicas.shape != (n, center.size):
        raise ValueError(f"replicas must be [n, {center.size}], "
                         f"got {replicas.shape}")
    scal = jnp.asarray([beta], jnp.float32)
    kern = functools.partial(_easgd_kernel, n_replicas=n)
    _note_launch()
    co, xo = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((n, 1, BLOCK), lambda i: (0, i, 0)),
        ],
        out_specs=(pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((n, 1, BLOCK), lambda i: (0, i, 0))),
        out_shape=(jax.ShapeDtypeStruct((nb, BLOCK), center.dtype),
                   jax.ShapeDtypeStruct((n, nb, BLOCK), replicas.dtype)),
        interpret=interpret,
    )(scal, center.reshape(nb, BLOCK), replicas.reshape(n, nb, BLOCK))
    return co.reshape(-1), xo.reshape(n, -1)
