"""Fused VC-ASGD server assimilation kernels (Eq. 1/2) — the paper's hot op.

The server update ``W_s <- a*W_s + (1-a)*W_c`` is purely memory-bound: at
LLM scale the whole parameter set must stream through the chip once per
assimilation.  The fusion opportunities are (a) the lerp itself, (b) the
optional DC-ASGD delay-compensation term, (c) the staleness-damped
effective alpha, and (d) the whole Eq. 2 multi-client reduction — one HBM
pass for all streams instead of several.

TPU adaptation (DESIGN.md §2): parameters ride the flat bus
(core/flat.py): one contiguous 1-D buffer, zero-padded to a BLOCK
multiple, tiled into (1, BLOCK)-element VMEM blocks (multiples of the
8x128 vector tile); the grid walks the flat buffer.  Scalars (alpha, lam,
Eq. 2 weights) ride in ANY memory.  The ``*_flat`` entry points take
pre-padded buffers and launch exactly ONE ``pallas_call`` for the whole
model; the legacy per-tensor entry points pad-and-reshape on the way in.

``launch_count()`` counts ``pallas_call`` invocations (trace-time) — the
benchmark/test evidence that the flat path is one launch per assimilation
while the per-leaf path is one per leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import BLOCK

_launches = 0


def launch_count() -> int:
    return _launches


def reset_launch_count() -> None:
    global _launches
    _launches = 0


def _note_launch() -> None:
    global _launches
    _launches += 1


def _lerp_kernel(scal_ref, s_ref, c_ref, o_ref):
    a = scal_ref[0]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (a * s + (1.0 - a) * c).astype(o_ref.dtype)


def _dc_lerp_kernel(scal_ref, s_ref, c_ref, g_ref, b_ref, o_ref):
    """Delay-compensated lerp; scal = [alpha, lam].  The client copy is
    first corrected by the diagonal-Hessian term lam*g*g*(W_s - W_backup)
    (Zheng et al. [18]), then assimilated."""
    a, lam = scal_ref[0], scal_ref[1]
    s = s_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    c_comp = c + lam * g * g * (s - b)
    o_ref[...] = (a * s + (1.0 - a) * c_comp).astype(o_ref.dtype)


def _assimilate_kernel(w_ref, s_ref, c_ref, o_ref, *, n_clients: int):
    """Eq. 2: acc = w0*s + sum_j w_{j+1}*c_j, accumulated in arrival order
    (bit-identical to folding Eq. 1) over one [n_clients, 1, BLOCK] tile."""
    acc = w_ref[0] * s_ref[...].astype(jnp.float32)          # [1, BLOCK]
    for j in range(n_clients):
        cj = pl.load(c_ref, (pl.dslice(j, 1), pl.dslice(0, 1),
                             slice(None)))[0]                # [1, BLOCK]
        acc = acc + w_ref[j + 1] * cj.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _blocked_call(kernel, scalars, arrays, *, interpret: bool):
    """Flatten every operand to [nb, BLOCK] (zero-padded) and run the grid."""
    x0 = arrays[0]
    n = x0.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n

    def prep(x):
        f = x.reshape(-1)
        if pad:
            f = jnp.pad(f, (0, pad))
        return f.reshape(nb, BLOCK)

    flats = [prep(x) for x in arrays]
    scal = jnp.stack([jnp.asarray(s, jnp.float32).reshape(()) for s in scalars])
    _note_launch()
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)) for _ in flats],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), x0.dtype),
        interpret=interpret,
    )(scal, *flats)
    out = out.reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(x0.shape)


def vc_asgd_lerp(server: jnp.ndarray, client: jnp.ndarray, alpha,
                 *, interpret: bool = True) -> jnp.ndarray:
    """W_s <- alpha*W_s + (1-alpha)*W_c, one fused pass over one tensor."""
    return _blocked_call(_lerp_kernel, [alpha], [server, client],
                         interpret=interpret)


def vc_asgd_dc_lerp(server, client, grad, backup, alpha, lam=0.04,
                    *, interpret: bool = True) -> jnp.ndarray:
    """Fused DC-ASGD + lerp (one HBM pass over four streams)."""
    return _blocked_call(_dc_lerp_kernel, [alpha, lam],
                         [server, client, grad, backup], interpret=interpret)


# ---------------------------------------------------------------------------
# flat-bus entry points: pre-padded contiguous buffers, ONE launch each
# ---------------------------------------------------------------------------

def _check_flat(buf: jnp.ndarray) -> int:
    if buf.ndim != 1 or buf.size % BLOCK:
        raise ValueError(
            f"flat buffer must be 1-D and a BLOCK({BLOCK}) multiple, "
            f"got shape {buf.shape}")
    return buf.size // BLOCK


def vc_asgd_lerp_flat(server: jnp.ndarray, client: jnp.ndarray, alpha,
                      *, interpret: bool = True) -> jnp.ndarray:
    """Eq. 1 over the whole flat bus in one blocked grid (no pad/reshape)."""
    _check_flat(server)
    return _blocked_call(_lerp_kernel, [alpha], [server, client],
                         interpret=interpret)


def vc_asgd_dc_lerp_flat(server, client, grad, backup, alpha, lam=0.04,
                         *, interpret: bool = True) -> jnp.ndarray:
    """DC-ASGD variant riding the same single-launch flat pass."""
    _check_flat(server)
    return _blocked_call(_dc_lerp_kernel, [alpha, lam],
                         [server, client, grad, backup], interpret=interpret)


def assimilate_flat(server: jnp.ndarray, clients: jnp.ndarray, weights,
                    *, interpret: bool = True) -> jnp.ndarray:
    """Eq. 2 as ONE fused weighted reduction: server [N] + clients [n, N]
    -> [N] in a single ``pallas_call`` whose grid walks the flat buffer;
    each tile reduces all n client streams in arrival order (bit-identical
    to the per-leaf fold in f32).  ``weights`` = [w_server, w_0..w_{n-1}]
    (assimilation_weights or the staleness-damped variant)."""
    nb = _check_flat(server)
    n_clients = int(clients.shape[0])
    if clients.shape != (n_clients, server.size):
        raise ValueError(f"clients must be [n, {server.size}], "
                         f"got {clients.shape}")
    if len(weights) != n_clients + 1:
        raise ValueError(f"need {n_clients + 1} weights, got {len(weights)}")
    w = jnp.stack([jnp.asarray(x, jnp.float32).reshape(()) for x in weights])
    s2 = server.reshape(nb, BLOCK)
    c3 = clients.reshape(n_clients, nb, BLOCK)
    kern = functools.partial(_assimilate_kernel, n_clients=n_clients)
    _note_launch()
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((n_clients, 1, BLOCK), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), server.dtype),
        interpret=interpret,
    )(w, s2, c3)
    return out.reshape(-1)
