"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) every kernel runs in interpret mode — the kernel
body executes as traced jnp ops, which validates BlockSpecs, index maps and
the kernel math against ref.py.  On TPU backends the same calls compile to
Mosaic.  ``interpret`` is decided once per process from the backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import quantize as _qz
from repro.kernels import rwkv6_scan as _rw
from repro.kernels import sparse_pack as _sp
from repro.kernels import topk_mask as _tm
from repro.kernels import vc_asgd_update as _vc


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_lerp(server, client, alpha):
    """VC-ASGD Eq. 1 on one tensor (pytree mapping handled by callers)."""
    return _vc.vc_asgd_lerp(server, client, alpha, interpret=_interpret())


def fused_dc_lerp(server, client, grad, backup, alpha, lam=0.04):
    return _vc.vc_asgd_dc_lerp(server, client, grad, backup, alpha, lam,
                               interpret=_interpret())


def fused_lerp_flat(server_buf, client_buf, alpha):
    """Eq. 1 over the whole flat bus (core/flat.py) — ONE launch."""
    return _vc.vc_asgd_lerp_flat(server_buf, client_buf, alpha,
                                 interpret=_interpret())


def fused_dc_lerp_flat(server_buf, client_buf, grad_buf, backup_buf, alpha,
                       lam=0.04):
    return _vc.vc_asgd_dc_lerp_flat(server_buf, client_buf, grad_buf,
                                    backup_buf, alpha, lam,
                                    interpret=_interpret())


def fused_assimilate_flat(server_buf, clients_buf, weights):
    """Eq. 2 over [n_clients, N] stacked flat buffers — ONE launch."""
    return _vc.assimilate_flat(server_buf, clients_buf, weights,
                               interpret=_interpret())


def fused_adam_flat(p_buf, g_buf, m_buf, v_buf, lr, b1, b2, eps,
                    weight_decay, c1, c2):
    """Whole-model Adam (params + m/v lanes of the flat bus) — ONE launch."""
    return _vc.adam_update_flat(p_buf, g_buf, m_buf, v_buf, lr, b1, b2, eps,
                                weight_decay, c1, c2, interpret=_interpret())


def fused_easgd_flat(center_buf, replicas_buf, beta):
    """Elastic EASGD round: center [N] + replicas [n, N] — ONE launch."""
    return _vc.easgd_elastic_flat(center_buf, replicas_buf, beta,
                                  interpret=_interpret())


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_block=256, kv_block=256):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_block=q_block,
                               kv_block=kv_block, interpret=_interpret())


def wkv6(r, k, v, w, u):
    return _rw.wkv6(r, k, v, w, u, interpret=_interpret())


def mamba_scan(u, dt, B, C, A, D, d_block=128):
    return _ms.mamba_scan(u, dt, B, C, A, D, d_block=d_block,
                          interpret=_interpret())


def quantize_int8(x):
    return _qz.quantize_int8(x, interpret=_interpret())


def dequantize_int8(q, scales, n, out_dtype=jnp.float32):
    return _qz.dequantize_int8(q, scales, n, out_dtype,
                               interpret=_interpret())


def threshold_sparsify(x, tau):
    return _tm.threshold_sparsify(x, tau, interpret=_interpret())


def blocked_topk_stats(x, lo):
    """ONE memory-bound pass: per-block packed candidate words + counts."""
    return _tm.blocked_topk_stats(x, lo, interpret=_interpret())


def threshold_sparsify_exact(x, tau, tie_start, tie_budget):
    """Exact-k kept/residual emit (deterministic under ties at tau)."""
    return _tm.threshold_sparsify_exact(x, tau, tie_start, tie_budget,
                                        interpret=_interpret())


def blocked_topk_sparsify(x, k):
    """Exact global top-k (kept, residual): stats launch + tiny refinement
    + exact-k emit launch; dense fallback when the bracket misses."""
    return _tm.blocked_topk_sparsify(x, k, interpret=_interpret())


def fused_quantize_pack(sel, idx, block=256):
    """Quantize + pack the sparse wire-frame body in ONE launch."""
    return _sp.quantize_pack(sel, idx, block=block, interpret=_interpret())


def fused_pack_body(q, scales, idx):
    """Pack an existing payload into the wire body — bitcast-only, so the
    bytes equal the payload arrays' own bytes exactly."""
    return _sp.pack_body(q, scales, idx, interpret=_interpret())
