"""Symmetric per-block int8 codec kernels for cross-pod delta compression.

One fused pass computes the per-block scale (max-|x| / 127) AND the
quantized payload; the dequant kernel fuses the scale multiply back.  Used
by the compressed VC-ASGD assimilation path (core/compression.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256          # quantization block (values per scale)
ROWS = 32             # QBLOCK-rows handled per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # [ROWS, QBLOCK]
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0].astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)[:, None]
    o_ref[...] = (q * s).astype(o_ref.dtype)


def quantize_int8(x: jnp.ndarray, *, interpret: bool = True):
    """x: any shape -> (q int8 [n], scales f32 [ceil(n/QBLOCK)])."""
    n = x.size
    nrow = -(-n // QBLOCK)
    ng = -(-nrow // ROWS)
    pad = ng * ROWS * QBLOCK - n
    xf = x.reshape(-1).astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xf = xf.reshape(ng * ROWS, QBLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(ng,),
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((ng * ROWS, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((ng * ROWS,), jnp.float32)],
        interpret=interpret,
    )(xf)
    return q.reshape(-1)[:n], s[:nrow]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                    out_dtype=jnp.float32, *, interpret: bool = True):
    nrow = scales.shape[0]
    ng = -(-nrow // ROWS)
    pad_rows = ng * ROWS - nrow
    qf = q.astype(jnp.int8).reshape(-1)
    pad = ng * ROWS * QBLOCK - qf.size
    if pad:
        qf = jnp.pad(qf, (0, pad))
    qf = qf.reshape(ng * ROWS, QBLOCK)
    sf = jnp.pad(scales, (0, pad_rows)) if pad_rows else scales
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(ng,),
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ng * ROWS, QBLOCK), out_dtype),
        interpret=interpret,
    )(qf, sf)
    return out.reshape(-1)[:n]
