"""whisper-tiny [audio]: enc-dec, 4L each, d=384 6H d_ff=1536 vocab=51865.
Conv frontend is a STUB (precomputed frame embeddings).  [arXiv:2212.04356;
unverified]  LayerNorm, GELU MLP, sinusoidal enc / learned dec positions.
Vocab padded 51865 -> 51872 for 16-way TP.  long_500k: skipped (pure full
attention, and the published decoder context is 448).
"""
from repro.models.common import BlockSpec, EncoderConfig, ModelConfig, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-tiny", family="audio",
        d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
        vocab_size=51865,
        layer_groups=uniform_groups(4, BlockSpec()),
        norm="layernorm", mlp_act="gelu", pos_emb="learned",
        encoder=EncoderConfig(n_layers=4, n_frames=1500, d_model=384,
                              n_heads=6, d_ff=1536),
        max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
        layer_groups=uniform_groups(2, BlockSpec()),
        encoder=EncoderConfig(n_layers=2, n_frames=16, d_model=32,
                              n_heads=2, d_ff=64),
        max_seq=256, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
