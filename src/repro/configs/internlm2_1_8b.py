"""internlm2-1.8b [dense]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
[arXiv:2403.17297; hf]  Llama-style: RMSNorm, SwiGLU, RoPE theta 1M.
"""
from repro.models.common import BlockSpec, ModelConfig, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-1.8b", family="dense",
        d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
        vocab_size=92544,
        layer_groups=uniform_groups(24, BlockSpec()),
        norm="rmsnorm", mlp_act="swiglu", rope_theta=1_000_000.0,
        max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=256,
        layer_groups=uniform_groups(2, BlockSpec()),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
