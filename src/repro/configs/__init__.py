"""Architecture config registry: one module per assigned architecture.

Each module exposes ``config()`` (the exact published configuration) and
``reduced()`` (a tiny same-family config for CPU smoke tests).
"""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    return import_module(_ARCH_MODULES[arch]).config()


def get_reduced(arch: str) -> ModelConfig:
    return import_module(_ARCH_MODULES[arch]).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
