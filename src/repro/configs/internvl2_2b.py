"""internvl2-2b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-1.8b backbone; 24L d=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  [arXiv:2404.16821; hf]
Vocab 92553 is padded internally to 92560 for 16-way TP (DESIGN.md §6).
"""
from repro.models.common import BlockSpec, ModelConfig, VisionStubConfig, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        arch="internvl2-2b", family="vlm",
        d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
        vocab_size=92553,
        layer_groups=uniform_groups(24, BlockSpec()),
        norm="rmsnorm", mlp_act="swiglu", rope_theta=1_000_000.0,
        vision=VisionStubConfig(n_patches=256, vit_dim=1024),
        max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=251,
        layer_groups=uniform_groups(2, BlockSpec()),
        vision=VisionStubConfig(n_patches=8, vit_dim=32),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
