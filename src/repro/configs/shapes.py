"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per architecture (the 40-cell table):
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill_step
  decode_32k   seq 32768,  global_batch 128  -> decode_step (1 new token)
  long_500k    seq 524288, global_batch 1    -> decode_step; only for archs
               with sub-quadratic / bounded per-step state (see
               ModelConfig.supports_long_decode and DESIGN.md §5)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.plan import NULL_PLAN
from repro.models.registry import build_model


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if runnable; else a human-readable skip reason."""
    if cell.name == "long_500k" and not cfg.supports_long_decode:
        return ("pure full-attention stack: 500k dense-KV decode has no "
                "sub-quadratic mechanism in the published architecture")
    return None


def tune_for_shape(cfg: ModelConfig, cell: ShapeCell) -> ModelConfig:
    """Per-cell chunk-size policy: bound the chunked-recurrence working set
    (it scales with local batch) and keep unrolled chunk counts sane."""
    kw = {}
    if cfg.rwkv is not None:
        kw["scan_chunk"] = {"train_4k": 128, "prefill_32k": 512}.get(cell.name, 128)
    elif cfg.mamba is not None:
        kw["scan_chunk"] = {"train_4k": 256, "prefill_32k": 1024}.get(cell.name, 256)
    if cell.name == "prefill_32k":
        kw["attn_q_block"] = 2048
        kw["attn_kv_block"] = 2048
    return cfg.replace(**kw) if kw else cfg


def input_specs(cfg: ModelConfig, cell: ShapeCell, plan=NULL_PLAN):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    sds = jax.ShapeDtypeStruct
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        batch = {}
        if cfg.encoder is not None:
            batch["frame_embeds"] = sds(
                (b, cfg.encoder.n_frames, cfg.encoder.d_model), jnp.bfloat16)
            batch["tokens"] = sds((b, s), jnp.int32)
        elif cfg.vision is not None:
            batch["patch_embeds"] = sds(
                (b, cfg.vision.n_patches, cfg.vision.vit_dim), jnp.bfloat16)
            batch["tokens"] = sds((b, s - cfg.vision.n_patches), jnp.int32)
        else:
            batch["tokens"] = sds((b, s), jnp.int32)
        return batch
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    return {
        "caches": model.cache_specs(b, s, plan),
        "token": sds((b,), jnp.int32),
        "pos": sds((), jnp.int32),
    }
