"""stablelm-3b [dense]: 32L d=2560 32H (GQA kv=32 == MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family; unverified]
StableLM-2 family traits: LayerNorm, partial rotary (25%), gated SiLU MLP.
"""
from repro.models.common import BlockSpec, ModelConfig, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-3b", family="dense",
        d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
        vocab_size=50304,
        layer_groups=uniform_groups(32, BlockSpec()),
        norm="layernorm", mlp_act="swiglu", rope_pct=0.25,
        rope_theta=10000.0, max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=256,
        layer_groups=uniform_groups(2, BlockSpec()),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
