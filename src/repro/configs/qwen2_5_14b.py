"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064,
QKV bias.  [hf:Qwen/Qwen2.5 family; hf]  RMSNorm, SwiGLU, rope theta 1M.
40 heads do not divide the 16-way model axis -> context-parallel attention
(DESIGN.md §6); hillclimbed against padded-head TP in EXPERIMENTS.md §Perf.
"""
from repro.models.common import BlockSpec, ModelConfig, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2.5-14b", family="dense",
        d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=13824,
        vocab_size=152064, qkv_bias=True,
        layer_groups=uniform_groups(48, BlockSpec()),
        norm="rmsnorm", mlp_act="swiglu", rope_theta=1_000_000.0,
        max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=80, n_heads=5, n_kv_heads=1, head_dim=16, d_ff=160,
        vocab_size=256,
        layer_groups=uniform_groups(2, BlockSpec()),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
