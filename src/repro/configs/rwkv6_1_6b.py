"""rwkv6-1.6b "Finch" [ssm]: 24L d=2048 (attention-free) d_ff=7168
vocab=65536; data-dependent decay.  [arXiv:2404.05892; unverified]
32 heads of 64.  long_500k runs trivially: decode state is O(1) per seq.
"""
from repro.models.common import BlockSpec, ModelConfig, RWKVConfig, uniform_groups

_BLK = BlockSpec(mixer="rwkv")


def config() -> ModelConfig:
    return ModelConfig(
        arch="rwkv6-1.6b", family="ssm",
        d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
        vocab_size=65536,
        layer_groups=uniform_groups(24, _BLK),
        norm="layernorm", pos_emb="none",
        rwkv=RWKVConfig(head_dim=64),
        max_seq=524288 + 64, scan_chunk=128,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, d_ff=160, vocab_size=256, n_heads=4, n_kv_heads=4,
        layer_groups=uniform_groups(2, _BLK),
        rwkv=RWKVConfig(head_dim=16, lora_dim_w=8, lora_dim_mix=8),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
