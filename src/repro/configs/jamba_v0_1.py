"""jamba-v0.1-52b [hybrid]: 32L d=4096, Mamba:attention 7:1 interleave
(attention at index 4 of each 8-layer block), MoE 16 experts top-2 on odd
layers, attn 32H (GQA kv=8), d_ff=14336, vocab=65536.  [arXiv:2403.19887; hf]
long_500k runs: Mamba state is O(1) and the 4 attention layers use the
chunk-sharded decode cache.
"""
from repro.models.common import BlockSpec, LayerGroup, MambaConfig, MoEConfig, ModelConfig


def _block(i: int) -> BlockSpec:
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return BlockSpec(mixer=mixer, ffn=ffn)


def config() -> ModelConfig:
    return ModelConfig(
        arch="jamba-v0.1-52b", family="hybrid",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=65536,
        layer_groups=(LayerGroup(tuple(_block(i) for i in range(8)), 4),),
        norm="rmsnorm", mlp_act="swiglu", pos_emb="none",   # jamba: no rope
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        max_seq=524288 + 64, scan_chunk=256,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256,
        layer_groups=(LayerGroup(tuple(_block(i) for i in range(8)), 1),),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
