"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=14336, 8 experts
top-2, sliding-window attention (4096).  [arXiv:2401.04088; hf]
RMSNorm, SwiGLU experts, rope theta 1M.  long_500k runs with the rolling
SWA cache (bounded window -> sub-quadratic decode state).
"""
from repro.models.common import BlockSpec, MoEConfig, ModelConfig, uniform_groups

_BLK = BlockSpec(attn_kind="swa", window=4096, ffn="moe")


def config() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-8x7b", family="moe",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=32000,
        layer_groups=uniform_groups(32, _BLK),
        norm="rmsnorm", mlp_act="swiglu", rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
        max_seq=524288 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=256,
        layer_groups=uniform_groups(
            2, BlockSpec(attn_kind="swa", window=32, ffn="moe")),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
