"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) per-expert
d_ff=512, 32 experts top-8, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  RMSNorm, SwiGLU experts,
tied embeddings.  Vocab padded 49155 -> 49168 for 16-way TP.
"""
from repro.models.common import BlockSpec, MoEConfig, ModelConfig, uniform_groups

_MOE = BlockSpec(ffn="moe")


def config() -> ModelConfig:
    return ModelConfig(
        arch="granite-moe-1b-a400m", family="moe",
        d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
        vocab_size=49155, tie_embeddings=True,
        layer_groups=uniform_groups(24, _MOE),
        norm="rmsnorm", mlp_act="swiglu", rope_theta=10000.0,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
        max_seq=32768 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
        layer_groups=uniform_groups(2, _MOE),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
