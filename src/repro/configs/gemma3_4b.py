"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global attention, 128k context.  [hf:google/gemma-3-1b-pt family;
unverified]  Gemma-3 traits: head_dim 256, QK-norm, GeGLU, RMSNorm, tied
embeddings, rope theta 1M global / 10k local, 1024-token sliding window.
"""
from repro.models.common import BlockSpec, LayerGroup, ModelConfig

_LOCAL = BlockSpec(attn_kind="swa", window=1024)
_GLOBAL = BlockSpec(attn_kind="full")


def config() -> ModelConfig:
    return ModelConfig(
        arch="gemma3-4b", family="dense",
        d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab_size=262144,
        # 34 layers: [L L L L L G] x 5 + [L L L L]
        layer_groups=(LayerGroup((_LOCAL,) * 5 + (_GLOBAL,), 5),
                      LayerGroup((_LOCAL,), 4)),
        norm="rmsnorm", mlp_act="geglu", qk_norm=True, tie_embeddings=True,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        max_seq=524288 + 64,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
        vocab_size=256,
        layer_groups=(LayerGroup((BlockSpec(attn_kind="swa", window=32),) * 2
                                 + (_GLOBAL,), 1),),
        max_seq=512, attn_q_block=32, attn_kv_block=32, scan_chunk=16,
    )
