"""Whisper-style encoder-decoder transformer (whisper-tiny backbone).

The conv1d audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [b, n_frames, d_model] (post-conv),
and the encoder adds sinusoidal positions on top.  The decoder uses a
learned position table, causal self-attention (two-tier decode cache) and
cross-attention into the encoder states (static K/V cache at decode time).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.common import ModelConfig
from repro.models.plan import NULL_PLAN


class CrossCache(NamedTuple):
    k: jnp.ndarray   # [b, kv, nf, hd]
    v: jnp.ndarray


class WhisperDecCache(NamedTuple):
    self_cache: L.DecodeCache
    cross: CrossCache


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    e = cfg.encoder
    return cfg.replace(d_model=e.d_model, n_heads=e.n_heads,
                       n_kv_heads=e.n_heads, d_ff=e.d_ff, head_dim=None)


def init_whisper(key, cfg: ModelConfig) -> Dict[str, Any]:
    e = cfg.encoder
    ks = jax.random.split(key, 8)
    ecfg = _enc_cfg(cfg)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": L.init_norm(ecfg), "attn": L.init_attention(k1, ecfg),
                "norm2": L.init_norm(ecfg), "mlp": L.init_mlp(k2, ecfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": L.init_norm(cfg), "attn": L.init_attention(k1, cfg),
                "norm_x": L.init_norm(cfg), "xattn": L.init_attention(k2, cfg),
                "norm2": L.init_norm(cfg), "mlp": L.init_mlp(k3, cfg)}

    enc = [enc_layer(jax.random.fold_in(ks[0], i)) for i in range(e.n_layers)]
    n_dec = sum(g.n_layers for g in cfg.layer_groups)
    dec = [dec_layer(jax.random.fold_in(ks[1], i)) for i in range(n_dec)]
    return {
        "embed": L.init_embedding(ks[2], cfg),
        "pos_table": (jax.random.normal(ks[3], (cfg.max_seq, cfg.d_model),
                                        jnp.float32) * 0.01).astype(cfg.pdtype),
        "enc": jax.tree.map(lambda *x: jnp.stack(x), *enc),
        "dec": jax.tree.map(lambda *x: jnp.stack(x), *dec),
        "enc_norm": L.init_norm(ecfg),
        "final_norm": L.init_norm(cfg),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frame_embeds: jnp.ndarray, plan=NULL_PLAN):
    """frame_embeds: [b, nf, d_enc] -> encoder states [b, nf, d_enc]."""
    ecfg = _enc_cfg(cfg)
    x = frame_embeds.astype(cfg.cdtype)
    x = x + L.sinusoidal_pos(x.shape[1], ecfg.d_model).astype(x.dtype)
    x = plan.act(x, "enc_bsd")

    def body(xc, p):
        h = L.apply_norm(p["norm1"], xc, ecfg)
        q, k, v = L.qkv_proj(p["attn"], h, ecfg)
        pos = np.arange(xc.shape[1], dtype=np.int32)
        o = L.blocked_attention(q[:, None], k, v, causal=False,
                                q_positions=pos[None], kv_positions=pos,
                                q_block=ecfg.attn_q_block,
                                kv_block=ecfg.attn_kv_block)
        o = o[:, 0].reshape(*xc.shape[:-1], -1)
        xc = xc + plan.act(o @ p["attn"]["wo"].astype(ecfg.cdtype), "enc_bsd")
        h = L.apply_norm(p["norm2"], xc, ecfg)
        xc = xc + plan.act(L.apply_mlp(p["mlp"], h, ecfg), "enc_bsd")
        return xc, ()

    x, _ = jax.lax.scan(lambda c, p: jax.checkpoint(body)(c, p), x, params["enc"])
    return L.apply_norm(params["enc_norm"], x, ecfg)


# ---------------------------------------------------------------------------
# decoder (teacher-forced / prefill path)
# ---------------------------------------------------------------------------

def _xattn(p, h, enc_kv: Tuple[jnp.ndarray, jnp.ndarray], cfg: ModelConfig, plan):
    """Cross attention. h: [b, s, d]; enc_kv: (k, v) [b, nf, kv, hd]."""
    dt = cfg.cdtype
    q = (h @ p["wq"].astype(dt)).reshape(*h.shape[:-1], cfg.n_heads, cfg.hd)
    k, v = enc_kv
    pos_q = np.arange(h.shape[1], dtype=np.int32)
    pos_k = np.arange(k.shape[1], dtype=np.int32)
    o = L.blocked_attention(q[:, None], k, v, causal=False,
                            q_positions=pos_q[None], kv_positions=pos_k,
                            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    o = o[:, 0].reshape(*h.shape[:-1], -1)
    return plan.act(o @ p["wo"].astype(dt), "bsd")


def _enc_kv(p, enc_states, cfg: ModelConfig):
    """Encoder K/V for cross attention (projected once per layer)."""
    dt = cfg.cdtype
    k = (enc_states @ p["wk"].astype(dt)).reshape(
        *enc_states.shape[:-1], cfg.n_kv_heads, cfg.hd)
    v = (enc_states @ p["wv"].astype(dt)).reshape(
        *enc_states.shape[:-1], cfg.n_kv_heads, cfg.hd)
    return k, v


def decoder_forward(params, cfg: ModelConfig, tokens, enc_states,
                    plan=NULL_PLAN, return_caches: bool = False):
    """tokens: [b, s]; enc_states: [b, nf, d]. Returns (logits, caches|None)."""
    x, ys = _decoder_stack(params, cfg, tokens, enc_states, plan,
                           return_caches)
    lg = L.logits(params["embed"], x, cfg)
    return plan.act(lg, "logits"), ys


def decoder_hidden(params, cfg: ModelConfig, tokens, enc_states,
                   plan=NULL_PLAN):
    return _decoder_stack(params, cfg, tokens, enc_states, plan, False)[0]


def _decoder_stack(params, cfg: ModelConfig, tokens, enc_states,
                   plan=NULL_PLAN, return_caches: bool = False):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    x = x + params["pos_table"].astype(x.dtype)[:s]
    x = plan.act(x, "bsd")

    def body(xc, p):
        h = L.apply_norm(p["norm1"], xc, cfg)
        q, k, v = L.qkv_proj(p["attn"], h, cfg)
        pos = np.arange(s, dtype=np.int32)
        o = L.blocked_attention(q[:, None], k, v, causal=True,
                                q_positions=pos[None], kv_positions=pos,
                                q_block=cfg.attn_q_block,
                                kv_block=cfg.attn_kv_block)
        o = o[:, 0].reshape(b, s, -1)
        xc = xc + plan.act(o @ p["attn"]["wo"].astype(cfg.cdtype), "bsd")
        ekv = _enc_kv(p["xattn"], enc_states, cfg)
        h = L.apply_norm(p["norm_x"], xc, cfg)
        xc = xc + _xattn(p["xattn"], h, ekv, cfg, plan)
        h = L.apply_norm(p["norm2"], xc, cfg)
        xc = xc + plan.act(L.apply_mlp(p["mlp"], h, cfg), "bsd")
        if return_caches:
            return xc, (k, v, ekv)
        return xc, ()

    if return_caches:
        x, ys = jax.lax.scan(lambda c, p: body(c, p), x, params["dec"])
    else:
        x, ys = jax.lax.scan(lambda c, p: jax.checkpoint(body)(c, p),
                             x, params["dec"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, ys


def whisper_loss(params, cfg: ModelConfig, batch, plan=NULL_PLAN,
                 ce_chunks: int = 8):
    from repro.models.transformer import chunked_ce
    enc = encode(params, cfg, batch["frame_embeds"], plan)
    x = decoder_hidden(params, cfg, batch["tokens"], enc, plan)
    tgt = batch["tokens"][:, 1:]
    nll = chunked_ce(params["embed"], cfg, x[:, :-1], tgt, plan, ce_chunks)
    loss = nll / float(np.prod(tgt.shape))
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def whisper_prefill(params, cfg: ModelConfig, batch, plan=NULL_PLAN):
    enc = encode(params, cfg, batch["frame_embeds"], plan)
    lg, ys = decoder_forward(params, cfg, batch["tokens"], enc, plan,
                             return_caches=True)
    k, v, ekv = ys                                        # stacked [L, ...]
    s = batch["tokens"].shape[1]
    C = plan.cache_chunks
    ln = -(-s // C)
    pad = C * ln - s

    def to_old(t):  # [L, b, s, kv, hd] -> [L, b, kv, C, ln, hd]
        t = jnp.moveaxis(t, 3, 2)
        t = jnp.pad(t, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        Lb = t.shape
        return t.reshape(Lb[0], Lb[1], Lb[2], C, ln, Lb[4]).astype(cfg.cdtype)

    pos = jnp.arange(C * ln, dtype=jnp.int32)
    old_pos = jnp.where(pos < s, pos, -1).reshape(C, ln)
    nl, b = k.shape[0], k.shape[1]
    self_cache = L.DecodeCache(
        k_old=plan.act(to_old(k), "cache_old_L"),
        v_old=plan.act(to_old(v), "cache_old_L"),
        old_pos=jnp.broadcast_to(old_pos, (nl, C, ln)),
        k_rec=jnp.zeros((nl, b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd), cfg.cdtype),
        v_rec=jnp.zeros((nl, b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd), cfg.cdtype),
        rec_pos=jnp.full((nl, L.RECENT_RING), -1, jnp.int32))
    cross = CrossCache(k=jnp.moveaxis(ekv[0], 3, 2), v=jnp.moveaxis(ekv[1], 3, 2))
    return plan.act(lg[:, -1], "dec_logits"), WhisperDecCache(self_cache, cross)


def whisper_decode_step(params, cfg: ModelConfig, caches: WhisperDecCache,
                        token, pos, plan=NULL_PLAN):
    """token [b]; pos scalar. Returns (logits [b, Vp], new caches)."""
    x = L.embed(params["embed"], token, cfg)
    x = x + jax.lax.dynamic_index_in_dim(
        params["pos_table"], pos, keepdims=False).astype(x.dtype)
    x = plan.act(x, "dec_x")

    def body(xc, scan_in):
        p, sc, cross = scan_in
        h = L.apply_norm(p["norm1"], xc, cfg)
        q, k, v = L.qkv_proj(p["attn"], h[:, None], cfg)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        sc = L.cache_append_recent(sc, k, v, pos)
        o = L.decode_attention(plan.act(q, "dec_q"), sc, pos)
        xc = xc + plan.act(o.reshape(xc.shape[0], -1)
                           @ p["attn"]["wo"].astype(cfg.cdtype), "dec_x")
        # cross attention against the static encoder cache
        h = L.apply_norm(p["norm_x"], xc, cfg)
        qx = (h @ p["xattn"]["wq"].astype(cfg.cdtype)).reshape(
            xc.shape[0], cfg.n_heads, cfg.hd)
        kx, vx = cross.k, cross.v                          # [b, kv, nf, hd]
        g = cfg.n_heads // cfg.n_kv_heads
        qg = qx.reshape(xc.shape[0], cfg.n_kv_heads, g, cfg.hd)
        sx = jnp.einsum("bkgd,bknd->bkgn", qg, kx.astype(qx.dtype),
                        preferred_element_type=jnp.float32)
        sx = sx / math.sqrt(cfg.hd)
        w = jax.nn.softmax(sx, -1)
        ox = jnp.einsum("bkgn,bknd->bkgd", w.astype(qx.dtype),
                        vx.astype(qx.dtype))
        xc = xc + plan.act(ox.reshape(xc.shape[0], -1)
                           @ p["xattn"]["wo"].astype(cfg.cdtype), "dec_x")
        h = L.apply_norm(p["norm2"], xc, cfg)
        xc = xc + plan.act(L.apply_mlp(p["mlp"], h, cfg), "dec_x")
        return xc, sc

    x, new_self = jax.lax.scan(lambda c, s_: body(c, s_), x,
                               (params["dec"], caches.self_cache, caches.cross))
    x = L.apply_norm(params["final_norm"], x, cfg)
    lg = L.logits(params["embed"], x, cfg)
    return plan.act(lg, "dec_logits"), WhisperDecCache(new_self, caches.cross)


def whisper_cache_specs(cfg: ModelConfig, b: int, seq_len: int, plan=NULL_PLAN):
    nl = sum(g.n_layers for g in cfg.layer_groups)
    C = plan.cache_chunks
    ln = -(-seq_len // C)
    e = cfg.encoder
    sds = jax.ShapeDtypeStruct
    self_cache = L.DecodeCache(
        k_old=sds((nl, b, cfg.n_kv_heads, C, ln, cfg.hd), cfg.cdtype),
        v_old=sds((nl, b, cfg.n_kv_heads, C, ln, cfg.hd), cfg.cdtype),
        old_pos=sds((nl, C, ln), jnp.int32),
        k_rec=sds((nl, b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd), cfg.cdtype),
        v_rec=sds((nl, b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd), cfg.cdtype),
        rec_pos=sds((nl, L.RECENT_RING), jnp.int32))
    cross = CrossCache(k=sds((nl, b, cfg.n_kv_heads, e.n_frames, cfg.hd), cfg.cdtype),
                       v=sds((nl, b, cfg.n_kv_heads, e.n_frames, cfg.hd), cfg.cdtype))
    return WhisperDecCache(self_cache, cross)
