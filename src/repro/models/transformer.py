"""Decoder-only LM assembly: heterogeneous block stacks, scan-over-superblocks
with remat, CP/TP-aware attention, KV-cache prefill/decode.

The layer plan (cfg.layer_groups) is a list of (superblock, repeats); we
``lax.scan`` over repeats with the superblock unrolled in the body.  This
bounds HLO size for deep models and makes cost-analysis rescaling exact
(runtime/hlo_analysis.py).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.common import BlockSpec, LayerGroup, ModelConfig
from repro.models.plan import NULL_PLAN


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, spec: BlockSpec) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = M.init_mamba(ks[0], cfg)
    elif spec.mixer == "rwkv":
        p["rwkv_tm"] = R.init_time_mix(ks[0], cfg)
    if spec.ffn != "none":
        p["norm2"] = L.init_norm(cfg)
        if spec.ffn == "moe":
            p["moe"] = L.init_moe(ks[1], cfg)
        elif spec.mixer == "rwkv":
            p["rwkv_cm"] = R.init_channel_mix(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2 + len(cfg.layer_groups))
    params: Dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg),
        "final_norm": L.init_norm(cfg),
    }
    if cfg.vision is not None:
        kv1, kv2 = jax.random.split(jax.random.fold_in(ks[0], 7))
        params["vis_proj"] = {
            "w1": L.he_normal(kv1, (cfg.vision.vit_dim, cfg.d_model), cfg.pdtype),
            "w2": L.he_normal(kv2, (cfg.d_model, cfg.d_model), cfg.pdtype),
        }
    for gi, g in enumerate(cfg.layer_groups):
        def init_rep(k):
            kk = jax.random.split(k, len(g.blocks))
            return [init_block(kk[i], cfg, s) for i, s in enumerate(g.blocks)]
        reps = [init_rep(jax.random.fold_in(ks[2 + gi], r))
                for r in range(g.repeats)]
        params[f"group{gi}"] = jax.tree.map(lambda *x: jnp.stack(x), *reps) \
            if g.repeats > 1 else jax.tree.map(lambda x: x[None], reps[0])
    return params


# ---------------------------------------------------------------------------
# attention sub-layer (train / prefill) in the three execution modes
# ---------------------------------------------------------------------------

def _rope_theta_for(cfg: ModelConfig, spec: BlockSpec) -> float:
    if spec.attn_kind == "swa" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def attn_forward(p, x, cfg: ModelConfig, spec: BlockSpec, plan,
                 return_kv: bool = False):
    """x: [b, s, d] ("local"/"head_tp") or [b, P, sl, d] ("cp").
    Returns (out same layout, optional (k, v) in natural [b, s, kv, hd])."""
    mode = plan.attn_mode
    window = spec.window if spec.attn_kind == "swa" else None
    theta = _rope_theta_for(cfg, spec)

    if mode in ("local", "head_tp"):
        b, s, d = x.shape
        q, k, v = L.qkv_proj(p, x, cfg)                      # [b,s,h/kv,hd]
        pos = np.arange(s, dtype=np.int32)
        q = L.apply_rope(q, jnp.asarray(pos), cfg, theta)
        k = L.apply_rope(k, jnp.asarray(pos), cfg, theta)
        q = plan.act(q, "q_bshd")
        k = plan.act(k, "kv_bshd")
        v = plan.act(v, "kv_bshd")
        o = L.blocked_attention(
            q[:, None], k, v, causal=True, window=window,
            q_positions=pos[None, :], kv_positions=pos,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
        o = o[:, 0].reshape(b, s, cfg.n_heads * cfg.hd)
        out = plan.act(o @ p["wo"].astype(cfg.cdtype), "bsd")
        return out, ((k, v) if return_kv else None)

    # ---- contiguous-chunk context parallelism -----------------------------
    b, P, sl, d = x.shape
    s = P * sl
    q, k, v = L.qkv_proj(p, x, cfg)                          # [b,P,sl,*,hd]
    pos = (np.arange(P, dtype=np.int32)[:, None] * sl
           + np.arange(sl, dtype=np.int32)[None, :])         # [P, sl]
    q = L.apply_rope(q, jnp.asarray(pos)[None], cfg, theta)
    k = L.apply_rope(k, jnp.asarray(pos)[None], cfg, theta)
    q = plan.act(q, "q_bpshd")

    if window is not None and plan.window_gather and P > 1:
        # gather only the neighbor kv chunks each q chunk can see
        nw = min(P, int(math.ceil(window / sl)) + 1)
        idx = (np.arange(P)[:, None] - (nw - 1) + np.arange(nw)[None, :])
        valid = idx >= 0                                      # [P, nw]
        idxc = np.clip(idx, 0, P - 1)
        kg = plan.act(jnp.take(k, jnp.asarray(idxc), axis=1), "kv_gather")
        vg = plan.act(jnp.take(v, jnp.asarray(idxc), axis=1), "kv_gather")
        # [b, P, nw, sl, kv, hd] -> flatten window dim
        kg = kg.reshape(b, P, nw * sl, cfg.n_kv_heads, cfg.hd)
        vg = vg.reshape(b, P, nw * sl, cfg.n_kv_heads, cfg.hd)
        kpos = (idxc[:, :, None] * sl + np.arange(sl)[None, None, :])
        kpos = np.where(valid[:, :, None], kpos, -10 ** 9)    # mask clipped dups
        kpos = kpos.reshape(P, nw * sl)
        o = _attn_per_chunk(q, kg, vg, pos, kpos, cfg, window=window)
    else:
        # full gather (replicate KV over the model axis), natural order
        kf = plan.act(k.reshape(b, s, cfg.n_kv_heads, cfg.hd), "kv_rep")
        vf = plan.act(v.reshape(b, s, cfg.n_kv_heads, cfg.hd), "kv_rep")
        o = L.blocked_attention(
            q, kf, vf, causal=True, window=window,
            q_positions=pos, kv_positions=np.arange(s, dtype=np.int32),
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    o = o.reshape(b, P, sl, cfg.n_heads * cfg.hd)
    out = plan.act(o @ p["wo"].astype(cfg.cdtype), "cp_bpsd")
    if return_kv:
        return out, (k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
                     v.reshape(b, s, cfg.n_kv_heads, cfg.hd))
    return out, None


def _attn_per_chunk(q, kg, vg, qpos, kpos, cfg: ModelConfig, window):
    """Per-chunk attention where each q chunk has its OWN kv set.
    q: [b,P,sl,h,hd]; kg/vg: [b,P,skv,kv,hd]; qpos [P,sl]; kpos [P,skv]."""
    b, P, sl, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    kh = L.repeat_kv(kg, h)
    vh = L.repeat_kv(vg, h)
    s = jnp.einsum("bpqhd,bpkhd->bphqk", q, kh,
                   preferred_element_type=jnp.float32) * scale
    mask = (qpos[:, None, :, None] >= kpos[:, None, None, :])
    if window is not None:
        mask = mask & (kpos[:, None, None, :] > qpos[:, None, :, None] - window)
    s = jnp.where(jnp.asarray(mask)[None], s, L.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bphqk,bpkhd->bpqhd", w.astype(vh.dtype), vh,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# one block (train / prefill)
# ---------------------------------------------------------------------------

def block_forward(p, x, cfg: ModelConfig, spec: BlockSpec, plan,
                  return_kv: bool = False):
    """Returns (x_out, aux_loss, kv or carry-state info)."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    h = L.apply_norm(p["norm1"], x, cfg)
    if spec.mixer == "attn":
        o, kv = attn_forward(p["attn"], h, cfg, spec, plan, return_kv)
        x = x + o
    elif spec.mixer == "mamba":
        o, mstate = M.mamba_chunked(p["mamba"], h, cfg)
        kv = mstate if return_kv else None
        x = x + plan.act(o, "bsd")
    elif spec.mixer == "rwkv":
        o, S, xl = R.time_mix_chunked(p["rwkv_tm"], h, cfg)
        kv = (S, xl) if return_kv else None
        x = x + plan.act(o, "bsd")

    if spec.ffn == "none":
        return x, aux, kv
    h = L.apply_norm(p["norm2"], x, cfg)
    if spec.ffn == "moe":
        if getattr(plan, "moe_ep", False) and x.ndim == 3:
            out, aux = L.apply_moe_ep(p["moe"], h, cfg, plan)
            x = x + plan.act(out, "bsd")
        else:
            hf = h.reshape(h.shape[0], -1, h.shape[-1])   # [b, s(*P), d]
            out, aux = jax.vmap(lambda t: L.apply_moe(p["moe"], t, cfg))(hf)
            aux = aux.mean()
            x = x + plan.act(out.reshape(x.shape),
                             "bsd" if x.ndim == 3 else "cp_bpsd")
    elif spec.mixer == "rwkv":
        b, s, d = h.shape
        prev = jnp.concatenate([jnp.zeros((b, 1, d), h.dtype), h[:, :-1]], 1)
        x = x + plan.act(R.channel_mix(p["rwkv_cm"], h, prev, cfg), "bsd")
        if kv is not None:
            kv = (*kv, h[:, -1])                           # cm_prev for decode
    else:
        x = x + plan.act(L.apply_mlp(p["mlp"], h, cfg),
                         "bsd" if x.ndim == 3 else "cp_bpsd")
    return x, aux, kv


# ---------------------------------------------------------------------------
# full forward (train) — scan over superblocks with remat
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], plan):
    """tokens (+ stub modality embeddings) -> x [b, s, d]."""
    x = L.embed(params["embed"], batch["tokens"], cfg)
    if cfg.vision is not None:
        pe = batch["patch_embeds"].astype(cfg.cdtype)      # [b, np, vit]
        v = jax.nn.gelu(pe @ params["vis_proj"]["w1"].astype(cfg.cdtype),
                        approximate=True)
        v = v @ params["vis_proj"]["w2"].astype(cfg.cdtype)
        x = jnp.concatenate([v, x], axis=1)                # image-first layout
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    return plan.act(x, "bsd")


def _remat_wrap(body, remat):
    """remat: False | True ("full" recompute) | "dots" (save matmul outputs
    — trades recompute FLOPs for activation memory/HBM traffic)."""
    if not remat:
        return body
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def lm_hidden(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
              plan=NULL_PLAN, remat: bool = True):
    """Embeddings -> final-norm hidden states [b, s, d] (+ MoE aux)."""
    x = _embed_inputs(params, cfg, batch, plan)
    b, s, d = x.shape
    cp = plan.cp if plan.attn_mode == "cp" else 1
    if cp > 1:
        assert s % cp == 0
        x = plan.act(x.reshape(b, cp, s // cp, d), "cp_bpsd")

    aux_total = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(xc, rep_params, _g=g):
            a = jnp.zeros((), jnp.float32)
            for bi, spec in enumerate(_g.blocks):
                xc, ai, _ = block_forward(rep_params[bi], xc, cfg, spec, plan)
                a = a + ai
            return xc, a

        body_fn = _remat_wrap(body, remat)
        x, auxs = jax.lax.scan(lambda c, p_: body_fn(c, p_), x, gp)
        aux_total = aux_total + auxs.sum()

    if cp > 1:
        x = plan.act(x.reshape(b, s, d), "bsd")
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, aux_total


def lm_forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
               plan=NULL_PLAN, remat: bool = True):
    """Returns (logits [b, s, vocab_pad], aux_loss scalar)."""
    x, aux_total = lm_hidden(params, cfg, batch, plan, remat)
    lg = L.logits(params["embed"], x, cfg)
    return plan.act(lg, "logits"), aux_total


def chunked_ce(embed_params, cfg: ModelConfig, hidden, targets, plan,
               n_chunks: int = 8):
    """Sum of next-token NLL, computed per sequence chunk under remat so the
    full [b, s, vocab] logits tensor never materializes (critical for the
    262k/152k-vocab architectures)."""
    b, s, d = hidden.shape
    n_chunks = max(1, min(n_chunks, s))
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks

    @jax.checkpoint
    def chunk_nll(h, t):
        lg = L.logits(embed_params, h, cfg)             # [b, cs, Vp]
        lg = plan.act(lg, "logits").astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        return (logz - tgt).sum()

    total = jnp.zeros((), jnp.float32)
    for c in range(n_chunks):
        sl = slice(c * cs, (c + 1) * cs)
        total = total + chunk_nll(hidden[:, sl], targets[:, sl])
    return total


def lm_loss(params, cfg: ModelConfig, batch, plan=NULL_PLAN,
            aux_weight: float = 0.01, remat: bool = True,
            ce_chunks: int = 8):
    """Next-token CE (+ MoE aux). labels = tokens shifted; stub-modality
    prefixes (vision patches) are excluded from the loss."""
    x, aux = lm_hidden(params, cfg, batch, plan, remat=remat)
    tokens = batch["tokens"]
    prefix = x.shape[1] - tokens.shape[1]                  # vision prefix len
    h = x[:, prefix: prefix + tokens.shape[1] - 1]         # predicts t+1
    tgt = tokens[:, 1:]
    nll_sum = chunked_ce(params["embed"], cfg, h, tgt, plan, ce_chunks)
    denom = float(np.prod(tgt.shape))
    loss = nll_sum / denom
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill: forward + emit decode caches
# ---------------------------------------------------------------------------

def lm_prefill(params, cfg: ModelConfig, batch, plan=NULL_PLAN):
    """Like lm_forward but also returns per-layer decode state (caches in the
    two-tier layout, chunk count = plan.cache_chunks)."""
    x = _embed_inputs(params, cfg, batch, plan)
    b, s, d = x.shape
    cp = plan.cp if plan.attn_mode == "cp" else 1
    if cp > 1:
        x = plan.act(x.reshape(b, cp, s // cp, d), "cp_bpsd")

    caches: List[Any] = []
    for gi, g in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(xc, rep_params, _g=g):
            cs = []
            for bi, spec in enumerate(_g.blocks):
                xc, _, st = block_forward(rep_params[bi], xc, cfg, spec, plan,
                                          return_kv=True)
                cs.append(_to_decode_state(st, spec, cfg, s, plan))
            return xc, tuple(cs)

        x, group_caches = jax.lax.scan(lambda c, p_: body(c, p_), x, gp)
        caches.append(group_caches)

    if cp > 1:
        x = plan.act(x.reshape(b, s, d), "bsd")
    x = L.apply_norm(params["final_norm"], x, cfg)
    lg = L.logits(params["embed"], x, cfg)
    return plan.act(lg[:, -1], "dec_logits"), tuple(caches)


def _to_decode_state(st, spec: BlockSpec, cfg: ModelConfig, s: int, plan):
    if spec.mixer == "attn":
        k, v = st                                          # [b, s, kv, hd]
        b = k.shape[0]
        window = spec.window if spec.attn_kind == "swa" else None
        C = plan.cache_chunks
        cache_len = _cache_len(cfg, spec, s, plan)
        ln = cache_len // C
        kc = k.swapaxes(1, 2)[:, :, -cache_len:]           # [b, kv, S, hd]
        vc = v.swapaxes(1, 2)[:, :, -cache_len:]
        kc = kc.reshape(b, cfg.n_kv_heads, C, ln, cfg.hd)
        vc = vc.reshape(b, cfg.n_kv_heads, C, ln, cfg.hd)
        pos0 = s - cache_len
        old_pos = (pos0 + jnp.arange(cache_len, dtype=jnp.int32)
                   ).reshape(C, ln)
        cache = L.DecodeCache(
            k_old=plan.act(kc.astype(cfg.cdtype), "cache_old"),
            v_old=plan.act(vc.astype(cfg.cdtype), "cache_old"),
            old_pos=old_pos,
            k_rec=jnp.zeros((b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd),
                            cfg.cdtype),
            v_rec=jnp.zeros((b, cfg.n_kv_heads, L.RECENT_RING, cfg.hd),
                            cfg.cdtype),
            rec_pos=jnp.full((L.RECENT_RING,), -1, jnp.int32))
        return cache
    if spec.mixer == "mamba":
        return st                                          # MambaState
    if spec.mixer == "rwkv":
        S, xl, cm_last = st
        return R.RWKVState(wkv=S, tm_prev=xl, cm_prev=cm_last)
    raise ValueError(spec.mixer)


def _cache_len(cfg: ModelConfig, spec: BlockSpec, total: int, plan) -> int:
    """Old-tier length: full context, or the SWA window (rolling)."""
    C = plan.cache_chunks
    if spec.attn_kind == "swa" and spec.window is not None:
        n = min(total, spec.window)
    else:
        n = total
    return -(-n // C) * C                                  # round up to chunks


# ---------------------------------------------------------------------------
# decode: one token through all layers, threading caches
# ---------------------------------------------------------------------------

def lm_decode_step(params, cfg: ModelConfig, caches, token, pos,
                   plan=NULL_PLAN):
    """token: [b] int32; pos: scalar int32 (position of `token`).
    Returns (logits [b, vocab_pad], new_caches)."""
    x = L.embed(params["embed"], token, cfg)               # [b, d]
    x = plan.act(x, "dec_x")

    new_caches = []
    li = 0
    for gi, g in enumerate(cfg.layer_groups):
        gp = params[f"group{gi}"]

        def body(xc, scan_in, _g=g):
            rep_params, rep_caches = scan_in
            outs = []
            for bi, spec in enumerate(_g.blocks):
                xc, st = block_decode(rep_params[bi], xc, rep_caches[bi],
                                      cfg, spec, pos, plan)
                outs.append(st)
            return xc, tuple(outs)

        x, new_group = jax.lax.scan(lambda c, s_: body(c, s_), x,
                                    (gp, caches[gi]))
        new_caches.append(new_group)
        li += g.n_layers

    x = L.apply_norm(params["final_norm"], x, cfg)
    lg = L.logits(params["embed"], x, cfg)
    return plan.act(lg, "dec_logits"), tuple(new_caches)


def block_decode(p, x, cache, cfg: ModelConfig, spec: BlockSpec, pos, plan):
    """x: [b, d]; returns (x, new_cache)."""
    h = L.apply_norm(p["norm1"], x, cfg)
    if spec.mixer == "attn":
        theta = _rope_theta_for(cfg, spec)
        q, k, v = L.qkv_proj(p["attn"], h[:, None], cfg)   # [b,1,h/kv,hd]
        posa = pos[None] if pos.ndim == 0 else pos
        q = L.apply_rope(q, posa.astype(jnp.float32), cfg, theta)[:, 0]
        k = L.apply_rope(k, posa.astype(jnp.float32), cfg, theta)[:, 0]
        v = v[:, 0]
        window = spec.window if spec.attn_kind == "swa" else None
        cache = L.cache_append_recent(cache, k, v, pos)
        o = L.decode_attention(plan.act(q, "dec_q"), cache, pos,
                               window=window)
        o = o.reshape(x.shape[0], cfg.n_heads * cfg.hd)
        x = x + plan.act(o @ p["attn"]["wo"].astype(cfg.cdtype), "dec_x")
    elif spec.mixer == "mamba":
        o, cache = M.mamba_decode(p["mamba"], h, cache, cfg)
        x = x + plan.act(o, "dec_x")
    elif spec.mixer == "rwkv":
        o, S, xl = R.time_mix_decode(p["rwkv_tm"], h, cache, cfg)
        cache = cache._replace(wkv=S, tm_prev=xl)
        x = x + plan.act(o, "dec_x")

    if spec.ffn == "none":
        return x, cache
    h = L.apply_norm(p["norm2"], x, cfg)
    if spec.ffn == "moe":
        # decode uses the gathered-weights path: exactly top-k active FLOPs,
        # traffic = k/e of the expert weights (no capacity waste)
        out = L.moe_decode_gathered(p["moe"], h, cfg)
        x = x + plan.act(out, "dec_x")
    elif spec.mixer == "rwkv":
        o = R.channel_mix(p["rwkv_cm"], h, cache.cm_prev, cfg)
        cache = cache._replace(cm_prev=h)
        x = x + plan.act(o, "dec_x")
    else:
        x = x + plan.act(L.apply_mlp(p["mlp"], h, cfg), "dec_x")
    return x, cache


# ---------------------------------------------------------------------------
# decode-cache specs (for the dry-run: no allocation)
# ---------------------------------------------------------------------------

def decode_cache_specs(cfg: ModelConfig, b: int, seq_len: int, plan=NULL_PLAN):
    """ShapeDtypeStruct pytree mirroring what prefill would emit, stacked per
    scan group: [repeats, ...] per block position."""
    out = []
    for g in cfg.layer_groups:
        per_block = []
        for spec in g.blocks:
            if spec.mixer == "attn":
                C = plan.cache_chunks
                ln = _cache_len(cfg, spec, seq_len, plan) // C
                st = L.cache_specs(b, cfg.n_kv_heads, C, ln, cfg.hd,
                                   cfg.cdtype)
            elif spec.mixer == "mamba":
                st = M.mamba_state_specs(b, cfg, cfg.cdtype)
            else:
                st = R.rwkv_state_specs(b, cfg)
            per_block.append(_stack_specs(st, g.repeats))
        out.append(tuple(per_block))
    return tuple(out)


def _stack_specs(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), tree)
