"""Uniform model facade: every architecture exposes the same five entry
points regardless of family (decoder-only LM, enc-dec, VLM, SSM, hybrid).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax

from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.common import ModelConfig
from repro.models.plan import NULL_PLAN


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- init ----
    def init(self, key):
        if self.cfg.is_enc_dec:
            return W.init_whisper(key, self.cfg)
        return T.init_lm(key, self.cfg)

    def param_specs(self):
        """Shape-only init (never allocates) — the dry-run path."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- train ----
    def loss(self, params, batch, plan=NULL_PLAN, remat: bool = True):
        if self.cfg.is_enc_dec:
            return W.whisper_loss(params, self.cfg, batch, plan)
        return T.lm_loss(params, self.cfg, batch, plan, remat=remat)

    def forward(self, params, batch, plan=NULL_PLAN, remat: bool = True):
        if self.cfg.is_enc_dec:
            enc = W.encode(params, self.cfg, batch["frame_embeds"], plan)
            lg, _ = W.decoder_forward(params, self.cfg, batch["tokens"], enc, plan)
            return lg
        return T.lm_forward(params, self.cfg, batch, plan, remat=remat)[0]

    # ---- serve ----
    def prefill(self, params, batch, plan=NULL_PLAN):
        if self.cfg.is_enc_dec:
            return W.whisper_prefill(params, self.cfg, batch, plan)
        return T.lm_prefill(params, self.cfg, batch, plan)

    def decode_step(self, params, caches, token, pos, plan=NULL_PLAN):
        if self.cfg.is_enc_dec:
            return W.whisper_decode_step(params, self.cfg, caches, token, pos, plan)
        return T.lm_decode_step(params, self.cfg, caches, token, pos, plan)

    def cache_specs(self, b: int, seq_len: int, plan=NULL_PLAN):
        if self.cfg.is_enc_dec:
            return W.whisper_cache_specs(self.cfg, b, seq_len, plan)
        return T.decode_cache_specs(self.cfg, b, seq_len, plan)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
