"""Execution-plan protocol decoupling models from distribution.

Models call ``plan.act(x, kind)`` at layout boundaries; the runtime's
``MeshPlan`` (runtime/sharding.py) turns those into
``with_sharding_constraint``s.  The default ``NullPlan`` is the identity —
models run unchanged on a single device (all tests exploit this, including
the property test that CP chunking with any P is numerically identical to
P=1).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class NullPlan:
    # attention execution mode: "local" (replicated heads), "head_tp"
    # (heads sharded over the model axis), "cp" (contiguous-chunk context
    # parallelism over the model axis)
    attn_mode: str = "local"
    cp: int = 1                 # CP chunk count (== model axis size when sharded)
    cache_chunks: int = 1       # decode-cache old-tier chunk count
    window_gather: bool = True  # SWA layers gather only neighbor kv chunks
    moe_ep: bool = False        # expert-parallel MoE dispatch (train/prefill)
    ep: int = 1                 # EP degree (== data axis size)

    def act(self, x, kind: str):
        """Sharding-constraint hook. kind names the logical layout:
        bsd / cp_bpsd / q_bpshd / kv_rep / kv_cp / logits / moe_tokens /
        dec_x / dec_q / scores ..."""
        return x


NULL_PLAN = NullPlan()
