"""Mamba (selective SSM) block — used by jamba-v0.1 (hybrid 1:7 interleave).

Two execution modes:

* ``chunked`` (train / prefill): python-unrolled loop over time chunks with a
  ``jax.lax.associative_scan`` *inside* each chunk.  associative_scan lowers
  to a tree of real HLO ops (no while-loop), so ``cost_analysis`` counts its
  FLOPs exactly — required by the roofline methodology — and the per-chunk
  state hand-off bounds the materialized [chunk, d_inner, d_state] tensor.
* ``recurrent`` (decode / oracle): one step of the exact recurrence.

TPU adaptation note (DESIGN.md §2): the CUDA selective-scan kernel fuses the
recurrence in SRAM; on TPU we target a Pallas kernel (kernels/mamba_scan.py)
with the same chunked decomposition, MXU-aligned [128k] blocks in VMEM.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import he_normal


class MambaState(NamedTuple):
    conv: jnp.ndarray   # [b, d_inner, d_conv - 1]
    ssm: jnp.ndarray    # [b, d_inner, d_state]  (f32)


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_inner, m.d_state, m.d_conv, dt_rank


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di, ds, dc, dtr = _dims(cfg)
    ks = jax.random.split(key, 8)
    # S4D-real init for A
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                     (di, ds)))
    dt_bias = jnp.log(jnp.exp(jnp.exp(
        jax.random.uniform(ks[6], (di,), jnp.float32) *
        (math.log(0.1) - math.log(0.001)) + math.log(0.001))) - 1.0 + 1e-9)
    return {
        "in_proj": he_normal(ks[0], (d, 2 * di), cfg.pdtype),
        "conv_w": he_normal(ks[1], (dc, di), cfg.pdtype, fan_in=dc),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "x_proj": he_normal(ks[2], (di, dtr + 2 * ds), cfg.pdtype),
        "dt_proj": he_normal(ks[3], (dtr, di), cfg.pdtype, fan_in=dtr),
        "dt_bias": dt_bias.astype(cfg.pdtype),
        "a_log": a_log.astype(jnp.float32),          # keep f32: exp-sensitive
        "d_skip": jnp.ones((di,), cfg.pdtype),
        "out_proj": he_normal(ks[4], (di, d), cfg.pdtype),
    }


def _ssm_inputs(p, x, cfg: ModelConfig):
    """Shared projections. x: [b, s, d] -> (u, u_pre, z, dt_r, B, C) with
    u [b,s,di] conv'd+silu'd, z gate, dt_r [b,s,dtr] (pre-dt_proj, small —
    the [b,s,di] dt and [b,s,di,ds] discretization are materialized
    per-chunk under remat to bound the working set), B/C [b,s,ds]."""
    di, ds, dc, dtr = _dims(cfg)
    dt_ = cfg.cdtype
    xz = x @ p["in_proj"].astype(dt_)                 # [b, s, 2di]
    u_pre, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time (kernel dc)
    pad = jnp.pad(u_pre, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(pad[:, i: i + u_pre.shape[1]] * p["conv_w"].astype(dt_)[i]
               for i in range(dc))
    u = jax.nn.silu(conv + p["conv_b"].astype(dt_))

    xdbc = u @ p["x_proj"].astype(dt_)                # [b, s, dtr+2ds]
    dt_r, B, C = jnp.split(xdbc, [dtr, dtr + ds], axis=-1)
    return u, u_pre, z, dt_r, B.astype(jnp.float32), C.astype(jnp.float32)


def _chunk_scan(p, u_c, dtr_c, B_c, C_c, h_prev, cfg: ModelConfig):
    """One chunk of the selective scan; the [chunk, di, ds] discretization
    tensors live only inside this (rematted) region."""
    dt_ = cfg.cdtype
    dt = jax.nn.softplus((dtr_c @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # [b,C,di]
    A = -jnp.exp(p["a_log"])
    a_c = jnp.exp(dt[..., None] * A)                  # [b,C,di,ds]
    b_c = (dt * u_c.astype(jnp.float32))[..., None] * B_c[..., None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    cumA, hs = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
    h = cumA * h_prev[:, None] + hs                   # [b,C,di,ds]
    y = jnp.einsum("bcds,bcs->bcd", h, C_c)           # [b,C,di]
    return y, h[:, -1]


def mamba_chunked(p, x, cfg: ModelConfig, h0: jnp.ndarray = None):
    """x: [b, s, d] -> y [b, s, d].  Unrolled chunks, each rematted, with
    the per-chunk state handed across chunk boundaries."""
    b, s, d = x.shape
    di, ds, dc, dtr = _dims(cfg)
    u, u_pre, z, dt_r, B, C = _ssm_inputs(p, x, cfg)

    chunk = min(cfg.scan_chunk, s)
    h_prev = h0 if h0 is not None else jnp.zeros((b, di, ds), jnp.float32)
    chunk_fn = jax.checkpoint(
        lambda uc, dc_, bc, cc, hp: _chunk_scan(p, uc, dc_, bc, cc, hp, cfg))
    if s % chunk == 0 and s // chunk > 1:
        # scan over chunks: one chunk's [C, di, ds] working set at a time
        # (the while-loop trip count is rescaled by the roofline analyzer)
        nch = s // chunk

        def sbody(hp, xs):
            uc, dc_, bc, cc = xs
            y, hp = chunk_fn(uc, dc_, bc, cc, hp)
            return hp, y

        stack = lambda t: t.reshape(b, nch, chunk, -1).swapaxes(0, 1)
        h_prev, ys = jax.lax.scan(
            sbody, h_prev, (stack(u), stack(dt_r), stack(B), stack(C)))
        y = ys.swapaxes(0, 1).reshape(b, s, di)
    else:
        ys = []
        for c0 in range(0, s, chunk):                 # last chunk may be short
            sl = slice(c0, c0 + chunk)
            y, h_prev = chunk_fn(u[:, sl], dt_r[:, sl], B[:, sl], C[:, sl],
                                 h_prev)
            ys.append(y)
        y = jnp.concatenate(ys, axis=1) if len(ys) > 1 else ys[0]
    y = y + u.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(cfg.cdtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cfg.cdtype)
    # decode-compatible carry state: PRE-conv activations of the tail
    conv_state = (u_pre[:, -(dc - 1):].swapaxes(1, 2) if dc > 1
                  else jnp.zeros((b, di, 0), cfg.cdtype))
    return out, MambaState(conv=conv_state, ssm=h_prev)


def mamba_decode_state(b: int, cfg: ModelConfig, dtype) -> MambaState:
    di, ds, dc, _ = _dims(cfg)
    return MambaState(conv=jnp.zeros((b, di, dc - 1), dtype),
                      ssm=jnp.zeros((b, di, ds), jnp.float32))


def mamba_state_specs(b: int, cfg: ModelConfig, dtype) -> MambaState:
    di, ds, dc, _ = _dims(cfg)
    sds = jax.ShapeDtypeStruct
    return MambaState(conv=sds((b, di, dc - 1), dtype),
                      ssm=sds((b, di, ds), jnp.float32))


def mamba_decode(p, x, state: MambaState, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, MambaState]:
    """One decode step. x: [b, d] -> (y [b, d], new state)."""
    b, d = x.shape
    di, ds, dc, dtr = _dims(cfg)
    dt_ = cfg.cdtype
    xz = x @ p["in_proj"].astype(dt_)
    u, z = jnp.split(xz, 2, axis=-1)                  # [b, di]

    conv_in = jnp.concatenate([state.conv.astype(dt_), u[:, :, None]], -1)
    u = jax.nn.silu(jnp.einsum("bdc,cd->bd", conv_in, p["conv_w"].astype(dt_))
                    + p["conv_b"].astype(dt_))
    new_conv = conv_in[:, :, 1:]

    xdbc = u @ p["x_proj"].astype(dt_)
    dt_r, B, C = jnp.split(xdbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [b, di]
    A = -jnp.exp(p["a_log"])
    a_bar = jnp.exp(dt[..., None] * A)                # [b, di, ds]
    bx = (dt * u.astype(jnp.float32))[..., None] * \
        B.astype(jnp.float32)[:, None, :]             # [b, di, ds]
    h = a_bar * state.ssm + bx
    y = jnp.einsum("bds,bs->bd", h, C.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(dt_) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_), MambaState(conv=new_conv, ssm=h)


def mamba_recurrent_ref(p, x, cfg: ModelConfig):
    """Step-by-step oracle (numpy-paced scan) — used by tests only."""
    b, s, d = x.shape
    state = mamba_decode_state(b, cfg, cfg.cdtype)
    ys = []
    for t in range(s):
        y, state = mamba_decode(p, x[:, t], state, cfg)
        ys.append(y)
    return jnp.stack(ys, axis=1)
