"""Model configuration dataclasses shared by the whole zoo.

Every architecture in the pool is expressed as a ``ModelConfig``: a flat
description of the embedding/FFN/attention dimensions plus a *layer plan*
(``layer_groups``) that captures heterogeneous stacks (gemma3's 5:1
local:global pattern, jamba's mamba/attention 7:1 interleave with MoE on
alternate layers) as repeated "superblocks".  The superblock is the unit we
``lax.scan`` over, which keeps HLO size and compile time bounded while
letting ``cost_analysis`` numbers be rescaled exactly (see
runtime/hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

import jax.numpy as jnp

AttnKind = Literal["full", "swa"]
MixerKind = Literal["attn", "mamba", "rwkv"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class BlockSpec:
    """One layer of the network: a sequence mixer followed by an FFN."""

    mixer: MixerKind = "attn"
    attn_kind: AttnKind = "full"      # only for mixer == "attn"
    window: Optional[int] = None       # sliding window size for attn_kind=="swa"
    ffn: FFNKind = "dense"

    def short(self) -> str:
        m = {"attn": "A", "mamba": "M", "rwkv": "R"}[self.mixer]
        if self.mixer == "attn" and self.attn_kind == "swa":
            m = "a"
        f = {"dense": "d", "moe": "e", "none": "-"}[self.ffn]
        return m + f


@dataclass(frozen=True)
class LayerGroup:
    """``repeats`` copies of a superblock (a tuple of BlockSpecs).

    The model scans over the ``repeats`` axis with the blocks of one
    superblock unrolled inside the scan body.
    """

    blocks: Tuple[BlockSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.blocks) * self.repeats


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # expert-parallel "virtual expert" factor: each expert is split into
    # ep_virtual f-parallel slices so that n_experts * ep_virtual divides the
    # EP axis (e.g. mixtral's 8 experts -> 16 virtual on a 16-way axis).
    # Exact: SwiGLU is elementwise over f and wo contracts f, so f-slices
    # compose by summation.
    ep_virtual: int = 1

    @property
    def n_virtual(self) -> int:
        return self.n_experts * self.ep_virtual

    @property
    def d_ff_virtual(self) -> int:
        assert self.d_ff_expert % self.ep_virtual == 0
        return self.d_ff_expert // self.ep_virtual


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default: ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_dim_w: int = 64     # decay lora rank
    lora_dim_mix: int = 32   # token-shift ddlerp lora rank


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper).  The conv/patch frontend
    is a STUB: inputs are precomputed frame embeddings."""

    n_layers: int
    n_frames: int            # encoder sequence length (post-conv)
    d_model: int
    n_heads: int
    d_ff: int


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: precomputed patch embeddings are model inputs."""

    n_patches: int
    vit_dim: int


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_groups: Tuple[LayerGroup, ...]
    head_dim: Optional[int] = None   # default d_model // n_heads
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    mlp_act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None   # gemma3 uses 10k local / 1M global
    rope_pct: float = 1.0            # fraction of head_dim that is rotated
    pos_emb: Literal["rope", "learned", "sinusoidal", "none"] = "rope"
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None
    max_seq: int = 131072
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # chunk sizes for blocked computation (attention / linear-recurrence)
    attn_q_block: int = 1024
    attn_kv_block: int = 1024
    scan_chunk: int = 256            # chunked linear recurrence (mamba / rwkv)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.layer_groups)

    @property
    def all_blocks(self) -> Tuple[BlockSpec, ...]:
        out = []
        for g in self.layer_groups:
            for _ in range(g.repeats):
                out.extend(g.blocks)
        return tuple(out)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder is not None

    @property
    def is_attention_free(self) -> bool:
        return all(b.mixer != "attn" for b in self.all_blocks)

    @property
    def supports_long_decode(self) -> bool:
        """True when per-step decode state is sub-quadratic / bounded:
        attention-free, hybrid with few attn layers, or bounded-window SWA.
        Pure full-attention stacks return False (long_500k is skipped)."""
        blocks = self.all_blocks
        attn_blocks = [b for b in blocks if b.mixer == "attn"]
        if not attn_blocks:
            return True
        full = [b for b in attn_blocks if b.attn_kind == "full"]
        # all-SWA (mixtral) -> bounded rolling cache
        if not full:
            return True
        # hybrid / mostly-local: full-attn layers are a small minority and the
        # seq-sharded decode path bounds per-chip state (jamba, gemma3)
        return len(full) <= len(blocks) // 4

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        pat = "".join(b.short() for b in self.all_blocks)
        return (f"{self.arch}: {self.n_layers}L d={self.d_model} H={self.n_heads}"
                f"/kv={self.n_kv_heads} hd={self.hd} ff={self.d_ff} "
                f"V={self.vocab_size} pattern={pat}")


def uniform_groups(n_layers: int, block: BlockSpec, superblock: int = 1) -> Tuple[LayerGroup, ...]:
    """Homogeneous stack: one group scanning `n_layers // superblock` repeats."""
    assert n_layers % superblock == 0
    return (LayerGroup(blocks=(block,) * superblock, repeats=n_layers // superblock),)


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count from shapes (filled in by the builders; used by
    roofline MODEL_FLOPS).  Importing here avoids a cycle."""
    from repro.models.registry import build_model
    import jax

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return sum(int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(shapes))
