"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free, data-dependent decay.

Time-mix (WKV6) recurrence per head (k-dim i, v-dim j):

    out_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

with per-channel, per-timestep decay ``w_t = exp(-exp(w0 + lora_w(x)))``.

Modes:

* ``chunked`` (train / prefill): python-unrolled chunks; *within* a chunk
  the intra-token interaction uses the numerically-exact log-space distance
  form  ``D[t,j,i] = exp(lcw_{t-1}[i] - lcw_j[i])`` whose exponent is always
  <= 0, so it is stable for any decay values (GLA-style, without secondary
  chunking).  All ops are real HLO (no while-loops) so cost_analysis is
  exact, per the roofline methodology.
* ``recurrent`` (decode / oracle): exact single-step recurrence.

Token-shift data-dependent lerp (ddlerp) follows the paper: a shared
low-rank bottleneck modulates five interpolation gates (w,k,v,r,g).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import he_normal, lecun_normal


class RWKVState(NamedTuple):
    wkv: jnp.ndarray      # [b, h, hd, hd]  (f32) matrix state
    tm_prev: jnp.ndarray  # [b, d]  last token input of time-mix
    cm_prev: jnp.ndarray  # [b, d]  last token input of channel-mix


MIX = ("w", "k", "v", "r", "g")


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    h, hd = _dims(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 16)
    p = {
        "mu_x": jnp.full((d,), 0.5, cfg.pdtype),
        "lora_a": lecun_normal(ks[0], (d, r.lora_dim_mix * 5), cfg.pdtype),
        "lora_b": (jax.random.normal(ks[1], (5, r.lora_dim_mix, d), jnp.float32)
                   * 0.01).astype(cfg.pdtype),
        "w0": jnp.full((d,), -5.0, jnp.float32),     # decay bias (f32, exp-sensitive)
        "w_a": lecun_normal(ks[2], (d, r.lora_dim_w), cfg.pdtype),
        "w_b": (jax.random.normal(ks[3], (r.lora_dim_w, d), jnp.float32)
                * 0.01).astype(cfg.pdtype),
        "u": (jax.random.normal(ks[4], (h, hd), jnp.float32) * 0.1
              ).astype(cfg.pdtype),
        "wr": he_normal(ks[5], (d, d), cfg.pdtype),
        "wk": he_normal(ks[6], (d, d), cfg.pdtype),
        "wv": he_normal(ks[7], (d, d), cfg.pdtype),
        "wg": he_normal(ks[8], (d, d), cfg.pdtype),
        "wo": he_normal(ks[9], (d, d), cfg.pdtype),
        "ln_x": jnp.ones((d,), cfg.pdtype),          # per-head groupnorm scale
    }
    for i, m in enumerate(MIX):
        p[f"mu_{m}"] = jnp.full((d,), 0.3 + 0.1 * i, cfg.pdtype)
    return p


def init_channel_mix(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_r": jnp.full((d,), 0.5, cfg.pdtype),
        "wk": he_normal(ks[0], (d, f), cfg.pdtype),
        "wv": he_normal(ks[1], (f, d), cfg.pdtype),
        "wr": he_normal(ks[2], (d, d), cfg.pdtype),
    }


def _ddlerp(p, x, x_prev, cfg: ModelConfig):
    """Data-dependent token-shift interpolation -> dict of five mixed inputs."""
    dt = cfg.cdtype
    dx = x_prev - x
    base = x + dx * p["mu_x"].astype(dt)
    lora = jnp.tanh(base @ p["lora_a"].astype(dt))
    lora = lora.reshape(*lora.shape[:-1], 5, cfg.rwkv.lora_dim_mix)
    mods = jnp.einsum("...ml,mld->...md", lora, p["lora_b"].astype(dt))
    out = {}
    for i, m in enumerate(MIX):
        out[m] = x + dx * (p[f"mu_{m}"].astype(dt) + mods[..., i, :])
    return out


def _time_mix_proj(p, x, x_prev, cfg: ModelConfig):
    """Projections shared by chunked and recurrent paths.
    x: [..., d] -> r,k,v [..., h, hd], g [..., d], logw [..., h, hd] (f32<=~0)."""
    h, hd = _dims(cfg)
    dt = cfg.cdtype
    mix = _ddlerp(p, x, x_prev, cfg)
    r = (mix["r"] @ p["wr"].astype(dt)).reshape(*x.shape[:-1], h, hd)
    k = (mix["k"] @ p["wk"].astype(dt)).reshape(*x.shape[:-1], h, hd)
    v = (mix["v"] @ p["wv"].astype(dt)).reshape(*x.shape[:-1], h, hd)
    g = jax.nn.silu(mix["g"] @ p["wg"].astype(dt))
    ww = p["w0"] + (jnp.tanh(mix["w"] @ p["w_a"].astype(dt))
                    @ p["w_b"].astype(dt)).astype(jnp.float32)
    logw = -jnp.exp(ww)                                # log decay, <= 0
    logw = logw.reshape(*x.shape[:-1], h, hd)
    return r, k, v, g, logw


def _head_groupnorm(p, x, cfg: ModelConfig, eps=64e-5):
    """Per-head LayerNorm over hd (RWKV's ln_x), then flatten heads."""
    h, hd = _dims(cfg)
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*x.shape[:-2], h * hd) * p["ln_x"].astype(jnp.float32)
    return y


def time_mix_chunked(p, x, cfg: ModelConfig, state: RWKVState = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [b, s, d] -> (out [b, s, d], final wkv state [b,h,hd,hd], x_last)."""
    b, s, d = x.shape
    h, hd = _dims(cfg)
    x_first = state.tm_prev if state is not None else jnp.zeros((b, d), cfg.cdtype)
    x_prev = jnp.concatenate([x_first[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _time_mix_proj(p, x, x_prev, cfg)
    u = p["u"].astype(jnp.float32)

    chunk = min(cfg.scan_chunk, s)
    S = (state.wkv if state is not None
         else jnp.zeros((b, h, hd, hd), jnp.float32))
    outs = []
    for c0 in range(0, s, chunk):                      # last chunk may be short
        cl = min(chunk, s - c0)
        sl = slice(c0, c0 + cl)
        rc = r[:, sl].astype(jnp.float32)              # [b,C,h,hd]
        kc = k[:, sl].astype(jnp.float32)
        vc = v[:, sl].astype(jnp.float32)
        lw = logw[:, sl]                               # [b,C,h,hd] (<= 0)
        lcw = jnp.cumsum(lw, axis=1)                   # inclusive log cumdecay
        # ---- inter-chunk: r_t decays over everything before the chunk
        rd = rc * jnp.exp(lcw - lw)                    # r_t * cw_{t-1}
        inter = jnp.einsum("bchi,bhij->bchj", rd, S)
        # ---- intra-chunk: exact log-space distance matrix (exponent <= 0)
        # D[t,j,i] = exp(lcw[t-1,i] - lcw[j,i]) for j < t ; u-bonus at j == t
        lq = (lcw - lw)[:, :, None]                    # [b,C,1,h,hd] query side
        lk = lcw[:, None]                              # [b,1,C,h,hd] key side
        tri = jnp.tril(jnp.ones((cl, cl), jnp.bool_), k=-1)
        D = jnp.where(tri[None, :, :, None, None], jnp.exp(lq - lk), 0.0)
        att = jnp.einsum("bthi,btjhi,bjhi->bthj", rc, D, kc)
        diag = jnp.einsum("bthi,hi,bthi->bth", rc, u, kc)
        eye_tj = jnp.eye(cl, dtype=att.dtype)[None, :, None, :]  # [1,t,1,j]
        att = att + diag[..., None] * eye_tj
        intra = jnp.einsum("bthj,bjhi->bthi", att, vc)
        outs.append(inter + intra)
        # ---- state update: S' = exp(lcw[-1]) * S + sum_j exp(lcw[-1]-lcw[j]) k_j v_j
        decay_all = jnp.exp(lcw[:, -1])                # [b,h,hd]
        kd = kc * jnp.exp(lcw[:, -1][:, None] - lcw)
        S = decay_all[..., None] * S + jnp.einsum("bchi,bchj->bhij", kd, vc)

    o = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    o = _head_groupnorm(p, o.reshape(b, s, h, hd), cfg)
    o = (o.astype(cfg.cdtype) * g) @ p["wo"].astype(cfg.cdtype)
    return o, S, x[:, -1]


def time_mix_decode(p, x, state: RWKVState, cfg: ModelConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One step. x: [b, d] -> (out [b, d], new_S, x)."""
    b, d = x.shape
    h, hd = _dims(cfg)
    r, k, v, g, logw = _time_mix_proj(p, x, state.tm_prev, cfg)
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].astype(jnp.float32)
    kv = k32[..., :, None] * v32[..., None, :]          # [b,h,hd,hd]
    out = jnp.einsum("bhi,bhij->bhj", r32, state.wkv + u[..., None] * kv)
    S = jnp.exp(logw)[..., None] * state.wkv + kv
    o = _head_groupnorm(p, out, cfg)
    o = (o.astype(cfg.cdtype) * g) @ p["wo"].astype(cfg.cdtype)
    return o, S, x


def channel_mix(p, x, x_prev, cfg: ModelConfig):
    """x: [..., d]; x_prev same shape (token-shifted)."""
    dt = cfg.cdtype
    dx = x_prev - x
    xk = x + dx * p["mu_k"].astype(dt)
    xr = x + dx * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (kk @ p["wv"].astype(dt))


def rwkv_state_init(b: int, cfg: ModelConfig) -> RWKVState:
    h, hd = _dims(cfg)
    return RWKVState(wkv=jnp.zeros((b, h, hd, hd), jnp.float32),
                     tm_prev=jnp.zeros((b, cfg.d_model), cfg.cdtype),
                     cm_prev=jnp.zeros((b, cfg.d_model), cfg.cdtype))


def rwkv_state_specs(b: int, cfg: ModelConfig) -> RWKVState:
    h, hd = _dims(cfg)
    sds = jax.ShapeDtypeStruct
    return RWKVState(wkv=sds((b, h, hd, hd), jnp.float32),
                     tm_prev=sds((b, cfg.d_model), cfg.cdtype),
                     cm_prev=sds((b, cfg.d_model), cfg.cdtype))


def time_mix_recurrent_ref(p, x, cfg: ModelConfig):
    """Token-by-token oracle for tests (python loop over time)."""
    b, s, d = x.shape
    st = rwkv_state_init(b, cfg)
    outs = []
    for t in range(s):
        o, S, xl = time_mix_decode(p, x[:, t], st, cfg)
        st = st._replace(wkv=S, tm_prev=xl)
        outs.append(o)
    return jnp.stack(outs, axis=1)
