"""Core neural-net building blocks (pure JAX, functional).

Key design points (see DESIGN.md §6):

* **Blocked attention** — python-unrolled q-block × kv-block loops with an
  online-softmax accumulator.  Unrolling (instead of ``lax.scan``) keeps
  ``compiled.cost_analysis()`` exact (scan bodies are counted once by XLA's
  analysis) and lets fully-masked blocks be skipped *statically*.
* **Strided context parallelism (CP)** — when head counts don't divide the
  model axis (gemma3: 8 heads, qwen: 40 heads), queries are sharded over the
  sequence instead.  We use a *strided* chunk assignment: chunk ``p`` owns
  positions ``p, p+P, p+2P, ...`` so every chunk spans the whole range →
  causal block-skipping stays static and per-shard load is balanced (no
  stragglers), unlike contiguous CP.
* **Two-tier KV cache** — decode caches are split into a chunk-sharded
  read-only "old" tier and a small replicated "recent" ring.  The decode
  step only ever writes the replicated tier, so no dynamic-update-slice on
  a sharded dim is ever needed; a cheap ``compact_cache`` (run every R
  steps, amortized) merges recent → old.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

NEG_INF = -1e30  # large-negative for masking (bf16-safe after cast)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def he_normal(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def lecun_normal(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(1.0 / fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def zeros(_, shape, dtype):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), cfg.pdtype)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (x32 ** 2).mean(-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, theta: float) -> jnp.ndarray:
    rot = int(cfg.hd * cfg.rope_pct)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig,
               theta: Optional[float] = None) -> jnp.ndarray:
    """x: [..., s, h, hd]; positions: broadcastable to x[..., s]."""
    if cfg.pos_emb != "rope":
        return x
    theta = theta if theta is not None else cfg.rope_theta
    freqs = rope_freqs(cfg, theta)                       # [rot/2]
    rot = freqs.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., s, rot/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([y.astype(x.dtype), xp], axis=-1)


def sinusoidal_pos(seq: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": he_normal(ks[0], (d, h * hd), cfg.pdtype),
        "wk": he_normal(ks[1], (d, kv * hd), cfg.pdtype),
        "wv": he_normal(ks[2], (d, kv * hd), cfg.pdtype),
        "wo": he_normal(ks[3], (h * hd, d), cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    return p


def _qk_norm(x, scale, eps):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt((x32 ** 2).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def qkv_proj(p, x, cfg: ModelConfig):
    """x: [..., s, d] -> q [..., s, h, hd], k/v [..., s, kv, hd]."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.cdtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(*q.shape[:-1], h, hd)
    k = k.reshape(*k.shape[:-1], kv, hd)
    v = v.reshape(*v.shape[:-1], kv, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[..., s, kv, hd] -> [..., s, h, hd] by repeating each kv head."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    rep = n_heads // kv
    k = jnp.broadcast_to(k[..., :, None, :],
                         (*k.shape[:-2], kv, rep, k.shape[-1]))
    return k.reshape(*k.shape[:-3], kv * rep, k.shape[-1])


# ---------------------------------------------------------------------------
# blocked attention (train / prefill)
# ---------------------------------------------------------------------------

class _Acc(NamedTuple):
    m: jnp.ndarray    # running max       [b, P, h, sq]
    l: jnp.ndarray    # running sum       [b, P, h, sq]
    o: jnp.ndarray    # unnormalized out  [b, P, h, sq, hd]


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: Optional[int] = None,
                      q_positions: Optional[jnp.ndarray] = None,
                      kv_positions: Optional[jnp.ndarray] = None,
                      q_block: int = 1024, kv_block: int = 1024,
                      softcap: Optional[float] = None) -> jnp.ndarray:
    """Online-softmax attention, python-unrolled over q and kv blocks.

    q:  [b, P, sq, h, hd]   (P = CP chunk dim; use P=1 when not CP-sharded)
    k,v:[b, skv, kvh, hd]   (replicated over the model axis in CP mode)
    q_positions: [P, sq] global positions of the queries (strided CP layout);
        defaults to contiguous arange for P == 1.
    Returns [b, P, sq, h, hd].
    """
    b, P, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    if q_positions is None:
        assert P == 1
        q_positions = jnp.arange(sq, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(skv, dtype=jnp.int32)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    n_qb = (sq + q_block - 1) // q_block
    n_kb = (skv + kv_block - 1) // kv_block
    scale = 1.0 / math.sqrt(hd)

    kh = repeat_kv(k, h)     # [b, skv, h, hd] (broadcast view; fused by XLA)
    vh = repeat_kv(v, h)

    outs = []
    for i in range(n_qb):
        qs = slice(i * q_block, min((i + 1) * q_block, sq))
        qi = q[:, :, qs]                                # [b,P,qb,h,hd]
        pos_i = q_positions[:, qs]                      # [P,qb]
        qb = qi.shape[2]
        m = jnp.full((b, P, h, qb), NEG_INF, jnp.float32)
        l = jnp.zeros((b, P, h, qb), jnp.float32)
        o = jnp.zeros((b, P, h, qb, hd), jnp.float32)
        # static skip bounds — positions are affine in the index, so use
        # the max/min over the (concrete) iota that built them:
        pos_i_max = int(_static_max(pos_i))
        pos_i_min = int(_static_min(pos_i))
        for j in range(n_kb):
            ks_ = slice(j * kv_block, min((j + 1) * kv_block, skv))
            kpos = kv_positions[ks_]
            kmin, kmax = int(_static_min(kpos)), int(_static_max(kpos))
            if causal and kmin > pos_i_max:
                continue                                 # fully masked (future)
            if window is not None and kmax < pos_i_min - window:
                continue                                 # fully masked (past window)
            kj = kh[:, ks_]                              # [b,kb,h,hd]
            vj = vh[:, ks_]
            s = jnp.einsum("bpqhd,bkhd->bphqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            mask = None
            if causal:
                mask = pos_i[None, :, None, :, None] >= kpos[None, None, None, None, :]
            if window is not None:
                wm = kpos[None, None, None, None, :] > \
                    pos_i[None, :, None, :, None] - window
                mask = wm if mask is None else (mask & wm)
            if mask is not None:
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l = l * alpha + pexp.sum(-1)
            o = o * alpha[..., None] + jnp.einsum(
                "bphqk,bkhd->bphqd", pexp.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            m = m_new
        o = o / jnp.maximum(l[..., None], 1e-30)
        outs.append(o.transpose(0, 1, 3, 2, 4))          # [b,P,qb,h,hd]
    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return out.astype(q.dtype)


def _static_max(x: jnp.ndarray) -> int:
    """Max of a trace-time-constant int array (positions are iota-built)."""
    import numpy as np
    return int(np.max(jax.device_get(_force_concrete(x))))


def _static_min(x: jnp.ndarray) -> int:
    import numpy as np
    return int(np.min(jax.device_get(_force_concrete(x))))


def _force_concrete(x):
    # positions arrays are built from numpy at trace time in all callers
    import numpy as np
    if isinstance(x, np.ndarray):
        return x
    try:
        return np.asarray(x)
    except Exception as e:  # pragma: no cover
        raise ValueError("attention positions must be trace-time constants") from e


def strided_positions(P: int, sq_local: int) -> "np.ndarray":  # noqa: F821
    """Strided CP layout: chunk p owns global positions p, p+P, p+2P, ..."""
    import numpy as np
    return (np.arange(P, dtype=np.int32)[:, None]
            + P * np.arange(sq_local, dtype=np.int32)[None, :])


# ---------------------------------------------------------------------------
# two-tier decode KV cache
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Per-attention-layer decode cache.

    k_old/v_old: [b, kv, C, L, hd]  chunk-sharded over the model axis (C) or
                 head-sharded (kv) — read-only within a decode step.
    old_pos:     [C, L] int32        global position of every old slot
                 (== -1 for invalid slots).
    k_rec/v_rec: [b, kv, R, hd]      replicated ring, written every step.
    rec_pos:     [R] int32           global position per recent slot (-1 invalid).
    """
    k_old: jnp.ndarray
    v_old: jnp.ndarray
    old_pos: jnp.ndarray
    k_rec: jnp.ndarray
    v_rec: jnp.ndarray
    rec_pos: jnp.ndarray


RECENT_RING = 64


def make_decode_cache(b: int, kv: int, chunks: int, chunk_len: int, hd: int,
                      dtype, prefilled: int = 0, recent: int = RECENT_RING
                      ) -> DecodeCache:
    """Empty (or logically-prefilled) cache. old_pos marks validity."""
    pos = (jnp.arange(chunks * chunk_len, dtype=jnp.int32)
           .reshape(chunks, chunk_len))
    old_pos = jnp.where(pos < prefilled, pos, -1)
    return DecodeCache(
        k_old=jnp.zeros((b, kv, chunks, chunk_len, hd), dtype),
        v_old=jnp.zeros((b, kv, chunks, chunk_len, hd), dtype),
        old_pos=old_pos,
        k_rec=jnp.zeros((b, kv, recent, hd), dtype),
        v_rec=jnp.zeros((b, kv, recent, hd), dtype),
        rec_pos=jnp.full((recent,), -1, jnp.int32),
    )


def cache_specs(b, kv, chunks, chunk_len, hd, dtype, recent: int = RECENT_RING):
    """ShapeDtypeStructs mirroring make_decode_cache (for dry-run lowering)."""
    sds = jax.ShapeDtypeStruct
    return DecodeCache(
        k_old=sds((b, kv, chunks, chunk_len, hd), dtype),
        v_old=sds((b, kv, chunks, chunk_len, hd), dtype),
        old_pos=sds((chunks, chunk_len), jnp.int32),
        k_rec=sds((b, kv, recent, hd), dtype),
        v_rec=sds((b, kv, recent, hd), dtype),
        rec_pos=sds((recent,), jnp.int32),
    )


def decode_attention(q: jnp.ndarray, cache: DecodeCache, pos: jnp.ndarray, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jnp.ndarray:
    """One-token attention against a two-tier cache.

    q: [b, h, hd]; pos: scalar int32 (current position).
    Softmax statistics over the chunk-sharded old tier partition cleanly:
    max/sum over the sharded dims become tiny all-reduces under GSPMD.
    """
    b, h, hd = q.shape
    kv = cache.k_old.shape[1]
    scale = 1.0 / math.sqrt(hd)
    g = h // kv
    qg = q.reshape(b, kv, g, hd)

    s_old = jnp.einsum("bkgd,bkcld->bkgcl", qg, cache.k_old.astype(q.dtype),
                       preferred_element_type=jnp.float32) * scale
    s_rec = jnp.einsum("bkgd,bkrd->bkgr", qg, cache.k_rec.astype(q.dtype),
                       preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_old = jnp.tanh(s_old / softcap) * softcap
        s_rec = jnp.tanh(s_rec / softcap) * softcap

    lo = (pos - window) if window is not None else -1
    ok_old = (cache.old_pos >= 0) & (cache.old_pos <= pos)
    ok_rec = (cache.rec_pos >= 0) & (cache.rec_pos <= pos)
    if window is not None:
        ok_old = ok_old & (cache.old_pos > lo)
        ok_rec = ok_rec & (cache.rec_pos > lo)
    s_old = jnp.where(ok_old[None, None, None], s_old, NEG_INF)
    s_rec = jnp.where(ok_rec[None, None, None], s_rec, NEG_INF)

    m = jnp.maximum(s_old.max((-2, -1)), s_rec.max(-1))          # [b,kv,g]
    p_old = jnp.exp(s_old - m[..., None, None])
    p_rec = jnp.exp(s_rec - m[..., None])
    denom = p_old.sum((-2, -1)) + p_rec.sum(-1)
    o = (jnp.einsum("bkgcl,bkcld->bkgd", p_old.astype(q.dtype),
                    cache.v_old.astype(q.dtype),
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bkgr,bkrd->bkgd", p_rec.astype(q.dtype),
                      cache.v_rec.astype(q.dtype),
                      preferred_element_type=jnp.float32))
    o = o / jnp.maximum(denom[..., None], 1e-30)
    return o.reshape(b, h, hd).astype(q.dtype)


def cache_append_recent(cache: DecodeCache, k_new: jnp.ndarray,
                        v_new: jnp.ndarray, pos: jnp.ndarray) -> DecodeCache:
    """Write this step's K/V into the replicated recent ring (cheap DUS on a
    replicated buffer — never touches the sharded tier)."""
    R = cache.k_rec.shape[2]
    slot = jnp.mod(pos, R)
    k_rec = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rec, k_new[:, :, None, :].astype(cache.k_rec.dtype), slot, axis=2)
    v_rec = jax.lax.dynamic_update_slice_in_dim(
        cache.v_rec, v_new[:, :, None, :].astype(cache.v_rec.dtype), slot, axis=2)
    rec_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.rec_pos, pos[None].astype(jnp.int32), slot, axis=0)
    return cache._replace(k_rec=k_rec, v_rec=v_rec, rec_pos=rec_pos)


def compact_cache(cache: DecodeCache, pos: jnp.ndarray) -> DecodeCache:
    """Fold the recent ring into the old tier (runs every RECENT_RING steps,
    outside the measured decode step; one masked pass over the old tier)."""
    b, kvh, C, L, hd = cache.k_old.shape
    R = cache.k_rec.shape[2]
    flat_pos = cache.old_pos.reshape(C * L)
    # each recent slot lands at old slot (rec_pos mod C*L) in ring order
    tgt = jnp.mod(cache.rec_pos, C * L)
    onehot = (jnp.arange(C * L, dtype=jnp.int32)[None, :] == tgt[:, None])
    onehot = onehot & (cache.rec_pos >= 0)[:, None]           # [R, C*L]
    sel = onehot.any(0)                                        # [C*L]
    kr = jnp.einsum("rl,bkrd->bkld", onehot.astype(cache.k_rec.dtype),
                    cache.k_rec)
    vr = jnp.einsum("rl,bkrd->bkld", onehot.astype(cache.v_rec.dtype),
                    cache.v_rec)
    new_pos = (onehot.astype(jnp.int32) * cache.rec_pos[:, None]).sum(0)
    k_old = jnp.where(sel[None, None, :, None],
                      kr, cache.k_old.reshape(b, kvh, C * L, hd))
    v_old = jnp.where(sel[None, None, :, None],
                      vr, cache.v_old.reshape(b, kvh, C * L, hd))
    old_pos = jnp.where(sel, new_pos, flat_pos)
    return cache._replace(
        k_old=k_old.reshape(b, kvh, C, L, hd),
        v_old=v_old.reshape(b, kvh, C, L, hd),
        old_pos=old_pos.reshape(C, L),
        k_rec=jnp.zeros_like(cache.k_rec),
        v_rec=jnp.zeros_like(cache.v_rec),
        rec_pos=jnp.full((R,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {"wi": he_normal(ks[0], (d, f), cfg.pdtype),
                "wg": he_normal(ks[1], (d, f), cfg.pdtype),
                "wo": he_normal(ks[2], (f, d), cfg.pdtype)}
    return {"wi": he_normal(ks[0], (d, f), cfg.pdtype),
            "wo": he_normal(ks[2], (f, d), cfg.pdtype)}


def apply_mlp(p, x, cfg: ModelConfig):
    dt = cfg.cdtype
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(dt), approximate=True) * (x @ p["wi"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(dt), approximate=True)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-bounded, local dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    """Expert weights are stored in the VIRTUAL layout [e*v, d, f/v] (v=1
    unless expert parallelism needs virtual splitting) — an f-parallel
    reshape of the published [e, d, f] weights, numerically identical."""
    m = cfg.moe
    d, f, ev = cfg.d_model, m.d_ff_virtual, m.n_virtual
    ks = jax.random.split(key, 4)
    p = {"router": lecun_normal(ks[0], (d, m.n_experts), cfg.pdtype)}
    if cfg.mlp_act in ("swiglu", "geglu"):
        p["wi"] = he_normal(ks[1], (ev, d, f), cfg.pdtype, fan_in=d)
        p["wg"] = he_normal(ks[2], (ev, d, f), cfg.pdtype, fan_in=d)
    else:
        p["wi"] = he_normal(ks[1], (ev, d, f), cfg.pdtype, fan_in=d)
    p["wo"] = he_normal(ks[3], (ev, f, d), cfg.pdtype, fan_in=f)
    return p


def _virtual_assignments(top_i, top_p, v: int):
    """[T, k] expert assignments -> [T, k*v] virtual assignments (each
    expert's v f-slices all receive the token; gates repeat — f-partial
    outputs sum to the full expert output)."""
    if v == 1:
        return top_i, top_p
    vt = (top_i[..., None] * v
          + jnp.arange(v, dtype=top_i.dtype)).reshape(*top_i.shape[:-1], -1)
    vp = jnp.repeat(top_p, v, axis=-1)
    return vt, vp


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, min(n_tokens, -(-c // 8) * 8))   # round up to 8, clamp


def apply_moe(p, x, cfg: ModelConfig):
    """x: [T, d] (tokens of ONE data shard chunk — dispatch is shard-local).
    Returns ([T, d], aux) where aux carries the load-balancing loss term.
    Capacity-overflow tokens are dropped (their expert output is zero; the
    residual passes through) — the same lossy-but-tolerant philosophy the
    paper applies to parameter updates (§III-D).
    """
    m = cfg.moe
    T, d = x.shape
    e, k, v = m.n_experts, m.top_k, m.ep_virtual
    E, kv = m.n_virtual, m.top_k * m.ep_virtual
    dt = cfg.cdtype
    cap = moe_capacity(T, cfg)

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # [T, e]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    vt_i, vt_p = _virtual_assignments(top_i, top_p, v)          # [T, k*v]

    flat_e = vt_i.reshape(-1)                                    # [T*kv]
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # [T*kv, E]
    pos_in_e = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(T * kv), flat_e]
    valid = pos_in_e < cap
    tok_id = jnp.repeat(jnp.arange(T, dtype=jnp.int32), kv)

    # slot table [E, cap] of source-token ids (T == OOB sentinel row).
    # Invalid (over-capacity) entries write at expert index E == out of
    # bounds, which mode="drop" silently discards.
    slot_tok = jnp.full((E, cap), T, jnp.int32)
    slot_tok = slot_tok.at[jnp.where(valid, flat_e, E),
                           jnp.where(valid, pos_in_e, 0)].set(
        tok_id, mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], 0)
    xe = x_pad[slot_tok]                                        # [E, cap, d]

    if cfg.mlp_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else partial(
            jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt)),
                        approximate=True)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))      # [E, cap, d]

    # combine: gather each (t, k*v) virtual output back; f-partials sum
    gath = ye[flat_e, jnp.minimum(pos_in_e, cap - 1)]            # [T*kv, d]
    gath = jnp.where(valid[:, None], gath, 0.0)
    w = vt_p.reshape(-1)[:, None].astype(gath.dtype)
    out = (gath * w).reshape(T, kv, d).sum(1)

    # Switch-style load-balance aux loss
    frac_tok = jnp.mean(jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), 0)
    frac_prob = probs.mean(0)
    aux = e * jnp.sum(frac_tok * frac_prob)
    return out.astype(x.dtype), aux


def apply_moe_ep(p, x, cfg: ModelConfig, plan):
    """Expert-parallel MoE for train/prefill (beyond paper; EXPERIMENTS §Perf).

    Tokens travel to their experts' home shards instead of expert weights /
    activation buffers being resharded: experts live sharded over the data
    axis ([E, d, fv] with E = n_virtual % D == 0), tokens are dispatched with
    one all-to-all each way.  The moved payload is the capacity-padded token
    buffer (MBs) instead of expert weights (GBs).

    x: [b, s, d] with b sharded over data. Returns ([b, s, d], aux).
    """
    m = cfg.moe
    D = plan.ep
    E, kv, v = m.n_virtual, m.top_k * m.ep_virtual, m.ep_virtual
    e, k = m.n_experts, m.top_k
    assert E % D == 0, (E, D)
    e_loc = E // D
    b, s, d = x.shape
    assert b % D == 0, (b, D)
    dt = cfg.cdtype
    xl = plan.act(x.reshape(D, (b // D) * s, d), "ep_tokens")   # [D, Tl, d]
    Tl = xl.shape[1]
    cap = moe_capacity(Tl, cfg)

    def route_one(xs):
        """Local routing on one data shard. xs: [Tl, d]."""
        logits = (xs @ p["router"].astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        vt_i, vt_p = _virtual_assignments(top_i, top_p, v)      # [Tl, kv]
        flat_e = vt_i.reshape(-1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(Tl * kv), flat_e]
        valid = pos < cap
        tok_id = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), kv)
        slot_tok = jnp.full((E, cap), Tl, jnp.int32)
        slot_tok = slot_tok.at[jnp.where(valid, flat_e, E),
                               jnp.where(valid, pos, 0)].set(tok_id,
                                                             mode="drop")
        x_pad = jnp.concatenate([xs, jnp.zeros((1, d), xs.dtype)], 0)
        xe = x_pad[slot_tok]                                    # [E, cap, d]
        # Switch aux (expert-level, local stats)
        frac_tok = jnp.mean(jax.nn.one_hot(top_i[:, 0], e,
                                           dtype=jnp.float32), 0)
        aux = e * jnp.sum(frac_tok * probs.mean(0))
        return xe, flat_e, pos, valid, vt_p, aux

    xe, flat_e, pos, valid, vt_p, aux = jax.vmap(route_one)(xl)

    # ---- dispatch all-to-all: [D_src, E, cap, d] -> [D_home, e_loc, ...]
    y = xe.reshape(D, D, e_loc, cap, d).transpose(1, 2, 0, 3, 4)
    y = plan.act(y, "ep_dispatched")        # [D_home, e_loc, D_src, cap, d]
    y = y.reshape(D, e_loc, D * cap, d)

    # ---- expert compute (fully local: E over data, fv over model) --------
    fv = m.d_ff_virtual
    wi = plan.act(p["wi"].astype(dt).reshape(D, e_loc, d, fv), "ep_w_in")
    wo = plan.act(p["wo"].astype(dt).reshape(D, e_loc, fv, d), "ep_w_out")
    if "wg" in p:
        wg = plan.act(p["wg"].astype(dt).reshape(D, e_loc, d, fv), "ep_w_in")
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else partial(
            jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("hecd,hedf->hecf", y, wg)) * \
            jnp.einsum("hecd,hedf->hecf", y, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("hecd,hedf->hecf", y, wi),
                        approximate=True)
    ye = jnp.einsum("hecf,hefd->hecd", h, wo)   # [D_home, e_loc, D*cap, d]

    # ---- return all-to-all --------------------------------------------
    back = ye.reshape(D, e_loc, D, cap, d).transpose(2, 0, 1, 3, 4)
    back = plan.act(back.reshape(D, E, cap, d), "ep_returned")

    def combine_one(ye_l, flat_e_l, pos_l, valid_l, gates_l):
        gath = ye_l[flat_e_l, jnp.minimum(pos_l, cap - 1)]      # [Tl*kv, d]
        gath = jnp.where(valid_l[:, None], gath, 0.0)
        w = gates_l.reshape(-1)[:, None].astype(gath.dtype)
        return (gath * w).reshape(Tl, kv, d).sum(1)

    out = jax.vmap(combine_one)(back, flat_e, pos, valid, vt_p)
    out = plan.act(out, "ep_tokens").reshape(b, s, d)
    return out.astype(x.dtype), aux.mean()


def moe_decode_gathered(p, x, cfg: ModelConfig):
    """Decode-time MoE: gather the top-k experts' weights per token and apply
    them densely — exactly ``k`` active expert-FFNs worth of FLOPs and
    ``k/e`` of the expert bytes, no capacity padding.  x: [b, d] -> [b, d]."""
    m = cfg.moe
    b, d = x.shape
    dt = cfg.cdtype
    logits_ = (x @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits_, -1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)            # [b, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_i, top_p = _virtual_assignments(top_i, top_p, m.ep_virtual)

    wi = p["wi"].astype(dt)[top_i]                           # [b, kv, d, fv]
    wo = p["wo"].astype(dt)[top_i]                           # [b, kv, fv, d]
    if "wg" in p:
        wg = p["wg"].astype(dt)[top_i]
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else partial(
            jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("bd,bkdf->bkf", x, wg)) * \
            jnp.einsum("bd,bkdf->bkf", x, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("bd,bkdf->bkf", x, wi), approximate=True)
    y = jnp.einsum("bkf,bkfd->bkd", h, wo)
    return (y * top_p[..., None].astype(dt)).sum(1)


# ---------------------------------------------------------------------------
# embedding / logits (padded vocab, model-axis sharded)
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig, multiple: int = 16) -> int:
    v = cfg.vocab_size
    return -(-v // multiple) * multiple


def init_embedding(key, cfg: ModelConfig):
    vp = padded_vocab(cfg)
    p = {"table": lecun_normal(key, (vp, cfg.d_model), cfg.pdtype,
                               fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = lecun_normal(jax.random.fold_in(key, 1),
                                    (cfg.d_model, vp), cfg.pdtype)
    return p


def embed(p, tokens, cfg: ModelConfig):
    return p["table"].astype(cfg.cdtype)[tokens]


def logits(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        out = x @ p["table"].astype(cfg.cdtype).T
    else:
        out = x @ p["unembed"].astype(cfg.cdtype)
    if cfg.logit_softcap is not None:
        out = jnp.tanh(out / cfg.logit_softcap) * cfg.logit_softcap
    # mask padded vocab rows
    vp, v = out.shape[-1], cfg.vocab_size
    if vp != v:
        mask = jnp.arange(vp) < v
        out = jnp.where(mask, out, NEG_INF)
    return out
