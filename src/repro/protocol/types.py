"""Typed vocabulary of the VC protocol: the coordinator <-> scheme <->
transport contract (paper §III).

The paper's architecture is a coordinator handing out *parameter leases*
to untrusted, preemptible workers and assimilating whatever comes back.
This module makes that contract explicit:

* ``Lease`` — one handout.  Carries everything the protocol previously
  threaded ad hoc through ``note_handout``/``drop_result`` hooks and the
  simulator's event payloads: the (cid, uid) identity, the round, the
  reconstruction-base ref (what the client trained from — compressed
  schemes rebuild W_c = base + delta from it), the deadline, and the wire
  stats of the upload frame.  A lease is *live* while registered with the
  Coordinator; assimilate/expire/drop each consume it exactly once and
  release the base ref, so a timed-out-and-reassigned result can never be
  assimilated twice and discarded handouts can never leak buffers.
* ``ResultMeta`` — the assimilation context a scheme sees for one result
  (derived from the lease + arrival-time facts by the Coordinator).
* ``SchemeState`` — the typed, pytree-registered server state schemes
  fold over (previously an untyped ``Dict[str, Any]``).  Schemes with
  client-local state subclass it (``@scheme_state`` registers the
  subclass); ``params`` always rides the FlatParams bus.

``as_flat``/``as_tree`` are the tree<->bus boundary coercions (moved here
from core/baselines.py so baselines depend on protocol, not vice versa).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, fields
from typing import Optional

import jax

from repro.core import flat as F


def as_flat(params) -> F.FlatParams:
    """Coerce a tree onto the flat bus (no-op for FlatParams)."""
    return params if isinstance(params, F.FlatParams) else F.flatten(params)


def as_tree(params):
    """Inverse boundary: what clients/evaluators consume."""
    return F.unflatten(params) if isinstance(params, F.FlatParams) else params


class LeaseError(RuntimeError):
    """Protocol violation: acting on a lease that is not live (double
    assimilation, submit after expiry, duplicate issue)."""


# lease lifecycle: ISSUED -> IN_FLIGHT -> {ASSIMILATED | DROPPED | EXPIRED}
LEASE_ISSUED = "issued"            # handed out, client training
LEASE_IN_FLIGHT = "in-flight"      # result encoded and on the wire
LEASE_ASSIMILATED = "assimilated"  # consumed by the scheme (terminal)
LEASE_DROPPED = "dropped"          # result discarded (terminal)
LEASE_EXPIRED = "expired"          # deadline passed (terminal)

_TERMINAL = frozenset({LEASE_ASSIMILATED, LEASE_DROPPED, LEASE_EXPIRED})


@dataclass
class Lease:
    """One explicit parameter handout (cid, uid) with its full lifecycle.

    ``base`` is the reconstruction-base ref — the exact FlatParams the
    coordinator handed to the client.  It is held for the lifetime of the
    lease only: every terminal transition clears it (``released`` becomes
    True), which is the no-leak guarantee the old per-scheme
    ``_handout`` dicts provided implicitly."""

    cid: int
    uid: int
    round: int                        # work epoch; rides the wire header
    shard: int
    read_version: int                 # server version the client started from
    base: Optional[F.FlatParams]      # reconstruction-base ref
    issued_at: float
    deadline: float = math.inf
    status: str = LEASE_ISSUED
    # UPLOAD-leg wire stats, filled at submit time
    msg_id: Optional[int] = None
    frame_bytes: int = 0
    # DOWNLOAD-leg wire stats, filled at issue time: how many handout
    # frames the client had to fetch (per-shard delta handouts skip the
    # segments it already holds) and their summed REAL encoded lengths —
    # the download duration is computed from these, never assumed
    handout_frames: int = 0
    handout_bytes: int = 0

    @property
    def key(self) -> tuple:
        return (self.cid, self.uid)

    @property
    def terminal(self) -> bool:
        return self.status in _TERMINAL

    @property
    def released(self) -> bool:
        return self.base is None

    def _release(self, status: str) -> None:
        self.status = status
        self.base = None


@dataclass
class ResultMeta:
    """Assimilation context for one arrived result.  Built by the
    Coordinator from the lease plus arrival-time facts; ``base`` is the
    lease's reconstruction-base ref (None when a scheme is driven
    directly without a coordinator — schemes fall back to the current
    server params, matching the old ``_handout.pop(..., fp.buf)``)."""

    cid: int
    unit_uid: int
    epoch: int
    shard: int
    read_version: int          # server version the client started from
    server_version: int        # server version at assimilation time
    t_arrival: float = 0.0
    base: Optional[F.FlatParams] = None

    @property
    def staleness(self) -> int:
        return max(0, self.server_version - self.read_version)


# ---------------------------------------------------------------------------
# typed scheme state
# ---------------------------------------------------------------------------

def scheme_state(cls):
    """Register a SchemeState dataclass as a pytree.

    Fields named in ``cls._tree_fields`` are children (arrays / FlatParams
    / dicts of either — anything jax.tree understands); every other field
    is carried as aux data by reference (version counters, slot maps).
    """
    tree_names = tuple(cls._tree_fields)
    aux_names = tuple(f.name for f in fields(cls) if f.name not in tree_names)

    def _flatten(s):
        return (tuple(getattr(s, n) for n in tree_names),
                tuple(getattr(s, n) for n in aux_names))

    def _unflatten(aux, children):
        obj = object.__new__(cls)
        for n, v in zip(tree_names, children):
            object.__setattr__(obj, n, v)
        for n, v in zip(aux_names, aux):
            object.__setattr__(obj, n, v)
        return obj

    jax.tree_util.register_pytree_node(cls, _flatten, _unflatten)
    return cls


@scheme_state
@dataclass
class SchemeState:
    """Base server state: params on the FlatParams bus + version counter.
    Schemes without client-local state use it as-is; the others subclass
    it with typed fields (replicas, backups, barrier buffers)."""

    _tree_fields = ("params",)

    params: F.FlatParams
    version: int = 0
