"""The redesigned ServerScheme: a pure-function core over typed state.

The old contract had grown to ten loosely-coupled hooks (``note_handout``
/ ``drop_result`` / ``residual_norm`` / ``payload_flat`` / ...) with the
lease lifecycle living privately in the simulator.  The redesign splits
responsibilities cleanly:

* the **scheme** is algorithm only: fold a payload into typed
  ``SchemeState`` (``init_state`` / ``handout`` / ``assimilate`` /
  ``on_epoch``), plus a pure client-side ``encode_payload``;
* the **Coordinator** (protocol/coordinator.py) owns everything
  stateful about the protocol: lease issue/renew/expire/drop, the
  per-client error-feedback residual ledger (with O(1) norm totals),
  wire encode/decode, and the transport.

Reconstruction bases travel ON the lease (``ResultMeta.base``), so
schemes keep no per-(cid, uid) handout dicts and cannot leak them.
State-in/state-out: ``assimilate`` may mutate ``state`` in place but must
return it — callers always rebind.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.protocol.types import Lease, ResultMeta, SchemeState, as_flat


class ServerScheme:
    """Stateless-client contract: a client downloads the lease's base
    params, trains on its shard, uploads a payload; the server
    assimilates payloads in arrival order.  Fault tolerance == dropping
    any subset of leases leaves the server state valid.

    ``state.params`` is a FlatParams; conversions happen at the BOUNDARY
    only (the driver unflattens once per dispatch and flattens the
    trained tree once per result) — a scheme performs ZERO tree<->bus
    conversions per round (core/flat.py counts them;
    tests/test_simulator.py pins the per-result budget)."""

    name = "base"
    # descriptive metadata (not read by the Coordinator — handout() is
    # always consulted): schemes that assume every client reports each
    # round are not fault tolerant, and schemes with client-local
    # replicas substitute them for the server snapshot at handout
    requires_all_clients = False    # True -> not fault tolerant (BSP/EASGD-p)
    has_local_replicas = False      # True -> handout substitutes local state

    # -- server-side core ---------------------------------------------------
    def init_state(self, params0) -> SchemeState:
        return SchemeState(params=as_flat(params0))

    def handout(self, state: SchemeState, cid: int,
                default: F.FlatParams) -> F.FlatParams:
        """Params for a new lease to ``cid``.  ``default`` is the driver's
        server snapshot (the store copy the client would download);
        replica schemes override it with client-local state.  Whatever is
        returned here rides the DOWNLOAD leg as real wire frames (the
        Coordinator encodes it at issue — per-shard delta frames over a
        sharded bus), so schemes never see transfer mechanics."""
        return default

    def on_issue(self, state: SchemeState, lease: Lease) -> None:
        """Hook: a lease was issued (DC-ASGD records its
        delay-compensation backup here)."""

    def params_for_client(self, state: SchemeState,
                          cid: Optional[int] = None) -> F.FlatParams:
        """Coordinator-less compatibility shim for direct scheme use:
        what ``cid`` would be handed, defaulting to the server params
        (delegates to ``handout`` so replica schemes stay consistent)."""
        if cid is None:
            return state.params
        return self.handout(state, cid, state.params)

    def assimilate(self, state: SchemeState, payload,
                   meta: ResultMeta) -> SchemeState:
        raise NotImplementedError

    def on_epoch(self, state: SchemeState, epoch: int) -> None:
        pass

    def drop_client(self, state: SchemeState, cid: int) -> None:
        """Preemption hook: schemes with client-local state lose it here.
        (Lease release and residual cleanup are the Coordinator's job.)"""

    # -- client-side core ---------------------------------------------------
    def encode_payload(self, trained_buf: jnp.ndarray, base: F.FlatParams,
                       residual: Optional[jnp.ndarray]
                       ) -> Tuple[Any, Optional[jnp.ndarray]]:
        """PURE function of (trained weights, lease base, carried
        error-feedback residual): what travels client -> server, on the
        bus.  Returns ``(payload, new_residual)``; ``new_residual`` is
        None for schemes without error feedback (the Coordinator keeps
        the residual ledger).  The payload is what gets wire-encoded
        (transfer/wire.py): a raw buffer ships as a dense frame, a
        CompressedDelta as a sparse one.  Default: full weights."""
        return trained_buf, None

    # -- shared helper ------------------------------------------------------
    @staticmethod
    def _payload_buf(fp: F.FlatParams, payload) -> jnp.ndarray:
        """Boundary-only conversion: a payload still in tree form is
        flattened exactly ONCE here; flat payloads (the hot path) pass
        through untouched."""
        if isinstance(payload, F.FlatParams):
            return payload.buf
        if isinstance(payload, (jnp.ndarray, np.ndarray)):
            return payload
        return F.flatten_like(payload, fp.spec)
