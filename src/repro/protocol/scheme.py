"""The redesigned ServerScheme: a pure-function core over typed state.

The old contract had grown to ten loosely-coupled hooks (``note_handout``
/ ``drop_result`` / ``residual_norm`` / ``payload_flat`` / ...) with the
lease lifecycle living privately in the simulator.  The redesign splits
responsibilities cleanly:

* the **scheme** is algorithm only: fold a payload into typed
  ``SchemeState`` (``init_state`` / ``handout`` / ``assimilate`` /
  ``on_epoch``), plus a pure client-side ``encode_payload``;
* the **Coordinator** (protocol/coordinator.py) owns everything
  stateful about the protocol: lease issue/renew/expire/drop, the
  per-client error-feedback residual ledger (with O(1) norm totals),
  wire encode/decode, and the transport.

Reconstruction bases travel ON the lease (``ResultMeta.base``), so
schemes keep no per-(cid, uid) handout dicts and cannot leak them.
State-in/state-out: ``assimilate`` may mutate ``state`` in place but must
return it — callers always rebind.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.protocol.types import Lease, ResultMeta, SchemeState, as_flat


class ServerScheme:
    """Stateless-client contract: a client downloads the lease's base
    params, trains on its shard, uploads a payload; the server
    assimilates payloads in arrival order.  Fault tolerance == dropping
    any subset of leases leaves the server state valid.

    ``state.params`` is a FlatParams; conversions happen at the BOUNDARY
    only (the driver unflattens once per dispatch and flattens the
    trained tree once per result) — a scheme performs ZERO tree<->bus
    conversions per round (core/flat.py counts them;
    tests/test_simulator.py pins the per-result budget)."""

    name = "base"
    # descriptive metadata (not read by the Coordinator — handout() is
    # always consulted): schemes that assume every client reports each
    # round are not fault tolerant, and schemes with client-local
    # replicas substitute them for the server snapshot at handout
    requires_all_clients = False    # True -> not fault tolerant (BSP/EASGD-p)
    has_local_replicas = False      # True -> handout substitutes local state

    # -- server-side core ---------------------------------------------------
    def init_state(self, params0) -> SchemeState:
        return SchemeState(params=as_flat(params0))

    def handout(self, state: SchemeState, cid: int,
                default: F.FlatParams) -> F.FlatParams:
        """Params for a new lease to ``cid``.  ``default`` is the driver's
        server snapshot (the store copy the client would download);
        replica schemes override it with client-local state.  Whatever is
        returned here rides the DOWNLOAD leg as real wire frames (the
        Coordinator encodes it at issue — per-shard delta frames over a
        sharded bus), so schemes never see transfer mechanics."""
        return default

    def on_issue(self, state: SchemeState, lease: Lease) -> None:
        """Hook: a lease was issued (DC-ASGD records its
        delay-compensation backup here)."""

    def params_for_client(self, state: SchemeState,
                          cid: Optional[int] = None) -> F.FlatParams:
        """Coordinator-less compatibility shim for direct scheme use:
        what ``cid`` would be handed, defaulting to the server params
        (delegates to ``handout`` so replica schemes stay consistent)."""
        if cid is None:
            return state.params
        return self.handout(state, cid, state.params)

    def assimilate(self, state: SchemeState, payload,
                   meta: ResultMeta) -> SchemeState:
        raise NotImplementedError

    def on_epoch(self, state: SchemeState, epoch: int) -> None:
        pass

    # -- aggregation tier (protocol/aggregator.py) --------------------------
    def assimilation_retention(self, meta: ResultMeta) -> float:
        """Fraction of the pre-update server mass ``assimilate`` RETAINS
        when folding ONE result — e.g. VC-ASGD's effective alpha.  The
        aggregation tier composes this multiplicatively across a flush
        window: the merged frame's summed client weight is
        ``1 - prod(retention_i)``.  Default 1.0: pure-delta schemes
        (Downpour family) add to the server copy without discounting it,
        so their merged frame carries zero displaced server mass."""
        return 1.0

    def assimilate_aggregate(self, state: SchemeState, payload,
                             meta: ResultMeta) -> SchemeState:
        """Fold ONE merged (already pre-assimilated) aggregate frame from
        an edge aggregator: ``payload`` is a ``wire.AggregatePayload``
        whose buf M is the aggregator's fold state — the scheme's own
        per-arrival ``assimilate`` applied at the edge, seeded from the
        upstream lease base B (``meta.base``) — and whose weight w is the
        summed client mass ``1 - prod(retention)``.

        The scheme-independent staleness correction is linear::

            W' = M + (1 - w) * (W - B)

        i.e. whatever the hub folded since the aggregator's handout
        (W - B) survives scaled by the merge's retained server mass.  When
        the hub has not moved (W == B bitwise, e.g. a round-synchronous
        driver or a single serialized flush) the correction term is
        exactly zero and the hub adopts M bit-for-bit — the same floats a
        flat hub folding the window's results in arrival order would
        produce.  Schemes with client-local replicas/barriers should
        override or reject; the weighted-averaging/delta family composes
        as-is."""
        fp = state.params
        base = meta.base.buf if meta.base is not None else fp.buf
        m = self._payload_buf(fp, payload.buf)
        if isinstance(fp.buf, np.ndarray):
            # numpy-backed bus: f32 scalar/buffer math with separate
            # mul/add (no FMA), matching the eager jnp form bit-for-bit —
            # the same convention as vc_asgd_update_flat
            keep = np.float32(1.0) - np.float32(payload.weight)
            out = (np.asarray(m).astype(np.float32)
                   + keep * (fp.buf.astype(np.float32)
                             - np.asarray(base).astype(np.float32)))
        else:
            keep = jnp.float32(1.0) - jnp.float32(payload.weight)
            out = (jnp.asarray(m).astype(jnp.float32)
                   + keep * (fp.buf.astype(jnp.float32)
                             - jnp.asarray(base).astype(jnp.float32)))
        state.params = fp.with_buf(out.astype(fp.buf.dtype))
        state.version += 1
        return state

    def drop_client(self, state: SchemeState, cid: int) -> None:
        """Preemption hook: schemes with client-local state lose it here.
        (Lease release and residual cleanup are the Coordinator's job.)"""

    # -- client-side core ---------------------------------------------------
    def encode_payload(self, trained_buf: jnp.ndarray, base: F.FlatParams,
                       residual: Optional[jnp.ndarray]
                       ) -> Tuple[Any, Optional[jnp.ndarray]]:
        """PURE function of (trained weights, lease base, carried
        error-feedback residual): what travels client -> server, on the
        bus.  Returns ``(payload, new_residual)``; ``new_residual`` is
        None for schemes without error feedback (the Coordinator keeps
        the residual ledger).  The payload is what gets wire-encoded
        (transfer/wire.py): a raw buffer ships as a dense frame, a
        CompressedDelta as a sparse one.  Default: full weights."""
        return trained_buf, None

    # -- shared helper ------------------------------------------------------
    @staticmethod
    def _payload_buf(fp: F.FlatParams, payload) -> jnp.ndarray:
        """Boundary-only conversion: a payload still in tree form is
        flattened exactly ONCE here; flat payloads (the hot path) pass
        through untouched."""
        if isinstance(payload, F.FlatParams):
            return payload.buf
        if isinstance(payload, (jnp.ndarray, np.ndarray)):
            return payload
        return F.flatten_like(payload, fp.spec)
