"""VC protocol: the typed coordinator <-> scheme <-> transport boundary.

``Lease`` makes every parameter handout explicit; ``Coordinator`` owns
the lease lifecycle, the error-feedback residual ledger, the wire
boundary, and the checkpoint hooks; ``ServerScheme`` is the pure
algorithm folded over typed ``SchemeState``.  The discrete-event
simulator (core/simulator.py) and real runtimes (launch/vc_serve.py)
drive the same Coordinator — see docs/PROTOCOL.md.
"""
from repro.protocol.aggregator import Aggregator
from repro.protocol.coordinator import Coordinator
from repro.protocol.handout import HandoutService, PullStats
from repro.protocol.scheme import ServerScheme
from repro.protocol.types import (LEASE_ASSIMILATED, LEASE_DROPPED,
                                  LEASE_EXPIRED, LEASE_IN_FLIGHT,
                                  LEASE_ISSUED, Lease, LeaseError, ResultMeta,
                                  SchemeState, as_flat, as_tree, scheme_state)

__all__ = [
    "Aggregator", "Coordinator", "ServerScheme", "HandoutService",
    "PullStats", "Lease", "LeaseError", "ResultMeta",
    "SchemeState", "as_flat", "as_tree", "scheme_state",
    "LEASE_ISSUED", "LEASE_IN_FLIGHT", "LEASE_ASSIMILATED",
    "LEASE_DROPPED", "LEASE_EXPIRED",
]
