"""Read-only handout serving: the fan-out face of a Coordinator's bus.

Training clients pull the model through leases (``Coordinator.issue``);
*subscribers* — evaluators, downstream consumers, the paper's "millions
of users" — only ever READ.  ``HandoutService`` serves them the same
immutable frames the lease path ships, through the same two ledgers:

* the Coordinator's **version-vector ledger** decides WHICH chunks a
  subscriber needs (one u32 vector compare per pull; a caught-up
  subscriber fetching an unchanged server costs zero frames — on the
  read path this applies even to a single-chunk dense bus), and
* the **content-addressed frame cache** (transfer/handout_cache.py)
  guarantees each chunk is ENCODED at most once per (round,
  write-version), no matter how many subscribers pull it — the
  flash-crowd case costs one encode plus N sends instead of N encodes.

Subscriber state is one version-vector *reference* per subscriber: the
Coordinator copies-on-write when versions bump, so a million caught-up
subscribers share a handful of immutable vectors instead of holding a
million copies.

The service never mutates lease or client state — ``_refresh_bus`` is
content-driven (a version bumps exactly when bytes moved), so a
subscriber pull happening before a client's issue changes WHEN the
compare runs, never which frames anyone is sent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.protocol.coordinator import Coordinator
from repro.protocol.types import as_flat
from repro.transfer import wire
from repro.transfer.transport import Transport


@dataclass
class PullStats:
    """One subscriber pull: what crossed (or would cross) the wire."""
    frames: int = 0                 # frames served to this subscriber
    bytes: int = 0                  # summed frame lengths
    encoded_bytes: int = 0          # cache misses THIS pull paid for
    fresh: bool = False             # first pull (full download)


class HandoutService:
    """Serve read-only subscribers from a Coordinator's frame cache.

    With ``transport`` set (launch/vc_serve.py), every served frame
    crosses the broker and is decoded on receipt — real bytes over a
    real process boundary.  Without it (the discrete-event simulator at
    1M subscribers), frames are served by reference and only counted —
    they are the same immutable cache bytes either way."""

    def __init__(self, coord: Coordinator, *,
                 transport: Optional[Transport] = None):
        self.coord = coord
        self.transport = transport
        self._sub_vec: Dict[int, np.ndarray] = {}
        self.pulls = 0
        self.frames_served = 0
        self.bytes_served = 0

    @property
    def subscribers(self) -> int:
        return len(self._sub_vec)

    def pull(self, sub_id: int, params, *, round: int) -> PullStats:
        """One subscriber pull against the current ``params`` bus: send
        every chunk whose write version moved past the subscriber's
        vector (all of them on first contact), snapshot the vector, and
        account the serve.  Frames come out of the coordinator's
        content-addressed cache — a flash crowd of N subscribers behind
        one content change costs ONE encode and N serves."""
        coord = self.coord
        n = coord._refresh_bus(as_flat(params))
        vec = self._sub_vec.get(sub_id)
        if vec is None:
            changed = range(n)
        else:
            changed = np.flatnonzero(coord._bus_versions != vec).tolist()
        st = PullStats(fresh=vec is None)
        for i in changed:
            frame, fresh = coord._chunk_frame(i, round)
            if self.transport is not None:
                # prove the leg: the frame crosses the broker and must
                # decode clean (magic/version/length/crc) on receipt
                wire.decode(self.transport.recv(self.transport.send(frame)))
            st.frames += 1
            st.bytes += len(frame)
            if fresh:
                st.encoded_bytes += len(frame)
        self._sub_vec[sub_id] = coord._bus_versions
        self.pulls += 1
        self.frames_served += st.frames
        self.bytes_served += st.bytes
        return st

    def drop_subscriber(self, sub_id: int) -> None:
        """Forget a subscriber (its next pull is a full download)."""
        self._sub_vec.pop(sub_id, None)

    def reset(self) -> None:
        """Checkpoint restore: every subscriber re-pulls in full (the
        serving counters survive — they describe the whole process)."""
        self._sub_vec.clear()
