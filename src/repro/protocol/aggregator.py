"""The edge aggregator: the hierarchical tier that breaks the
single-coordinator ceiling (ROADMAP "millions of users").

An ``Aggregator`` speaks the Lease/Coordinator protocol in BOTH
directions:

* **downward** it IS a Coordinator — it issues leases to its clients with
  the same wire frames on both legs (per-shard delta handouts, dense /
  sparse result uploads), the same residual ledger, the same lifecycle
  (issue / renew / submit / deliver / expire / drop / drop_client) — all
  inherited, not reimplemented;
* **upward** it is a CLIENT of the hub: it holds ONE lease per flush
  window, pre-assimilates its clients' payloads into a transient fold
  state, and at flush submits that merged state plus the summed client
  weight upstream as ONE ``KIND_AGG`` v3 frame.

Bit-identity is by construction, not by algebra: the fold state is seeded
from the upstream lease's DECODED base (bit-identical to the hub copy at
issue) and each arriving payload is folded with the scheme's own
per-arrival ``assimilate`` — the identical float op sequence a flat hub
would execute on the same arrivals.  The merged frame's ``weight`` is
``1 - prod(retention_i)`` (``ServerScheme.assimilation_retention``); the
hub folds the frame with ``assimilate_aggregate``:
``W' = M + (1 - w) * (W - B)``, which reduces to adopting M exactly when
the hub hasn't moved since the window opened (W == B), and otherwise
scales the hub's interim progress by the merge's retained server mass.

Failure model: the aggregator owns NO durable scheme state — only the
per-window fold.  Losing an entire aggregator (``fail()``) therefore
releases its clients' leases, its residual ledger, and its upstream
lease; the hub reissues the window and nothing leaks (property-tested in
tests/test_aggregator.py).
"""
from __future__ import annotations

import itertools
import math
from typing import Optional

from repro.protocol.coordinator import Coordinator
from repro.protocol.scheme import ServerScheme
from repro.protocol.types import Lease, LeaseError, ResultMeta
from repro.transfer import wire
from repro.transfer.transport import Transport


class Aggregator(Coordinator):
    """A Coordinator whose scheme state is a transient per-window fold,
    with an upstream client face toward a hub Coordinator."""

    def __init__(self, scheme: ServerScheme, hub: Coordinator, *,
                 agg_id: int, transport: Optional[Transport] = None,
                 timeout_s: float = math.inf,
                 handout_dtype: str = "float32"):
        if scheme.requires_all_clients:
            raise ValueError(
                f"scheme {scheme.name!r} requires every client each round "
                f"(barrier/persistent-replica semantics) — partial edge "
                f"merges cannot represent it")
        # the downward face is a full Coordinator over the EDGE transport;
        # the construction-time state is a placeholder — every window
        # reseeds it from the upstream lease's decoded base.  The edge
        # inherits the whole download leg: content-addressed frame cache
        # and the (optional) bf16 handout dtype included.
        super().__init__(scheme, hub.state.params, transport=transport,
                         timeout_s=timeout_s, handout_dtype=handout_dtype)
        self.hub = hub
        self.agg_id = agg_id
        self.up_lease: Optional[Lease] = None
        self.window_retention = 1.0     # prod of per-fold retentions
        self.window_merged = 0          # results folded this window
        self.flushes = 0                # merged frames shipped upstream
        self._window_uid = itertools.count()

    # -- upstream face -------------------------------------------------------

    def open_window(self, *, round: int, now: float = 0.0, base=None,
                    read_version: Optional[int] = None,
                    deadline: Optional[float] = None) -> Lease:
        """Take a fresh upstream lease from the hub and seed the window's
        fold state from its DECODED base — the bit-exact hub copy the
        flush will be corrected against.  ``base`` defaults to the hub's
        live params (a driver with a consistency store passes its
        snapshot)."""
        if self.up_lease is not None:
            raise LeaseError(
                f"aggregator {self.agg_id} already holds upstream lease "
                f"{self.up_lease.key} — flush or fail first")
        if base is None:
            base = self.hub.state.params
        rv = self.hub.state.version if read_version is None else read_version
        self.up_lease = self.hub.issue(
            cid=self.agg_id, uid=next(self._window_uid), round=round,
            read_version=rv, base=base, now=now, deadline=deadline)
        try:
            # transient fold state: the aggregator owns no durable scheme
            # state, so a lost window costs exactly one window of results
            self.state = self.scheme.init_state(self.up_lease.base)
        except BaseException:
            # a failed seed must not wedge the aggregator holding a live
            # upstream lease no open_window() could ever replace
            lease, self.up_lease = self.up_lease, None
            self.hub.drop(lease)
            raise
        self.window_retention = 1.0
        self.window_merged = 0
        return self.up_lease

    def assimilate(self, lease: Lease, payload, *, server_version: int,
                   t_arrival: float = 0.0, params_override=None):
        """Fold one downstream result into the window — the scheme's own
        per-arrival ``assimilate`` (inherited), plus the retention
        product that becomes the merged frame's summed weight."""
        if self.up_lease is None:
            raise LeaseError(
                f"aggregator {self.agg_id} has no open window "
                f"(open_window before folding)")
        meta = ResultMeta(cid=lease.cid, unit_uid=lease.uid,
                          epoch=lease.round, shard=lease.shard,
                          read_version=lease.read_version,
                          server_version=server_version)
        retention = self.scheme.assimilation_retention(meta)
        state = super().assimilate(lease, payload,
                                   server_version=server_version,
                                   t_arrival=t_arrival,
                                   params_override=params_override)
        self.window_retention *= retention
        self.window_merged += 1
        return state

    def flush(self, now: float = 0.0) -> Optional[Lease]:
        """Close the window: submit the fold state M plus the summed
        client weight ``1 - prod(retention)`` upstream as ONE v3
        aggregate frame under the window's lease, leaving it IN_FLIGHT
        for the hub to deliver/assimilate.  A window that folded nothing
        drops its upstream lease instead (an empty merge must never count
        as a result) and returns None."""
        up, self.up_lease = self.up_lease, None
        if up is None:
            raise LeaseError(f"aggregator {self.agg_id} has no open window")
        if self.window_merged == 0:
            self.hub.drop(up)
            return None
        weight = 1.0 - self.window_retention
        self.hub.submit(up, wire.AggregatePayload(self.state.params.buf,
                                                  weight))
        self.flushes += 1
        return up

    def fail(self) -> None:
        """The whole edge dies (spot reclaim of the aggregator node):
        every downstream client's leases AND residual release, and the
        hub reclaims the upstream lease exactly as it would any client's
        — the no-leak guarantee one level up."""
        for cid in list(self._cid_leases):
            self.drop_client(cid)
        # residuals of clients with no live lease still die with the node
        for cid in list(self._res_norms):
            self.drop_client(cid)
        self._client_vec.clear()
        self.hub.drop_client(self.agg_id)
        self.up_lease = None

    # -- introspection -------------------------------------------------------

    @property
    def window_open(self) -> bool:
        return self.up_lease is not None
