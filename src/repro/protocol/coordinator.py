"""The Coordinator: one narrow object that owns the VC protocol's state.

Everything the paper's §III server does between "a client asked for
work" and "a result was folded into the server params" lives here — and
nothing else does.  The discrete-event simulator (core/simulator.py) and
a real runtime (launch/vc_serve.py) drive the SAME object; only the
notion of time differs (the caller supplies ``now``).

Responsibilities:

* **Lease lifecycle** — ``issue`` / ``renew`` / ``expire`` / ``drop`` /
  ``assimilate``.  A lease is live while in ``self.leases``; every
  terminal transition consumes it exactly once and clears its
  reconstruction-base ref.  Double assimilation (e.g. of a
  timed-out-and-reassigned result) raises ``LeaseError``.
* **Error-feedback residual ledger** — per-client residual buffers plus
  RUNNING l2-norm totals, updated at submit/drop time, so
  ``residual_norm(cid)`` and ``residual_mass()`` are O(1) dict/float
  reads instead of scans over per-(cid, uid) buffers.
* **The wire, BOTH legs** — every submitted result is encoded to a real
  transfer/wire.py frame and pushed through the ``Transport``; delivery
  decodes and validates (torn frames never assimilate).  The DOWNLOAD
  leg is symmetric: ``issue`` encodes the handout as real frames too —
  per-shard frames over a ShardedTreeSpec bus (a client re-fetches only
  the segments that changed since its last handout: delta handouts), one
  full-model dense frame at shard count 1 — and the lease's
  reconstruction base is rebuilt from the DECODED bytes (bit-identical:
  dense f32/bf16 round-trips are exact).  Frame-kind counts and byte
  totals on both legs are measured off the encoded bytes.
* **Checkpoint hooks** — the server copy is the only state that must
  survive (clients are disposable by design); ``save_checkpoint`` /
  ``restore_checkpoint`` snapshot (params, version) through the
  checkpoint manager's flat one-pass path.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.protocol.scheme import ServerScheme
from repro.protocol.types import (LEASE_ASSIMILATED, LEASE_DROPPED,
                                  LEASE_EXPIRED, LEASE_IN_FLIGHT,
                                  LEASE_ISSUED, Lease, LeaseError, ResultMeta,
                                  SchemeState, as_flat)
from repro.transfer import wire
from repro.transfer.handout_cache import HandoutCache
from repro.transfer.transport import LoopbackTransport, Transport

# download-leg frame dtypes (satellite of the content-addressed handout
# PR): f32 masters always; "bfloat16" ships half-width dense frames with
# the wire's exact bf16 round-trip (the client reconstructs exactly the
# bf16 image of the master — the same guarantee style as the existing
# f32/bf16 dense round-trip tests)
_HANDOUT_DTYPES = {"float32": "float32", "f32": "float32",
                   "bfloat16": "bfloat16", "bf16": "bfloat16"}


class Coordinator:
    """Owns leases, residuals, the wire boundary, and the scheme state."""

    def __init__(self, scheme: ServerScheme, params0, *,
                 transport: Optional[Transport] = None,
                 timeout_s: float = math.inf,
                 handout_dtype: str = "float32"):
        self.scheme = scheme
        self.state: SchemeState = scheme.init_state(as_flat(params0))
        self.transport: Transport = transport or LoopbackTransport()
        self.timeout_s = timeout_s
        try:
            self.handout_dtype = _HANDOUT_DTYPES[handout_dtype]
        except KeyError:
            raise ValueError(f"handout_dtype {handout_dtype!r} not in "
                             f"{sorted(_HANDOUT_DTYPES)}") from None
        self.leases: Dict[tuple, Lease] = {}        # (cid, uid) -> live lease
        # lease-deadline heap: (deadline, dl_seq, key), validated lazily
        # against the lease's current _dl_seq (renew pushes a fresh entry),
        # so expire() is O(1) per call when nothing is due instead of a
        # full registry scan; _cid_leases mirrors registry insertion order
        # per client for O(|client's leases|) drop_client
        self._lease_heap: List = []
        self._seq = 0
        self._cid_leases: Dict[int, Dict[tuple, None]] = {}
        # error-feedback ledger: per-client residual buffer + running norms
        self._residuals: Dict[int, jnp.ndarray] = {}
        self._res_norms: Dict[int, float] = {}
        self._res_norm_total = 0.0
        # DOWNLOAD-leg ledger (version vector): the server bus carries one
        # monotone u32 write-version per shard (`_bus_versions`, bumped when
        # the shard's bytes change vs the cached copy `_bus_cache`); each
        # client holds the version vector of its last handout
        # (`_client_vec`).  Delta handout = one O(n_shards) vector compare,
        # not a per-client byte-map diff.  `_bus_src` is an identity token
        # for the last-seen handout buffer so repeat handouts of the SAME
        # buffer skip the byte comparison entirely.  Safety is one-sided:
        # a content revert (A->B->A between a client's handouts) costs a
        # spurious re-send, never a missed one.
        self._bus_versions: Optional[np.ndarray] = None
        self._bus_cache: Optional[np.ndarray] = None
        self._bus_src = None
        self._chunk_len = 0
        self._client_vec: Dict[int, np.ndarray] = {}
        # content-addressed frame cache (transfer/handout_cache.py): each
        # chunk's frame is encoded at most once per (round, write-version)
        # and the SAME immutable bytes are served to every requester —
        # clients here, read-only subscribers via protocol/handout.py
        self.handout_cache = HandoutCache()
        self.handout_frames = 0
        self.handout_bytes = 0
        # UPLOAD-leg wire frame kinds, measured at delivery.  This dict is
        # ALSO the allow-list of kinds valid on the upload leg: handout
        # kinds (KIND_SHARD) arriving here are rejected by deliver().
        self.frames = {wire.KIND_DENSE: 0, wire.KIND_SPARSE: 0,
                       wire.KIND_AGG: 0}
        self.assimilated = 0
        self.dropped = 0
        self.expired = 0
        # extra dict of the checkpoint restore_checkpoint() last loaded
        self.restored_extra: Dict = {}

    # -- lease lifecycle -----------------------------------------------------

    def issue(self, *, cid: int, uid: int, round: int, shard: int = 0,
              read_version: int = 0, base, now: float = 0.0,
              deadline: Optional[float] = None) -> Lease:
        """Hand out params for one work unit.  ``base`` is the server
        snapshot the client downloads; replica schemes may substitute
        client-local state via ``scheme.handout``.

        The DOWNLOAD leg is real bytes: the handout is encoded to wire
        frames, pushed through the transport and delivered right here
        (the caller IS the client), so ``lease.handout_bytes`` is the
        measured transfer size and ``lease.base`` is rebuilt from the
        decoded frames — bit-identical to the handout buffer."""
        key = (cid, uid)
        if key in self.leases:
            raise LeaseError(f"lease {key} already live "
                             f"({self.leases[key].status})")
        fp = as_flat(self.scheme.handout(self.state, cid, as_flat(base)))
        lease = Lease(cid=cid, uid=uid, round=round, shard=shard,
                      read_version=read_version, base=fp, issued_at=now,
                      deadline=(now + self.timeout_s if deadline is None
                                else deadline))
        lease.base = self._deliver_handout(lease, fp)
        self.leases[key] = lease
        self._seq += 1
        lease._issue_seq = lease._dl_seq = self._seq
        try:
            # nothing with an infinite deadline can ever expire: pushing it
            # would grow the heap unboundedly under the default timeout_s=inf
            # (terminal transitions clean the heap only lazily, and expire()
            # can never pop past a finite root to reach the inf entries)
            if lease.deadline != math.inf:
                heapq.heappush(self._lease_heap,
                               (lease.deadline, self._seq, key))
            self._cid_leases.setdefault(cid, {})[key] = None
            self.scheme.on_issue(self.state, lease)
        except BaseException:
            # a half-issued lease must not outlive the failure as a live
            # registry entry: under the default timeout_s=inf nothing
            # would ever expire it, and the unit could never be reissued
            self._terminate(lease, LEASE_DROPPED)
            raise
        return lease

    def _deliver_handout(self, lease: Lease, fp: F.FlatParams
                         ) -> F.FlatParams:
        """Put the handout on the wire and take client-side delivery.

        Over a ``ShardedTreeSpec`` bus (n_shards > 1) the handout ships
        as per-shard frames (``wire.KIND_SHARD``, one per contiguous
        segment of the shard table) and only the segments whose WRITE
        VERSION moved past the client's version vector are re-sent — the
        delta-handout rule; the client patches them into its held copy.
        A plain (single-shard) bus falls back to one full-model dense
        frame.  The returned FlatParams is reconstructed from the
        DECODED bytes; dense f32/bf16 round-trips are exact, so it is
        bit-identical to ``fp`` (asserted by the protocol tests, relied
        on by the pinned simulator regression).

        Version-vector invariant: ``client_vec[i] == bus_versions[i]``
        if-and-only-if the client's held shard ``i`` is byte-identical
        to the cached bus shard ``i`` — versions are bumped exactly when
        a shard's bytes change vs the cache, and a client's vector is
        snapshotted only after its held copy was patched to the cache's
        content.  Equal version therefore ALWAYS implies equal bytes;
        the converse can fail only on a content revert (A->B->A), which
        costs a spurious re-send, never a missed one.

        Caveat (documented, not exercised by any current scenario): a
        replica scheme whose ``handout`` returns per-client buffers over
        a sharded bus would thrash the cache and bump versions on every
        alternation — extra frames, never wrong bytes.

        Every frame comes out of ``self.handout_cache`` — encoded at
        most once per (round, chunk, write-version), byte-identical to
        a fresh per-client encode because the encode closure is
        deterministic in exactly the cache key's content."""
        spec = fp.spec
        n = self._refresh_bus(fp)
        bf16 = self.handout_dtype == "bfloat16"
        if n == 1:
            # plain bus: one full-model dense frame, ALWAYS sent (no
            # delta rule at chunk count 1 — pinned behaviour), but the
            # encode itself is served from the cache
            frame, _ = self._chunk_frame(0, lease.round)
            msg = wire.decode(self.transport.recv(self.transport.send(frame)))
            held = np.asarray(msg.payload)
            if bf16:
                held = held.astype(np.float32)  # widening is exact
            lease.handout_frames += 1
            lease.handout_bytes += len(frame)
            self.handout_frames += 1
            self.handout_bytes += len(frame)
            # backend-preserving: a numpy-backed bus (flat task protocol)
            # hands out numpy — no device transfer on the hot path
            return F.FlatParams(held if isinstance(fp.buf, np.ndarray)
                                else jnp.asarray(held), spec)
        vec = self._client_vec.get(lease.cid)
        if vec is None:
            changed = range(n)                  # fresh client: full download
        else:
            changed = np.flatnonzero(self._bus_versions != vec).tolist()
        # unchanged shards were received (and bf16-rounded) from earlier
        # handouts of byte-identical content, so the bf16 image of the
        # cache IS the client's held copy for them
        held = (self._bus_cache.astype(jnp.bfloat16).astype(np.float32)
                if bf16 else self._bus_cache.copy())
        for i in changed:
            lo, hi = spec.shard_bounds(i)
            frame, _ = self._chunk_frame(i, lease.round)
            msg = wire.decode(self.transport.recv(self.transport.send(frame)))
            payload = np.asarray(msg.payload)
            held[lo:hi] = payload.astype(np.float32) if bf16 else payload
            lease.handout_frames += 1
            lease.handout_bytes += len(frame)
        self.handout_frames += lease.handout_frames
        self.handout_bytes += lease.handout_bytes
        self._client_vec[lease.cid] = self._bus_versions
        return F.FlatParams(held if isinstance(fp.buf, np.ndarray)
                            else jnp.asarray(held), spec)

    def _refresh_bus(self, fp: F.FlatParams) -> int:
        """Sync the write-version ledger to the handout buffer's current
        content and return the chunk count.  Over a ShardedTreeSpec bus
        (n_shards > 1) chunks are the bus shards; a plain bus is ONE
        chunk (versioned the same way, so read-only subscribers get the
        delta rule even at chunk count 1 — client handouts there still
        always ship the full frame, the pinned behaviour).  Shared by
        the lease path above and protocol/handout.py's subscriber
        pulls: whoever touches the bus first pays the compare, and the
        version bump is content-driven, so WHEN it runs never changes
        which frames anyone is sent."""
        spec = fp.spec
        buf = np.asarray(fp.buf)
        sharded = (isinstance(spec, F.ShardedTreeSpec) and spec.n_shards > 1)
        n = spec.n_shards if sharded else 1
        length = spec.shard_len if sharded else buf.shape[0]
        if (self._bus_versions is None or len(self._bus_versions) != n
                or self._chunk_len != length):
            self._bus_versions = np.ones(n, np.uint32)
            self._bus_cache = buf.copy()
            self._bus_src = fp.buf
            self._chunk_len = length
            self._client_vec.clear()            # stale vectors: wrong shape
            self.handout_cache.reset()          # chunk meaning changed
        elif fp.buf is not self._bus_src:
            # contiguous reshape (padded == n * shard_len) -> one
            # vectorized per-chunk comparison for the whole bus
            cache2d = self._bus_cache.reshape(n, length)
            buf2d = buf.reshape(n, length)
            moved = np.any(buf2d != cache2d, axis=1)
            if moved.any():
                self._bus_versions = self._bus_versions.copy()
                self._bus_versions[moved] += 1
                cache2d[moved] = buf2d[moved]
            self._bus_src = fp.buf
        return n

    def _chunk_frame(self, i: int, round: int):
        """One chunk's wire frame out of the content-addressed cache —
        ``(frame, fresh)``, encoded iff (round, chunk, write-version)
        was never served before.  Must be called after ``_refresh_bus``
        (the cache slice and version are the ledger's current truth)."""
        n = len(self._bus_versions)
        lo, hi = i * self._chunk_len, (i + 1) * self._chunk_len
        version = int(self._bus_versions[i])

        def encode() -> bytes:
            seg = self._bus_cache[lo:hi]
            if self.handout_dtype == "bfloat16":
                seg = seg.astype(jnp.bfloat16)
            if n == 1:
                return wire.encode_dense(seg, round=round)
            return wire.encode_shard(seg, shard=i, n_shards=n, round=round)

        return self.handout_cache.get(round=round, chunk=i, version=version,
                                      data=self._bus_cache[lo:hi],
                                      encode=encode)

    def renew(self, lease: Lease, deadline: float) -> Lease:
        """Extend a live lease's deadline (client asked for more time)."""
        self._live(lease)
        lease.deadline = deadline
        # fresh heap entry with a fresh seq; the old entry dies lazily
        # (its seq no longer matches the lease's _dl_seq).  A renewal to
        # an infinite deadline needs no entry at all — bumping _dl_seq
        # already invalidated the old finite one.
        self._seq += 1
        lease._dl_seq = self._seq
        if deadline != math.inf:
            heapq.heappush(self._lease_heap, (deadline, self._seq, lease.key))
        return lease

    def submit(self, lease: Lease, trained_buf: jnp.ndarray) -> Lease:
        """Client finished local training: encode the payload (applying
        error feedback), push the frame through the transport, and record
        the wire stats on the lease.  The upload duration is the frame's
        REAL length (``lease.frame_bytes``) — never an assumed size."""
        if self._live(lease).status != LEASE_ISSUED:
            raise LeaseError(f"lease {lease.key} already submitted "
                             f"({lease.status})")
        if isinstance(trained_buf, wire.AggregatePayload):
            # aggregation tier: the payload is already post-assimilation —
            # the edge aggregator ran the scheme encode AND the residual
            # ledger on its own downward leg, so neither applies here
            payload, new_res = trained_buf, None
        else:
            payload, new_res = self.scheme.encode_payload(
                trained_buf, lease.base, self._residuals.get(lease.cid))
        # the header carries the POST-payload residual norm; the ledger is
        # only committed after the send succeeds, so a transport failure
        # leaves submit() all-or-nothing (the mass the payload extracted is
        # not lost from the carry, and a retry re-compresses from the same
        # residual)
        norm = (float(jnp.linalg.norm(new_res)) if new_res is not None
                else self.residual_norm(lease.cid))
        frame = wire.encode(payload, round=lease.round, residual_norm=norm)
        lease.msg_id = self.transport.send(frame)
        if new_res is not None:
            self._residuals[lease.cid] = new_res
            self._res_norm_total += norm - self._res_norms.get(lease.cid, 0.0)
            self._res_norms[lease.cid] = norm
        lease.frame_bytes = len(frame)
        lease.status = LEASE_IN_FLIGHT
        return lease

    def deliver(self, lease: Lease):
        """Take delivery of the lease's frame: recv (exactly once) +
        decode — magic/version/length/crc are validated, so a torn
        transfer raises (WireError) and is never assimilated."""
        if self._live(lease).status != LEASE_IN_FLIGHT:
            raise LeaseError(f"nothing in flight for lease {lease.key} "
                             f"({lease.status})")
        msg = wire.decode(self.transport.recv(lease.msg_id))
        if msg.kind not in self.frames:
            # a handout kind (KIND_SHARD) on the upload leg: structurally
            # valid wire bytes, semantically never assimilable.  The recv
            # already consumed the frame, so the lease must terminate HERE
            # — otherwise it would sit IN_FLIGHT forever with its msg_id
            # pointing at nothing.
            self._unregister(lease)
            lease._release(LEASE_DROPPED)
            self.dropped += 1
            raise wire.WireError(
                f"frame kind {msg.kind} invalid on the upload leg "
                f"(lease {lease.key} dropped)")
        self.frames[msg.kind] += 1
        if msg.kind == wire.KIND_AGG:
            buf = (msg.payload if isinstance(self.state.params.buf,
                                             np.ndarray)
                   else jnp.asarray(msg.payload))
            return wire.AggregatePayload(buf, msg.weight)
        if (msg.kind == wire.KIND_SPARSE
                or isinstance(self.state.params.buf, np.ndarray)):
            # sparse payloads pass through; a numpy-backed bus (flat task
            # protocol) keeps the decoded payload on host — no device_put
            return msg.payload
        return jnp.asarray(msg.payload)

    def assimilate(self, lease: Lease, payload, *, server_version: int,
                   t_arrival: float = 0.0,
                   params_override: Optional[F.FlatParams] = None
                   ) -> SchemeState:
        """Fold one result into the server state and CONSUME the lease.
        A lease can be assimilated at most once — a second attempt (the
        timed-out-and-reassigned double) raises ``LeaseError``.

        ``params_override`` is the consistency-store snapshot the
        processing parameter server read (eventual: possibly stale;
        strong: the head) — it replaces ``state.params`` before the
        scheme's update, exactly as the old simulator did inline."""
        self._live(lease)
        meta = ResultMeta(cid=lease.cid, unit_uid=lease.uid,
                          epoch=lease.round, shard=lease.shard,
                          read_version=lease.read_version,
                          server_version=server_version,
                          t_arrival=t_arrival, base=lease.base)
        if params_override is not None:
            self.state.params = params_override
        if isinstance(payload, wire.AggregatePayload):
            # a merged frame from an edge aggregator: the scheme's
            # aggregate rule (W' = M + (1-w)(W - B)) instead of the
            # per-result fold — B is the lease base, already on the meta
            self.state = self.scheme.assimilate_aggregate(
                self.state, payload, meta)
        else:
            self.state = self.scheme.assimilate(self.state, payload, meta)
        self._unregister(lease)
        lease._release(LEASE_ASSIMILATED)
        self.assimilated += 1
        return self.state

    def _unregister(self, lease: Lease) -> None:
        """Remove a lease from the registry and the per-cid index (the
        deadline heap cleans up lazily)."""
        del self.leases[lease.key]
        cid_map = self._cid_leases.get(lease.cid)
        if cid_map is not None:
            cid_map.pop(lease.key, None)

    def _terminate(self, lease: Lease, status: str) -> None:
        """The single discard path (drop and expire both end here): the
        in-flight frame is dropped at the transport (bytes were still
        spent), the lease leaves the registry, and its base is released."""
        if lease.msg_id is not None:
            self.transport.drop(lease.msg_id)
        if self.leases.get(lease.key) is lease:
            self._unregister(lease)
            lease._release(status)
            if status == LEASE_EXPIRED:
                self.expired += 1
            else:
                self.dropped += 1

    def drop(self, lease: Lease) -> None:
        """Discard an in-flight result (sender died mid-upload / timeout
        reassignment).  Idempotent — dropping a lease that already
        terminated is a no-op, so the death-then-timeout double-drop is
        safe."""
        self._terminate(lease, LEASE_DROPPED)

    def expire(self, now: float) -> List[Lease]:
        """Release every live lease past its deadline (the BOINC timeout:
        the unit will be reassigned under a NEW lease; this one can never
        be assimilated afterwards).  O(1) per call when nothing is due:
        the deadline heap's root bounds the earliest live deadline."""
        heap = self._lease_heap
        out: List[Lease] = []
        while heap and heap[0][0] <= now:
            _, seq, key = heapq.heappop(heap)
            lease = self.leases.get(key)
            if lease is not None and getattr(lease, "_dl_seq", -1) == seq:
                out.append(lease)
        if out:
            # registry-insertion order, exactly the old full-scan order
            out.sort(key=lambda l: l._issue_seq)
            for lease in out:
                self._terminate(lease, LEASE_EXPIRED)
        return out

    def drop_client(self, cid: int) -> None:
        """Preemption: the client is gone.  Scheme-local state (replicas)
        is dropped, every lease held by the client is released, and the
        client-side residual AND version-vector ledgers forget it (both
        lived on the dead instance — a respawned client re-downloads the
        full model) — running norm totals updated, never rescanned."""
        self.scheme.drop_client(self.state, cid)
        for key in list(self._cid_leases.get(cid, ())):
            self.drop(self.leases[key])
        if cid in self._res_norms:
            self._res_norm_total -= self._res_norms.pop(cid)
            self._residuals.pop(cid, None)
        self._client_vec.pop(cid, None)

    def _live(self, lease: Lease) -> Lease:
        if self.leases.get(lease.key) is not lease:
            raise LeaseError(
                f"lease {lease.key} is not live (status={lease.status}): "
                f"assimilated/expired/dropped leases are consumed exactly "
                f"once")
        return lease

    # -- error-feedback ledger (O(1) reads) ----------------------------------

    def residual_norm(self, cid: int) -> float:
        """l2 norm of the residual ``cid`` carries after its latest
        payload (0.0 for uncompressed schemes).  O(1): maintained at
        submit/drop time, rides the wire header."""
        return self._res_norms.get(cid, 0.0)

    def residual_mass(self) -> float:
        """Running total of per-client residual norms — how much update
        mass is still in flight client-side across the fleet.  O(1)."""
        return self._res_norm_total

    # -- checkpoint hooks ----------------------------------------------------

    def save_checkpoint(self, manager, step: int,
                        extra: Optional[Dict] = None) -> None:
        """Snapshot the durable protocol state (server params + version)
        through the manager's one-pass flat path.  Leases/residuals are
        deliberately NOT persisted: in-flight work is disposable by
        design — a restarted coordinator reissues it."""
        manager.save_server(step, self.state.params, self.state.version,
                            extra=extra)

    def restore_checkpoint(self, manager) -> Optional[int]:
        """Resume (params, version) from the newest server checkpoint.
        Returns the checkpoint step, or None if there was nothing to
        restore (state untouched).  The checkpoint's ``extra`` dict lands
        in ``self.restored_extra`` so a runtime can resume its own
        counters (e.g. launch/vc_serve.py's next uid).

        Scheme-local state is REBUILT from the restored params via
        ``init_state`` (not patched in place): replicas/backups derived
        from the construction-time init would otherwise be inconsistent
        with the restored center — e.g. a resumed EASGDFlatPod would hand
        out replica rows tiled from the random fresh init."""
        step = manager.latest_step()
        if step is None:
            return None
        # everything in flight predates the restore point: live leases are
        # dropped (bases released, frames discarded at the transport) and
        # the error-feedback ledger is reset — residual mass accumulated
        # AFTER the checkpoint must not be re-injected into the restored
        # params, and residual_mass() must not report it.  A restarted
        # coordinator reissues the work under fresh leases.
        for lease in list(self.leases.values()):
            self.drop(lease)
        self._lease_heap.clear()
        self._residuals.clear()
        self._res_norms.clear()
        self._res_norm_total = 0.0
        params, version, extra, _ = manager.restore_server_or_init(
            self.state.params, lambda: None)
        self.state = self.scheme.init_state(params)
        self.state.version = version
        self.restored_extra = dict(extra)
        # every client re-downloads in full: forget their version vectors
        # (bus versions stay monotone across the restore)
        self._client_vec.clear()
        # cached frames embed their round in the header; a resumed server
        # may re-issue rounds, so the frame cache and its watermark start
        # clean (correctness never depended on them — pure memoization)
        self.handout_cache.reset()
        return step

    # -- introspection -------------------------------------------------------

    @property
    def wire_stats(self):
        return self.transport.stats

    @property
    def in_flight(self) -> int:
        return len(self.leases)
