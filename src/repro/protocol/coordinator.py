"""The Coordinator: one narrow object that owns the VC protocol's state.

Everything the paper's §III server does between "a client asked for
work" and "a result was folded into the server params" lives here — and
nothing else does.  The discrete-event simulator (core/simulator.py) and
a real runtime (launch/vc_serve.py) drive the SAME object; only the
notion of time differs (the caller supplies ``now``).

Responsibilities:

* **Lease lifecycle** — ``issue`` / ``renew`` / ``expire`` / ``drop`` /
  ``assimilate``.  A lease is live while in ``self.leases``; every
  terminal transition consumes it exactly once and clears its
  reconstruction-base ref.  Double assimilation (e.g. of a
  timed-out-and-reassigned result) raises ``LeaseError``.
* **Error-feedback residual ledger** — per-client residual buffers plus
  RUNNING l2-norm totals, updated at submit/drop time, so
  ``residual_norm(cid)`` and ``residual_mass()`` are O(1) dict/float
  reads instead of scans over per-(cid, uid) buffers.
* **The wire, BOTH legs** — every submitted result is encoded to a real
  transfer/wire.py frame and pushed through the ``Transport``; delivery
  decodes and validates (torn frames never assimilate).  The DOWNLOAD
  leg is symmetric: ``issue`` encodes the handout as real frames too —
  per-shard frames over a ShardedTreeSpec bus (a client re-fetches only
  the segments that changed since its last handout: delta handouts), one
  full-model dense frame at shard count 1 — and the lease's
  reconstruction base is rebuilt from the DECODED bytes (bit-identical:
  dense f32/bf16 round-trips are exact).  Frame-kind counts and byte
  totals on both legs are measured off the encoded bytes.
* **Checkpoint hooks** — the server copy is the only state that must
  survive (clients are disposable by design); ``save_checkpoint`` /
  ``restore_checkpoint`` snapshot (params, version) through the
  checkpoint manager's flat one-pass path.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.protocol.scheme import ServerScheme
from repro.protocol.types import (LEASE_ASSIMILATED, LEASE_DROPPED,
                                  LEASE_EXPIRED, LEASE_IN_FLIGHT,
                                  LEASE_ISSUED, Lease, LeaseError, ResultMeta,
                                  SchemeState, as_flat)
from repro.transfer import wire
from repro.transfer.transport import LoopbackTransport, Transport


class Coordinator:
    """Owns leases, residuals, the wire boundary, and the scheme state."""

    def __init__(self, scheme: ServerScheme, params0, *,
                 transport: Optional[Transport] = None,
                 timeout_s: float = math.inf):
        self.scheme = scheme
        self.state: SchemeState = scheme.init_state(as_flat(params0))
        self.transport: Transport = transport or LoopbackTransport()
        self.timeout_s = timeout_s
        self.leases: Dict[tuple, Lease] = {}        # (cid, uid) -> live lease
        # error-feedback ledger: per-client residual buffer + running norms
        self._residuals: Dict[int, jnp.ndarray] = {}
        self._res_norms: Dict[int, float] = {}
        self._res_norm_total = 0.0
        # DOWNLOAD-leg ledger: the bytes each client last received, so a
        # per-shard handout re-sends only segments that changed since
        # (delta handouts; bounded by fleet size, dropped with the client)
        self._held: Dict[int, np.ndarray] = {}
        self.handout_frames = 0
        self.handout_bytes = 0
        # UPLOAD-leg wire frame kinds, measured at delivery
        self.frames = {wire.KIND_DENSE: 0, wire.KIND_SPARSE: 0}
        self.assimilated = 0
        self.dropped = 0
        self.expired = 0
        # extra dict of the checkpoint restore_checkpoint() last loaded
        self.restored_extra: Dict = {}

    # -- lease lifecycle -----------------------------------------------------

    def issue(self, *, cid: int, uid: int, round: int, shard: int = 0,
              read_version: int = 0, base, now: float = 0.0,
              deadline: Optional[float] = None) -> Lease:
        """Hand out params for one work unit.  ``base`` is the server
        snapshot the client downloads; replica schemes may substitute
        client-local state via ``scheme.handout``.

        The DOWNLOAD leg is real bytes: the handout is encoded to wire
        frames, pushed through the transport and delivered right here
        (the caller IS the client), so ``lease.handout_bytes`` is the
        measured transfer size and ``lease.base`` is rebuilt from the
        decoded frames — bit-identical to the handout buffer."""
        key = (cid, uid)
        if key in self.leases:
            raise LeaseError(f"lease {key} already live "
                             f"({self.leases[key].status})")
        fp = as_flat(self.scheme.handout(self.state, cid, as_flat(base)))
        lease = Lease(cid=cid, uid=uid, round=round, shard=shard,
                      read_version=read_version, base=fp, issued_at=now,
                      deadline=(now + self.timeout_s if deadline is None
                                else deadline))
        lease.base = self._deliver_handout(lease, fp)
        self.leases[key] = lease
        self.scheme.on_issue(self.state, lease)
        return lease

    def _deliver_handout(self, lease: Lease, fp: F.FlatParams
                         ) -> F.FlatParams:
        """Put the handout on the wire and take client-side delivery.

        Over a ``ShardedTreeSpec`` bus (n_shards > 1) the handout ships
        as per-shard frames (``wire.KIND_SHARD``, one per contiguous
        segment of the shard table) and only the segments that CHANGED
        since the client's last handout are re-sent — the delta-handout
        rule; the client patches them into its held copy.  A plain
        (single-shard) bus falls back to one full-model dense frame.
        The returned FlatParams is reconstructed from the DECODED bytes;
        dense f32/bf16 round-trips are exact, so it is bit-identical to
        ``fp`` (asserted by the protocol tests, relied on by the pinned
        simulator regression)."""
        spec = fp.spec
        buf = np.asarray(fp.buf)
        sharded = (isinstance(spec, F.ShardedTreeSpec) and spec.n_shards > 1)
        prev = self._held.get(lease.cid) if sharded else None
        if sharded:
            frames = []
            for i in range(spec.n_shards):
                lo, hi = spec.shard_bounds(i)
                if prev is not None and np.array_equal(buf[lo:hi],
                                                       prev[lo:hi]):
                    continue                    # client already holds it
                frames.append(wire.encode_shard(buf[lo:hi], shard=i,
                                                n_shards=spec.n_shards,
                                                round=lease.round))
            held = prev.copy() if prev is not None else np.zeros_like(buf)
        else:
            frames = [wire.encode_dense(buf, round=lease.round)]
            held = buf
        for frame in frames:
            msg = wire.decode(self.transport.recv(self.transport.send(frame)))
            if msg.kind == wire.KIND_SHARD:
                lo, hi = spec.shard_bounds(msg.shard)
                held[lo:hi] = np.asarray(msg.payload)
            else:
                held = np.asarray(msg.payload)
            lease.handout_frames += 1
            lease.handout_bytes += len(frame)
        self.handout_frames += lease.handout_frames
        self.handout_bytes += lease.handout_bytes
        if sharded:
            self._held[lease.cid] = held
        return F.FlatParams(jnp.asarray(held), spec)

    def renew(self, lease: Lease, deadline: float) -> Lease:
        """Extend a live lease's deadline (client asked for more time)."""
        self._live(lease)
        lease.deadline = deadline
        return lease

    def submit(self, lease: Lease, trained_buf: jnp.ndarray) -> Lease:
        """Client finished local training: encode the payload (applying
        error feedback), push the frame through the transport, and record
        the wire stats on the lease.  The upload duration is the frame's
        REAL length (``lease.frame_bytes``) — never an assumed size."""
        if self._live(lease).status != LEASE_ISSUED:
            raise LeaseError(f"lease {lease.key} already submitted "
                             f"({lease.status})")
        payload, new_res = self.scheme.encode_payload(
            trained_buf, lease.base, self._residuals.get(lease.cid))
        # the header carries the POST-payload residual norm; the ledger is
        # only committed after the send succeeds, so a transport failure
        # leaves submit() all-or-nothing (the mass the payload extracted is
        # not lost from the carry, and a retry re-compresses from the same
        # residual)
        norm = (float(jnp.linalg.norm(new_res)) if new_res is not None
                else self.residual_norm(lease.cid))
        frame = wire.encode(payload, round=lease.round, residual_norm=norm)
        lease.msg_id = self.transport.send(frame)
        if new_res is not None:
            self._residuals[lease.cid] = new_res
            self._res_norm_total += norm - self._res_norms.get(lease.cid, 0.0)
            self._res_norms[lease.cid] = norm
        lease.frame_bytes = len(frame)
        lease.status = LEASE_IN_FLIGHT
        return lease

    def deliver(self, lease: Lease):
        """Take delivery of the lease's frame: recv (exactly once) +
        decode — magic/version/length/crc are validated, so a torn
        transfer raises (WireError) and is never assimilated."""
        if self._live(lease).status != LEASE_IN_FLIGHT:
            raise LeaseError(f"nothing in flight for lease {lease.key} "
                             f"({lease.status})")
        msg = wire.decode(self.transport.recv(lease.msg_id))
        self.frames[msg.kind] += 1
        return (msg.payload if msg.kind == wire.KIND_SPARSE
                else jnp.asarray(msg.payload))

    def assimilate(self, lease: Lease, payload, *, server_version: int,
                   t_arrival: float = 0.0,
                   params_override: Optional[F.FlatParams] = None
                   ) -> SchemeState:
        """Fold one result into the server state and CONSUME the lease.
        A lease can be assimilated at most once — a second attempt (the
        timed-out-and-reassigned double) raises ``LeaseError``.

        ``params_override`` is the consistency-store snapshot the
        processing parameter server read (eventual: possibly stale;
        strong: the head) — it replaces ``state.params`` before the
        scheme's update, exactly as the old simulator did inline."""
        self._live(lease)
        meta = ResultMeta(cid=lease.cid, unit_uid=lease.uid,
                          epoch=lease.round, shard=lease.shard,
                          read_version=lease.read_version,
                          server_version=server_version,
                          t_arrival=t_arrival, base=lease.base)
        if params_override is not None:
            self.state.params = params_override
        self.state = self.scheme.assimilate(self.state, payload, meta)
        del self.leases[lease.key]
        lease._release(LEASE_ASSIMILATED)
        self.assimilated += 1
        return self.state

    def _terminate(self, lease: Lease, status: str) -> None:
        """The single discard path (drop and expire both end here): the
        in-flight frame is dropped at the transport (bytes were still
        spent), the lease leaves the registry, and its base is released."""
        if lease.msg_id is not None:
            self.transport.drop(lease.msg_id)
        if self.leases.get(lease.key) is lease:
            del self.leases[lease.key]
            lease._release(status)
            if status == LEASE_EXPIRED:
                self.expired += 1
            else:
                self.dropped += 1

    def drop(self, lease: Lease) -> None:
        """Discard an in-flight result (sender died mid-upload / timeout
        reassignment).  Idempotent — dropping a lease that already
        terminated is a no-op, so the death-then-timeout double-drop is
        safe."""
        self._terminate(lease, LEASE_DROPPED)

    def expire(self, now: float) -> List[Lease]:
        """Release every live lease past its deadline (the BOINC timeout:
        the unit will be reassigned under a NEW lease; this one can never
        be assimilated afterwards)."""
        out = [l for l in self.leases.values() if l.deadline <= now]
        for lease in out:
            self._terminate(lease, LEASE_EXPIRED)
        return out

    def drop_client(self, cid: int) -> None:
        """Preemption: the client is gone.  Scheme-local state (replicas)
        is dropped, every lease held by the client is released, and the
        client-side residual AND held-bytes ledgers forget it (both lived
        on the dead instance — a respawned client re-downloads the full
        model) — running norm totals updated, never rescanned."""
        self.scheme.drop_client(self.state, cid)
        for lease in [l for l in self.leases.values() if l.cid == cid]:
            self.drop(lease)
        if cid in self._res_norms:
            self._res_norm_total -= self._res_norms.pop(cid)
            self._residuals.pop(cid, None)
        self._held.pop(cid, None)

    def _live(self, lease: Lease) -> Lease:
        if self.leases.get(lease.key) is not lease:
            raise LeaseError(
                f"lease {lease.key} is not live (status={lease.status}): "
                f"assimilated/expired/dropped leases are consumed exactly "
                f"once")
        return lease

    # -- error-feedback ledger (O(1) reads) ----------------------------------

    def residual_norm(self, cid: int) -> float:
        """l2 norm of the residual ``cid`` carries after its latest
        payload (0.0 for uncompressed schemes).  O(1): maintained at
        submit/drop time, rides the wire header."""
        return self._res_norms.get(cid, 0.0)

    def residual_mass(self) -> float:
        """Running total of per-client residual norms — how much update
        mass is still in flight client-side across the fleet.  O(1)."""
        return self._res_norm_total

    # -- checkpoint hooks ----------------------------------------------------

    def save_checkpoint(self, manager, step: int,
                        extra: Optional[Dict] = None) -> None:
        """Snapshot the durable protocol state (server params + version)
        through the manager's one-pass flat path.  Leases/residuals are
        deliberately NOT persisted: in-flight work is disposable by
        design — a restarted coordinator reissues it."""
        manager.save_server(step, self.state.params, self.state.version,
                            extra=extra)

    def restore_checkpoint(self, manager) -> Optional[int]:
        """Resume (params, version) from the newest server checkpoint.
        Returns the checkpoint step, or None if there was nothing to
        restore (state untouched).  The checkpoint's ``extra`` dict lands
        in ``self.restored_extra`` so a runtime can resume its own
        counters (e.g. launch/vc_serve.py's next uid).

        Scheme-local state is REBUILT from the restored params via
        ``init_state`` (not patched in place): replicas/backups derived
        from the construction-time init would otherwise be inconsistent
        with the restored center — e.g. a resumed EASGDFlatPod would hand
        out replica rows tiled from the random fresh init."""
        step = manager.latest_step()
        if step is None:
            return None
        params, version, extra, _ = manager.restore_server_or_init(
            self.state.params, lambda: None)
        self.state = self.scheme.init_state(params)
        self.state.version = version
        self.restored_extra = dict(extra)
        self._held.clear()             # every client re-downloads in full
        return step

    # -- introspection -------------------------------------------------------

    @property
    def wire_stats(self):
        return self.transport.stats

    @property
    def in_flight(self) -> int:
        return len(self.leases)
