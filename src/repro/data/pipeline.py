"""Data pipeline: deterministic synthetic token streams + the work
generator's dataset sharding, with double-buffered host prefetch.

The paper's work generator splits the training set into n_t subsets
(§III-A); ``ShardedTokenDataset`` is that split for LM training — each
subtask (island round) draws only from its own shard, so the epoch
semantics of the simulator and the pod-scale runtime match.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


class SyntheticTokenSource:
    """Deterministic, seekable synthetic corpus: a mixture of Zipfian
    unigrams and a order-2 Markov chain so models have real structure to
    learn (loss actually goes down)."""

    def __init__(self, vocab_size: int, seed: int = 0, order_dim: int = 64):
        self.vocab = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        self._mix = rng.integers(1, self.vocab, size=(order_dim,))
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._probs = p / p.sum()

    def sample(self, n_seqs: int, seq_len: int, offset: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + offset)
        base = rng.choice(self.vocab, size=(n_seqs, seq_len), p=self._probs)
        # inject structure: token[t] correlates with token[t-1]
        mix = self._mix[base[:, :-1] % len(self._mix)]
        coin = rng.random((n_seqs, seq_len - 1)) < 0.35
        base[:, 1:] = np.where(coin, (base[:, :-1] + mix) % self.vocab,
                               base[:, 1:])
        return base.astype(np.int32)


@dataclass
class ShardedTokenDataset:
    """The work-generator split: n_shards disjoint sequence ranges."""
    source: SyntheticTokenSource
    n_shards: int
    seqs_per_shard: int
    seq_len: int

    def shard_batch(self, shard: int, batch: int, step: int) -> np.ndarray:
        """Deterministic batch from one shard (client subtask training)."""
        offset = shard * self.seqs_per_shard + step * batch
        return self.source.sample(batch, self.seq_len,
                                  offset=shard * 10_000_019 + step)


def make_batch_for(cfg: ModelConfig, batch: int, seq_len: int,
                   seed: int = 0) -> Dict[str, jnp.ndarray]:
    """One model-ready batch (tokens + stub modality inputs)."""
    src = SyntheticTokenSource(cfg.vocab_size, seed)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.encoder is not None:
        rng = np.random.default_rng(seed + 1)
        out["frame_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.encoder.n_frames, cfg.encoder.d_model)),
            jnp.bfloat16)
        out["tokens"] = jnp.asarray(src.sample(batch, seq_len))
    elif cfg.vision is not None:
        rng = np.random.default_rng(seed + 1)
        out["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.vision.n_patches, cfg.vision.vit_dim)), jnp.bfloat16)
        out["tokens"] = jnp.asarray(
            src.sample(batch, seq_len - cfg.vision.n_patches))
    else:
        out["tokens"] = jnp.asarray(src.sample(batch, seq_len))
    return out


def subtask_batches(cfg: ModelConfig, ds: ShardedTokenDataset, shard: int,
                    batch: int, n_steps: int) -> Iterator[Dict[str, jnp.ndarray]]:
    """Batches for one training subtask (the client's local steps)."""
    for step in range(n_steps):
        yield {"tokens": jnp.asarray(ds.shard_batch(shard, batch, step))}


class Prefetcher:
    """Host-side double buffering: overlaps batch synthesis/IO with device
    compute (one producer thread, bounded queue)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()

        def run():
            for item in it:
                self._q.put(item)
            self._q.put(self._done)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
