from repro.data.pipeline import (ShardedTokenDataset, SyntheticTokenSource,
                                 make_batch_for, subtask_batches)

__all__ = ["SyntheticTokenSource", "ShardedTokenDataset", "subtask_batches",
           "make_batch_for"]
