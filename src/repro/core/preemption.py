"""Client-fleet models: heterogeneity, network latency, preemption (§III-B,
§III-E).  All distributions are seeded and deterministic, so every
experiment in EXPERIMENTS.md reproduces bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class InstanceType:
    """Mirrors the paper's Table I fleet + §IV-E pricing."""
    name: str
    vcpu: int
    clock_ghz: float
    ram_gb: int
    net_gbps: float
    price_standard: float        # $/hr
    price_preemptible: float     # $/hr
    # relative training throughput (samples/s multiplier vs the 2.3GHz/8vCPU
    # reference server) — heterogeneity knob
    rel_speed: float = 1.0


# the paper's Table I fleet (prices from §IV-E: fleet of 5 = $1.67/hr std,
# $0.50/hr preemptible -> per-instance averages; per-type prices chosen to
# reproduce those totals with the published 70-90% discount band)
PAPER_FLEET = (
    InstanceType("c5.2xlarge-a", 8, 2.2, 32, 5, 0.340, 0.102, rel_speed=0.96),
    InstanceType("c5.2xlarge-b", 8, 2.5, 32, 5, 0.340, 0.102, rel_speed=1.09),
    InstanceType("c5a.2xlarge", 8, 2.8, 15, 2, 0.308, 0.092, rel_speed=1.22),
    InstanceType("c5a.4xlarge", 16, 2.8, 30, 2, 0.616, 0.185, rel_speed=2.30),
    InstanceType("m5.2xlarge", 8, 2.3, 61, 10, 0.384, 0.115, rel_speed=1.00),
)

SERVER_INSTANCE = InstanceType("m5.4xlarge-server", 8, 2.3, 61, 10,
                               0.768, 0.768, rel_speed=1.0)


@dataclass
class PreemptionModel:
    """Exponential instance lifetime (memoryless — matches how cloud spot
    reclaims behave at fleet scale) + restart delay."""
    mean_lifetime_s: float = 3600.0     # expected time-to-preempt
    restart_delay_s: float = 120.0      # replacement instance spin-up
    enabled: bool = True

    def sample_lifetime(self, rng: np.random.Generator) -> float:
        if not self.enabled:
            return float("inf")
        return float(rng.exponential(self.mean_lifetime_s))


@dataclass(frozen=True)
class KillSchedule:
    """Deterministic coordinator-kill injection for the fault-injection
    harness (core/simulator.py::run_preemptible_training): the coordinator
    'dies' immediately before executing each listed global step, losing
    ALL in-memory state — recovery must come entirely from the last
    one-pass train checkpoint (checkpoint/store.py).  Each kill fires
    once; steps re-reached after a restore are not re-killed."""

    kill_steps: tuple = ()

    @classmethod
    def at(cls, *steps: int) -> "KillSchedule":
        return cls(kill_steps=tuple(sorted(set(int(s) for s in steps))))

    @classmethod
    def exponential(cls, mean_interval_steps: float, horizon: int,
                    seed: int = 0) -> "KillSchedule":
        """Memoryless kill times (the spot-reclaim model of
        PreemptionModel, in steps instead of seconds)."""
        rng = np.random.default_rng(seed)
        steps, t = [], 0.0
        while True:
            t += float(rng.exponential(mean_interval_steps))
            if t >= horizon:
                break
            steps.append(int(t))
        return cls.at(*steps)


@dataclass
class LatencyModel:
    """WAN-ish transfer latency: base RTT + size/bandwidth + lognormal jitter
    (§III-B: clients in different regions see variable latency)."""
    base_s: float = 0.15
    jitter_sigma: float = 0.5

    def sample(self, rng: np.random.Generator, nbytes: float,
               net_gbps: float) -> float:
        bw = net_gbps * 1e9 / 8.0
        jitter = float(rng.lognormal(0.0, self.jitter_sigma))
        return self.base_s * jitter + nbytes / bw


@dataclass
class ClientModel:
    """One volunteer/preemptible client: instance type + stochastic state."""
    cid: int
    itype: InstanceType
    preemption: PreemptionModel
    latency: LatencyModel
    rng: np.random.Generator
    alive_until: float = 0.0
    reliability: float = 1.0            # scheduler's EMA estimate

    def spawn(self, now: float) -> None:
        self.alive_until = now + self.preemption.sample_lifetime(self.rng)

    def compute_time(self, base_cost_s: float) -> float:
        """Time to run a subtask whose reference cost is base_cost_s on the
        1.0-speed instance; +-10% run-to-run noise."""
        noise = 1.0 + 0.1 * float(self.rng.standard_normal())
        return max(base_cost_s / self.itype.rel_speed * max(noise, 0.5), 1e-3)

    def transfer_time(self, nbytes: float) -> float:
        return self.latency.sample(self.rng, nbytes, self.itype.net_gbps)


def make_fleet(n_clients: int, *, seed: int = 0,
               preemption: Optional[PreemptionModel] = None,
               latency: Optional[LatencyModel] = None) -> list[ClientModel]:
    preemption = preemption or PreemptionModel()
    latency = latency or LatencyModel()
    rng = np.random.default_rng(seed)
    fleet = []
    for cid in range(n_clients):
        itype = PAPER_FLEET[cid % len(PAPER_FLEET)]
        fleet.append(ClientModel(
            cid=cid, itype=itype, preemption=preemption, latency=latency,
            rng=np.random.default_rng(rng.integers(2 ** 63))))
    return fleet
