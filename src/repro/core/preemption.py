"""Client-fleet models: heterogeneity, network latency, preemption (§III-B,
§III-E).  All distributions are seeded and deterministic, so every
experiment in EXPERIMENTS.md reproduces bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class InstanceType:
    """Mirrors the paper's Table I fleet + §IV-E pricing."""
    name: str
    vcpu: int
    clock_ghz: float
    ram_gb: int
    net_gbps: float
    price_standard: float        # $/hr
    price_preemptible: float     # $/hr
    # relative training throughput (samples/s multiplier vs the 2.3GHz/8vCPU
    # reference server) — heterogeneity knob
    rel_speed: float = 1.0


# the paper's Table I fleet (prices from §IV-E: fleet of 5 = $1.67/hr std,
# $0.50/hr preemptible -> per-instance averages; per-type prices chosen to
# reproduce those totals with the published 70-90% discount band)
PAPER_FLEET = (
    InstanceType("c5.2xlarge-a", 8, 2.2, 32, 5, 0.340, 0.102, rel_speed=0.96),
    InstanceType("c5.2xlarge-b", 8, 2.5, 32, 5, 0.340, 0.102, rel_speed=1.09),
    InstanceType("c5a.2xlarge", 8, 2.8, 15, 2, 0.308, 0.092, rel_speed=1.22),
    InstanceType("c5a.4xlarge", 16, 2.8, 30, 2, 0.616, 0.185, rel_speed=2.30),
    InstanceType("m5.2xlarge", 8, 2.3, 61, 10, 0.384, 0.115, rel_speed=1.00),
)

SERVER_INSTANCE = InstanceType("m5.4xlarge-server", 8, 2.3, 61, 10,
                               0.768, 0.768, rel_speed=1.0)


@dataclass
class PreemptionModel:
    """Exponential instance lifetime (memoryless — matches how cloud spot
    reclaims behave at fleet scale) + restart delay."""
    mean_lifetime_s: float = 3600.0     # expected time-to-preempt
    restart_delay_s: float = 120.0      # replacement instance spin-up
    enabled: bool = True

    def sample_lifetime(self, rng: np.random.Generator) -> float:
        if not self.enabled:
            return float("inf")
        return float(rng.exponential(self.mean_lifetime_s))

    def lifetime_end(self, rng: np.random.Generator, now: float,
                     client: Optional["ClientModel"] = None) -> float:
        """Absolute sim-time this instance dies if spawned at ``now``.
        The base model is memoryless: one exponential draw past ``now``.
        Subclasses may use ``client`` (AZ, instance type) for correlated
        or time-of-day effects."""
        return now + self.sample_lifetime(rng)


@dataclass
class SpotPricePreemption(PreemptionModel):
    """Spot-market preemption: a mean-reverting per-AZ price series on a
    fixed grid; an instance is reclaimed the first time its AZ's price
    rises above the bid.  All clients in one AZ die at the same crossing
    — the paper's mass-reclaim regime, driven by an actual price path
    instead of iid lifetimes.

    The series and its upward bid-crossing times are precomputed once
    per model (deterministic in ``price_seed``), so ``lifetime_end`` is
    a single ``searchsorted``."""
    bid: float = 1.0                    # $/hr the fleet bids
    price_mean: float = 0.85            # long-run price level
    price_sigma: float = 0.12           # per-step shock scale
    price_theta: float = 0.05           # mean-reversion rate per step
    price_dt_s: float = 60.0            # grid resolution
    horizon_s: float = 7 * 24 * 3600.0  # precomputed span
    n_az: int = 3
    price_seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng((self.price_seed, 0x5307))
        n_steps = max(int(self.horizon_s / self.price_dt_s), 2)
        self._crossings = []
        for az in range(max(self.n_az, 1)):
            shocks = rng.standard_normal(n_steps)
            p = np.empty(n_steps)
            p[0] = self.price_mean
            for i in range(1, n_steps):        # AR(1) mean reversion
                p[i] = (p[i - 1]
                        + self.price_theta * (self.price_mean - p[i - 1])
                        + self.price_sigma * shocks[i])
            above = p > self.bid
            up = np.flatnonzero(above[1:] & ~above[:-1]) + 1
            self._crossings.append(up.astype(np.float64) * self.price_dt_s)

    def lifetime_end(self, rng: np.random.Generator, now: float,
                     client: Optional["ClientModel"] = None) -> float:
        del rng                             # price path is the only driver
        if not self.enabled:
            return float("inf")
        az = (client.az if client is not None else 0) % max(self.n_az, 1)
        times = self._crossings[az]
        i = int(np.searchsorted(times, now, side="right"))
        return float(times[i]) if i < len(times) else float("inf")


@dataclass
class CorrelatedReclaimModel(PreemptionModel):
    """Individual exponential lifetimes PLUS per-AZ mass reclaims: at
    Poisson times every live instance in the AZ vanishes at once (the
    thundering-herd case — all survivors of the AZ re-download the full
    model through the delta ledger when they respawn)."""
    az_reclaim_interval_s: float = 6 * 3600.0   # mean gap between AZ events
    n_az: int = 3
    horizon_s: float = 7 * 24 * 3600.0
    reclaim_seed: int = 0

    def __post_init__(self) -> None:
        self._az_times = []
        for az in range(max(self.n_az, 1)):
            rng = np.random.default_rng((self.reclaim_seed, 0xA2, az))
            t, times = 0.0, []
            while t < self.horizon_s:
                t += float(rng.exponential(self.az_reclaim_interval_s))
                times.append(t)
            self._az_times.append(np.asarray(times))

    def lifetime_end(self, rng: np.random.Generator, now: float,
                     client: Optional["ClientModel"] = None) -> float:
        if not self.enabled:
            return float("inf")
        own = now + float(rng.exponential(self.mean_lifetime_s))
        az = (client.az if client is not None else 0) % max(self.n_az, 1)
        times = self._az_times[az]
        i = int(np.searchsorted(times, now, side="right"))
        az_next = float(times[i]) if i < len(times) else float("inf")
        return min(own, az_next)


@dataclass
class DiurnalChurnModel(PreemptionModel):
    """Volunteer-computing churn: the departure hazard follows a 24h
    sinusoid (volunteers leave when their machines wake up for the day),
    phase-shifted per region.  Lifetimes are drawn by inverting the
    cumulative hazard — one Exp(1) draw + one ``searchsorted`` against a
    precomputed per-region hazard grid."""
    amplitude: float = 0.8              # hazard swing, 0..1
    period_s: float = 24 * 3600.0
    n_regions: int = 4
    grid_dt_s: float = 300.0
    horizon_s: float = 14 * 24 * 3600.0

    def __post_init__(self) -> None:
        base_rate = 1.0 / max(self.mean_lifetime_s, 1e-9)
        n = max(int(self.horizon_s / self.grid_dt_s), 2)
        t = np.arange(n) * self.grid_dt_s
        self._grid_t = t
        self._cum = []
        for r in range(max(self.n_regions, 1)):
            phase = (r / max(self.n_regions, 1)) * self.period_s
            lam = base_rate * (1.0 + self.amplitude
                               * np.sin(2 * np.pi * (t + phase)
                                        / self.period_s))
            self._cum.append(np.concatenate(
                [[0.0], np.cumsum(lam[:-1] * self.grid_dt_s)]))

    def lifetime_end(self, rng: np.random.Generator, now: float,
                     client: Optional["ClientModel"] = None) -> float:
        if not self.enabled:
            return float("inf")
        region = ((client.az if client is not None else 0)
                  % max(self.n_regions, 1))
        cum, t = self._cum[region], self._grid_t
        u = float(rng.exponential(1.0))     # target hazard mass
        base = float(np.interp(now, t, cum))
        i = int(np.searchsorted(cum, base + u, side="left"))
        if i >= len(t):                     # beyond the grid: mean rate
            tail = (base + u) - cum[-1]
            return float(t[-1] + tail * self.mean_lifetime_s)
        return float(t[i])


@dataclass(frozen=True)
class KillSchedule:
    """Deterministic coordinator-kill injection for the fault-injection
    harness (core/simulator.py::run_preemptible_training): the coordinator
    'dies' immediately before executing each listed global step, losing
    ALL in-memory state — recovery must come entirely from the last
    one-pass train checkpoint (checkpoint/store.py).  Each kill fires
    once; steps re-reached after a restore are not re-killed."""

    kill_steps: tuple = ()

    @classmethod
    def at(cls, *steps: int) -> "KillSchedule":
        return cls(kill_steps=tuple(sorted(set(int(s) for s in steps))))

    @classmethod
    def exponential(cls, mean_interval_steps: float, horizon: int,
                    seed: int = 0) -> "KillSchedule":
        """Memoryless kill times (the spot-reclaim model of
        PreemptionModel, in steps instead of seconds)."""
        rng = np.random.default_rng(seed)
        steps, t = [], 0.0
        while True:
            t += float(rng.exponential(mean_interval_steps))
            if t >= horizon:
                break
            steps.append(int(t))
        return cls.at(*steps)


@dataclass
class LatencyModel:
    """WAN-ish transfer latency: base RTT + size/bandwidth + lognormal jitter
    (§III-B: clients in different regions see variable latency)."""
    base_s: float = 0.15
    jitter_sigma: float = 0.5

    def sample(self, rng: np.random.Generator, nbytes: float,
               net_gbps: float) -> float:
        bw = net_gbps * 1e9 / 8.0
        jitter = float(rng.lognormal(0.0, self.jitter_sigma))
        return self.base_s * jitter + nbytes / bw


@dataclass
class ClientModel:
    """One volunteer/preemptible client: instance type + stochastic state."""
    cid: int
    itype: InstanceType
    preemption: PreemptionModel
    latency: LatencyModel
    rng: np.random.Generator
    alive_until: float = 0.0
    reliability: float = 1.0            # scheduler's EMA estimate
    az: int = 0                         # availability zone / region

    def spawn(self, now: float) -> None:
        self.alive_until = self.preemption.lifetime_end(self.rng, now, self)

    def compute_time(self, base_cost_s: float) -> float:
        """Time to run a subtask whose reference cost is base_cost_s on the
        1.0-speed instance; +-10% run-to-run noise."""
        noise = 1.0 + 0.1 * float(self.rng.standard_normal())
        return max(base_cost_s / self.itype.rel_speed * max(noise, 0.5), 1e-3)

    def transfer_time(self, nbytes: float) -> float:
        return self.latency.sample(self.rng, nbytes, self.itype.net_gbps)


def make_fleet(n_clients: int, *, seed: int = 0,
               preemption: Optional[PreemptionModel] = None,
               latency: Optional[LatencyModel] = None,
               tiers: Optional[list] = None,
               n_az: int = 1) -> list[ClientModel]:
    """Build the client fleet.  ``tiers`` (optional) is a list of
    ``(InstanceType, weight)`` pairs for heterogeneous compute/bandwidth
    mixes — picks use a SEPARATE rng stream so the default path's
    per-client seed consumption (and thus every pinned trace) is
    unchanged.  ``n_az`` spreads clients round-robin over availability
    zones / regions for the correlated preemption models."""
    preemption = preemption or PreemptionModel()
    latency = latency or LatencyModel()
    rng = np.random.default_rng(seed)
    if tiers:
        trng = np.random.default_rng((seed, 0x71E5))
        w = np.asarray([t[1] for t in tiers], np.float64)
        picks = trng.choice(len(tiers), size=n_clients, p=w / w.sum())
    fleet = []
    for cid in range(n_clients):
        itype = (tiers[picks[cid]][0] if tiers
                 else PAPER_FLEET[cid % len(PAPER_FLEET)])
        fleet.append(ClientModel(
            cid=cid, itype=itype, preemption=preemption, latency=latency,
            rng=np.random.default_rng(rng.integers(2 ** 63)),
            az=cid % max(n_az, 1)))
    return fleet
