"""FlatParams — one contiguous parameter bus for the whole assimilation path.

The server update (Eq. 1/2) is purely memory-bound: every assimilation
streams the entire parameter set through HBM once.  Walking the parameter
*tree* leaf-by-leaf (one lerp / one ``pallas_call`` / one top-k per leaf)
leaves that bandwidth on the table and compresses worse than a global
top-k.  ``FlatParams`` collapses the tree into a single 1-D buffer so that
assimilation, compression and checkpointing each become ONE pass over ONE
contiguous array — the layout Hivemind-style systems ship on the wire.

Buffer layout / alignment contract
----------------------------------

* Leaves are packed back-to-back in ``jax.tree.flatten`` order, each leaf
  raveled C-contiguously and cast to the buffer's compute dtype
  (``float32`` by default; assimilation math is f32 regardless of the
  storage dtype, exactly like the per-leaf path).
* ``TreeSpec`` is the offset table: per-leaf ``(offset, size, shape,
  dtype)`` plus the original treedef.  ``offsets[i] + sizes[i] ==
  offsets[i+1]`` — no inter-leaf padding, so the buffer is bit-identical
  to the concatenation of the raveled leaves.
* The buffer tail is zero-padded up to a multiple of ``BLOCK`` (the
  Pallas grid tile, 8192 = 8·1024 elements, a multiple of the 8×128 TPU
  vector tile).  Kernels therefore launch a single blocked grid over the
  whole model with no per-call pad-and-reshape.  Zero padding is a fixed
  point of every flat op (lerp, delta add, weighted reduction), so the
  tail stays zero and never leaks into leaves.
* ``spec.n`` is the logical element count (sum of leaf sizes);
  ``spec.padded`` is the physical buffer length.  Compression computes k
  from ``spec.n`` so padding never inflates the density budget.

Round-trip: ``unflatten(flatten(tree)) == tree`` with dtypes preserved.
bf16 and f32 leaves round-trip exactly (widening casts); integer leaves
round-trip exactly for |x| < 2**24 (f32 mantissa) — parameter/optimizer
trees in this repo satisfy that (step counters, token ids).

``FlatParams`` is registered as a pytree (buffer = child, spec = static
aux data), so it passes through ``jit``/``vmap`` and the checkpoint layer
unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

# Pallas grid tile of the flat kernels (kernels/vc_asgd_update.py imports
# this constant): multiple of the 8x128 vector tile.
BLOCK = 8 * 1024

# tree<->bus conversion counters: flatten/unflatten are the BOUNDARY of the
# flat world, and the hot loops (simulator assimilation, vc rounds) must
# cross it a bounded number of times per round.  tests/test_simulator.py
# asserts the exact per-result budget against these.
_conversions = {"flatten": 0, "unflatten": 0}


def conversion_counts() -> dict:
    return dict(_conversions)


def reset_conversion_counts() -> None:
    _conversions["flatten"] = 0
    _conversions["unflatten"] = 0


def _note_flatten() -> None:
    _conversions["flatten"] += 1


def _note_unflatten() -> None:
    _conversions["unflatten"] += 1


@dataclass(frozen=True)
class TreeSpec:
    """Static description of a flattened tree: the leaf offset table."""

    treedef: Any                          # jax treedef (hashable)
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf shapes
    dtypes: Tuple[str, ...]               # per-leaf storage dtypes (names)
    offsets: Tuple[int, ...]              # element offset of each leaf
    sizes: Tuple[int, ...]                # element count of each leaf
    n: int                                # logical elements (sum of sizes)
    padded: int                           # physical length (BLOCK multiple)

    @property
    def num_leaves(self) -> int:
        return len(self.shapes)

    def meta(self) -> dict:
        """JSON-serializable layout (checkpoint header; no treedef)."""
        return {"shapes": [list(s) for s in self.shapes],
                "dtypes": list(self.dtypes),
                "offsets": list(self.offsets),
                "n": self.n, "padded": self.padded}


@dataclass(frozen=True)
class ShardedTreeSpec(TreeSpec):
    """Mesh-aware layout: the flat bus cut into ``n_shards`` contiguous,
    BLOCK-padded segments — one per device on the mesh axis ``axis``.

    ``shard_len`` is a BLOCK multiple and ``padded == n_shards *
    shard_len``, so placing the 1-D buffer with
    ``NamedSharding(mesh, P(axis))`` gives every device EXACTLY its own
    contiguous segment, and every flat kernel (lerp / Eq. 2 / Adam /
    EASGD) can run per-shard under ``shard_map`` with no gather: the ops
    are elementwise over the bus, so shard-local results are bit-identical
    to the single-host pass.  Leaves may straddle shard boundaries —
    ``shard_table()`` is the per-shard view of which leaf slices each
    device owns (layout bookkeeping only; kernels never consult it)."""

    n_shards: int = 1
    shard_len: int = 0                    # elements per shard (BLOCK multiple)
    axis: str = "pod"                     # mesh axis the bus shards over

    def shard_bounds(self, i: int) -> Tuple[int, int]:
        """[start, stop) element range of shard ``i``'s segment."""
        if not 0 <= i < self.n_shards:
            raise IndexError(f"shard {i} out of range 0..{self.n_shards - 1}")
        return i * self.shard_len, (i + 1) * self.shard_len

    def shard_table(self):
        """Per-shard list of (leaf_idx, leaf_offset, length): the leaf
        slices whose elements live in that shard's segment.  Every leaf
        element appears exactly once across all shards (tests assert)."""
        table = []
        for i in range(self.n_shards):
            lo, hi = self.shard_bounds(i)
            segs = []
            for li, (off, size) in enumerate(zip(self.offsets, self.sizes)):
                a, b = max(off, lo), min(off + size, hi)
                if a < b:
                    segs.append((li, a - off, b - a))
            table.append(segs)
        return table

    def meta(self) -> dict:
        out = super().meta()
        out["shard"] = {"n_shards": self.n_shards,
                       "shard_len": self.shard_len, "axis": self.axis}
        return out


def _shard_len(n: int, n_shards: int, pad_to: int) -> int:
    """Per-shard segment length: smallest BLOCK multiple covering n."""
    return max(pad_to, -(-n // (n_shards * pad_to)) * pad_to)


def shard_spec(spec: TreeSpec, n_shards: int, *, axis: str = "pod",
               pad_to: int = BLOCK) -> ShardedTreeSpec:
    """Re-lay an existing TreeSpec onto ``n_shards`` contiguous segments.
    Only the tail padding changes — offsets/sizes (and therefore the
    logical buffer prefix) are identical to the single-host layout."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    sl = _shard_len(spec.n, n_shards, pad_to)
    return ShardedTreeSpec(
        treedef=spec.treedef, shapes=spec.shapes, dtypes=spec.dtypes,
        offsets=spec.offsets, sizes=spec.sizes, n=spec.n,
        padded=sl * n_shards, n_shards=n_shards, shard_len=sl, axis=axis)


def sharded_tree_spec(tree, n_shards: int, *, axis: str = "pod",
                      pad_to: int = BLOCK) -> ShardedTreeSpec:
    """Sharded layout of ``tree`` (no data movement)."""
    return shard_spec(tree_spec(tree, pad_to=pad_to), n_shards,
                      axis=axis, pad_to=pad_to)


def flatten_sharded(tree, n_shards: int, *, dtype=jnp.float32,
                    axis: str = "pod", pad_to: int = BLOCK) -> "FlatParams":
    """Flatten onto the sharded layout: same leaf packing as ``flatten``,
    tail zero-padded so every shard's segment is a BLOCK multiple."""
    _note_flatten()
    spec = sharded_tree_spec(tree, n_shards, axis=axis, pad_to=pad_to)
    leaves = jax.tree.leaves(tree)
    parts = [jnp.asarray(l).reshape(-1).astype(dtype) for l in leaves]
    pad = spec.padded - spec.n
    if pad:
        parts.append(jnp.zeros((pad,), dtype))
    return FlatParams(jnp.concatenate(parts), spec)


@dataclass(frozen=True)
class FlatParams:
    """One contiguous 1-D parameter buffer plus its TreeSpec."""

    buf: jnp.ndarray                      # [spec.padded], compute dtype
    spec: TreeSpec

    def with_buf(self, buf) -> "FlatParams":
        return FlatParams(buf, self.spec)

    def tree(self):
        return unflatten(self)


jax.tree_util.register_pytree_node(
    FlatParams,
    lambda fp: ((fp.buf,), fp.spec),
    lambda spec, children: FlatParams(children[0], spec))


@dataclass(frozen=True)
class FlatOptState:
    """Adam moments as two extra lanes of the parameter bus.

    ``m``/``v`` are [spec.padded] f32 buffers with the SAME TreeSpec as the
    parameters they track — leaf i's moments live at the same
    ``offsets[i]:offsets[i]+sizes[i]`` slice as leaf i itself, so island
    redistribution and checkpointing move (params, m, v) as three
    contiguous lanes of one record instead of walking three trees.  The
    zero tail is a fixed point of the Adam update (g=0 -> m=v=0 -> step=0),
    so padding never leaks.  ``step`` is the shared scalar step counter.
    """

    m: jnp.ndarray                        # [spec.padded], float32
    v: jnp.ndarray                        # [spec.padded], float32
    step: jnp.ndarray                     # scalar int32
    spec: TreeSpec

    def leaf_m(self):
        """m as a tree (debug/inspection boundary — not the hot path).
        Moments stay f32 regardless of the params' storage dtypes."""
        return _unflatten_f32(self.m, self.spec)

    def leaf_v(self):
        return _unflatten_f32(self.v, self.spec)


jax.tree_util.register_pytree_node(
    FlatOptState,
    lambda s: ((s.m, s.v, s.step), s.spec),
    lambda spec, ch: FlatOptState(ch[0], ch[1], ch[2], spec))


def _unflatten_f32(buf: jnp.ndarray, spec: TreeSpec):
    _note_unflatten()
    leaves = [buf[o:o + s].reshape(shape)
              for o, s, shape in zip(spec.offsets, spec.sizes, spec.shapes)]
    return jax.tree.unflatten(spec.treedef, leaves)


def init_opt_state(spec: TreeSpec) -> FlatOptState:
    """Fresh Adam lanes for a parameter bus with layout ``spec``."""
    return FlatOptState(m=jnp.zeros((spec.padded,), jnp.float32),
                        v=jnp.zeros((spec.padded,), jnp.float32),
                        step=jnp.zeros((), jnp.int32), spec=spec)


def _padded_len(n: int, pad_to: int) -> int:
    return max(pad_to, -(-n // pad_to) * pad_to)


def tree_spec(tree, *, pad_to: int = BLOCK) -> TreeSpec:
    """Layout of `tree` on the flat bus (no data movement)."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot flatten an empty tree")
    shapes = tuple(tuple(int(d) for d in jnp.shape(l)) for l in leaves)
    dtypes = tuple(str(jnp.asarray(l).dtype) for l in leaves)
    sizes = tuple(math.prod(s) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    return TreeSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=tuple(offsets), sizes=sizes, n=off,
                    padded=_padded_len(off, pad_to))


def flatten(tree, *, dtype=jnp.float32, pad_to: int = BLOCK) -> FlatParams:
    """Pack every leaf into one contiguous buffer (tail zero-padded)."""
    _note_flatten()
    spec = tree_spec(tree, pad_to=pad_to)
    leaves = jax.tree.leaves(tree)
    parts = [jnp.asarray(l).reshape(-1).astype(dtype) for l in leaves]
    pad = spec.padded - spec.n
    if pad:
        parts.append(jnp.zeros((pad,), dtype))
    return FlatParams(jnp.concatenate(parts), spec)


def unflatten(fp: FlatParams):
    """Rebuild the tree, casting each leaf back to its recorded dtype."""
    _note_unflatten()
    spec = fp.spec
    leaves = [fp.buf[o:o + s].reshape(shape).astype(jnp.dtype(dt))
              for o, s, shape, dt in zip(spec.offsets, spec.sizes,
                                         spec.shapes, spec.dtypes)]
    return jax.tree.unflatten(spec.treedef, leaves)


def flatten_batched(tree, *, dtype=jnp.float32, pad_to: int = BLOCK
                    ) -> Tuple[jnp.ndarray, TreeSpec]:
    """Flatten a tree whose every leaf carries a leading batch dim (e.g.
    [n_islands, ...]) into a stacked [batch, padded] buffer.  The returned
    spec describes ONE row (leaf shapes without the leading dim)."""
    _note_flatten()
    leaves = jax.tree.leaves(tree)
    b = leaves[0].shape[0]
    row = jax.tree.map(lambda l: l[0], tree)
    spec = tree_spec(row, pad_to=pad_to)
    parts = [jnp.asarray(l).reshape(b, -1).astype(dtype) for l in leaves]
    pad = spec.padded - spec.n
    if pad:
        parts.append(jnp.zeros((b, pad), dtype))
    return jnp.concatenate(parts, axis=1), spec


def unflatten_batched(buf: jnp.ndarray, spec: TreeSpec, *, dtype=None):
    """Inverse of flatten_batched: [batch, padded] -> tree with leading dim.

    ``dtype`` overrides the recorded leaf dtypes (e.g. f32 for error-
    feedback residuals, which must NOT be truncated to the params'
    storage dtype between rounds)."""
    _note_unflatten()
    b = buf.shape[0]
    leaves = [buf[:, o:o + s].reshape((b,) + shape)
              .astype(jnp.dtype(dt) if dtype is None else dtype)
              for o, s, shape, dt in zip(spec.offsets, spec.sizes,
                                         spec.shapes, spec.dtypes)]
    return jax.tree.unflatten(spec.treedef, leaves)


def flatten_like(tree, spec: TreeSpec, *, dtype=jnp.float32) -> jnp.ndarray:
    """Flatten `tree` onto an EXISTING layout, asserting it matches.
    Returns just the buffer (the caller already holds the spec)."""
    _note_flatten()
    leaves = jax.tree.leaves(tree)
    shapes = tuple(tuple(int(d) for d in jnp.shape(l)) for l in leaves)
    if shapes != spec.shapes:
        raise ValueError(
            f"tree layout mismatch: {shapes} vs spec {spec.shapes}")
    parts = [jnp.asarray(l).reshape(-1).astype(dtype) for l in leaves]
    pad = spec.padded - spec.n
    if pad:
        parts.append(jnp.zeros((pad,), dtype))
    return jnp.concatenate(parts)


def zeros_like_flat(spec: TreeSpec, *, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((spec.padded,), dtype)


def stack_flats(flats: Sequence[FlatParams]) -> jnp.ndarray:
    """[n, padded] client matrix for the fused Eq. 2 reduction."""
    if not flats:
        raise ValueError("need at least one FlatParams")
    spec0 = flats[0].spec
    for f in flats[1:]:
        if f.spec.shapes != spec0.shapes or f.spec.padded != spec0.padded:
            raise ValueError("FlatParams layouts differ")
    return jnp.stack([f.buf for f in flats])
