"""BOINC-style scheduler (§II-C, §III-B): timeout reassignment, reliability
tracking, sticky-file shard affinity, per-client concurrency caps (Tn).

Hot-path note: the simulator calls ``expire_timeouts``/``next_deadline`` on
every event pop, so both are O(1) when nothing is due — a lazy min-heap of
``(deadline, seq, uid)`` replaces the old full scans of ``inflight``.  Heap
entries are validated by uid liveness (uids are never reused and a unit's
deadline never changes after assignment).  Expired hits are replayed in
assignment order (``seq``), which is exactly the old dict-insertion-order
iteration, so requeue ordering — and therefore every downstream trace — is
bit-identical.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.work_generator import WorkGenerator, WorkUnit


@dataclass
class Assignment:
    unit: WorkUnit
    cid: int
    t_assigned: float
    deadline: float
    seq: int = 0                 # assignment order (monotone)


class Scheduler:
    """Tracks in-flight workunits; the simulator drives it with events.

    * ``timeout_s``: if a result is not back in time, the unit is requeued
      (the paper's configurable time limit).
    * reliability: EMA of per-client success; unreliable clients are given
      work last (the paper: "assign subtasks to more reliable clients").
    * sticky affinity: prefer giving a client shards whose files it already
      holds (BOINC sticky files -> no re-download).
    """

    def __init__(self, gen: WorkGenerator, *, timeout_s: float = 1800.0,
                 tasks_per_client: int = 2, reliability_decay: float = 0.8):
        self.gen = gen
        self.timeout_s = timeout_s
        self.tasks_per_client = tasks_per_client
        self.rel_decay = reliability_decay
        self.inflight: Dict[int, Assignment] = {}      # uid -> assignment
        self.client_load: Dict[int, int] = {}
        self.client_rel: Dict[int, float] = {}
        self.client_cache: Dict[int, Set[int]] = {}    # cid -> cached shards
        self.reassignments = 0
        self.results_ok = 0
        self._seq = 0                                  # assignment counter
        self._dl_heap: List = []                       # (deadline, seq, uid)
        self._cid_uids: Dict[int, Dict[int, None]] = {}  # cid -> live uids

    # -- assignment ----------------------------------------------------------
    def request_work(self, cid: int, now: float) -> List[WorkUnit]:
        """Client asks for work (BOINC pull model). Returns <= free-slot units,
        sticky-affine first."""
        free = self.tasks_per_client - self.client_load.get(cid, 0)
        out: List[WorkUnit] = []
        if free <= 0 or not self.gen.pending:
            return out
        cache = self.client_cache.setdefault(cid, set())
        for unit in self.gen.pending.select(cache, free):
            unit.deadline = now + self.timeout_s
            self._seq += 1
            self.inflight[unit.uid] = Assignment(unit, cid, now, unit.deadline,
                                                 seq=self._seq)
            heapq.heappush(self._dl_heap, (unit.deadline, self._seq, unit.uid))
            self._cid_uids.setdefault(cid, {})[unit.uid] = None
            self.client_load[cid] = self.client_load.get(cid, 0) + 1
            cache.add(unit.shard)
            out.append(unit)
        return out

    def _drop(self, asg: Assignment) -> None:
        del self.inflight[asg.unit.uid]
        cid_map = self._cid_uids.get(asg.cid)
        if cid_map is not None:
            cid_map.pop(asg.unit.uid, None)

    # -- result & failure paths ----------------------------------------------
    def complete(self, uid: int, now: float) -> Optional[WorkUnit]:
        asg = self.inflight.get(uid)
        if asg is None:
            return None                                 # already timed out
        self._drop(asg)
        self.client_load[asg.cid] -= 1
        r = self.client_rel.get(asg.cid, 1.0)
        self.client_rel[asg.cid] = self.rel_decay * r + (1 - self.rel_decay)
        self.results_ok += 1
        return asg.unit

    def fail_client(self, cid: int, now: float) -> List[WorkUnit]:
        """Preemption/crash: every unit on that client is requeued now."""
        uids = list(self._cid_uids.get(cid, ()))        # assignment order
        lost = [self.inflight[uid] for uid in uids]
        for a in lost:
            self._drop(a)
            self.gen.requeue(a.unit)
            self.reassignments += 1
        self.client_load[cid] = 0
        r = self.client_rel.get(cid, 1.0)
        self.client_rel[cid] = self.rel_decay * r       # decay toward 0
        return [a.unit for a in lost]

    def expire_timeouts(self, now: float) -> List[WorkUnit]:
        """Requeue every in-flight unit past its deadline (§III-B)."""
        heap = self._dl_heap
        if not heap or heap[0][0] > now:
            # O(1) fast path unless the root is stale; pop stale roots so
            # the heap stays honest for next_deadline()
            while heap and heap[0][2] not in self.inflight:
                heapq.heappop(heap)
                if heap and heap[0][0] <= now:
                    break
            if not heap or heap[0][0] > now:
                return []
        hits: List[Assignment] = []
        while heap and heap[0][0] <= now:
            _, _, uid = heapq.heappop(heap)
            asg = self.inflight.get(uid)
            if asg is not None:
                hits.append(asg)
        hits.sort(key=lambda a: a.seq)                  # old insertion order
        for a in hits:
            self._drop(a)
            self.client_load[a.cid] = max(0, self.client_load[a.cid] - 1)
            r = self.client_rel.get(a.cid, 1.0)
            self.client_rel[a.cid] = self.rel_decay * r
            self.gen.requeue(a.unit)
            self.reassignments += 1
        return [a.unit for a in hits]

    def next_deadline(self) -> float:
        heap = self._dl_heap
        while heap and heap[0][2] not in self.inflight:
            heapq.heappop(heap)
        return heap[0][0] if heap else math.inf
