"""BOINC-style scheduler (§II-C, §III-B): timeout reassignment, reliability
tracking, sticky-file shard affinity, per-client concurrency caps (Tn).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.work_generator import WorkGenerator, WorkUnit


@dataclass
class Assignment:
    unit: WorkUnit
    cid: int
    t_assigned: float
    deadline: float


class Scheduler:
    """Tracks in-flight workunits; the simulator drives it with events.

    * ``timeout_s``: if a result is not back in time, the unit is requeued
      (the paper's configurable time limit).
    * reliability: EMA of per-client success; unreliable clients are given
      work last (the paper: "assign subtasks to more reliable clients").
    * sticky affinity: prefer giving a client shards whose files it already
      holds (BOINC sticky files -> no re-download).
    """

    def __init__(self, gen: WorkGenerator, *, timeout_s: float = 1800.0,
                 tasks_per_client: int = 2, reliability_decay: float = 0.8):
        self.gen = gen
        self.timeout_s = timeout_s
        self.tasks_per_client = tasks_per_client
        self.rel_decay = reliability_decay
        self.inflight: Dict[int, Assignment] = {}      # uid -> assignment
        self.client_load: Dict[int, int] = {}
        self.client_rel: Dict[int, float] = {}
        self.client_cache: Dict[int, Set[int]] = {}    # cid -> cached shards
        self.reassignments = 0
        self.results_ok = 0

    # -- assignment ----------------------------------------------------------
    def request_work(self, cid: int, now: float) -> List[WorkUnit]:
        """Client asks for work (BOINC pull model). Returns <= free-slot units,
        sticky-affine first."""
        free = self.tasks_per_client - self.client_load.get(cid, 0)
        out: List[WorkUnit] = []
        if free <= 0 or not self.gen.pending:
            return out
        cache = self.client_cache.setdefault(cid, set())
        # sticky-first ordering, stable within groups
        pending = sorted(self.gen.pending,
                         key=lambda u: (u.shard not in cache, u.uid))
        for unit in pending[:free]:
            self.gen.pending.remove(unit)
            unit.deadline = now + self.timeout_s
            self.inflight[unit.uid] = Assignment(unit, cid, now, unit.deadline)
            self.client_load[cid] = self.client_load.get(cid, 0) + 1
            cache.add(unit.shard)
            out.append(unit)
        return out

    # -- result & failure paths ----------------------------------------------
    def complete(self, uid: int, now: float) -> Optional[WorkUnit]:
        asg = self.inflight.pop(uid, None)
        if asg is None:
            return None                                 # already timed out
        self.client_load[asg.cid] -= 1
        r = self.client_rel.get(asg.cid, 1.0)
        self.client_rel[asg.cid] = self.rel_decay * r + (1 - self.rel_decay)
        self.results_ok += 1
        return asg.unit

    def fail_client(self, cid: int, now: float) -> List[WorkUnit]:
        """Preemption/crash: every unit on that client is requeued now."""
        lost = [a for a in self.inflight.values() if a.cid == cid]
        for a in lost:
            del self.inflight[a.unit.uid]
            self.gen.requeue(a.unit)
            self.reassignments += 1
        self.client_load[cid] = 0
        r = self.client_rel.get(cid, 1.0)
        self.client_rel[cid] = self.rel_decay * r       # decay toward 0
        return [a.unit for a in lost]

    def expire_timeouts(self, now: float) -> List[WorkUnit]:
        """Requeue every in-flight unit past its deadline (§III-B)."""
        expired = [a for a in self.inflight.values() if a.deadline <= now]
        for a in expired:
            del self.inflight[a.unit.uid]
            self.client_load[a.cid] = max(0, self.client_load[a.cid] - 1)
            r = self.client_rel.get(a.cid, 1.0)
            self.client_rel[a.cid] = self.rel_decay * r
            self.gen.requeue(a.unit)
            self.reassignments += 1
        return [a.unit for a in expired]

    def next_deadline(self) -> float:
        if not self.inflight:
            return math.inf
        return min(a.deadline for a in self.inflight.values())
