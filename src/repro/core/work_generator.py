"""Work generator (§III-A): splits one DL training job into data-parallel
training subtasks (BOINC "workunits"), tracks epochs, and decides the split.

A subtask = (data shard, model + server parameter snapshot version, training
recipe).  An epoch completes when every subtask of that epoch has been
assimilated; the generator then emits the next epoch's subtasks (with the
current server parameter version) until the stop criterion is met.

``PendingQueue`` is the fleet-scale hot-path structure: the scheduler's
sticky-first pick used to ``sorted()`` the whole pending list per request
(O(P log P) per dispatch — quadratic over a run), which dominated the
per-event cost at 10k+ clients.  The queue keeps uid-ordered min-heaps
(global + per-shard, lazily invalidated) so one selection is
O(|cache| + log P) while returning EXACTLY the units the old
``sorted(key=(shard not in cache, uid))[:k]`` returned.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class WorkUnit:
    uid: int
    epoch: int
    shard: int                   # index into the dataset split
    param_version: int           # server version the client starts from
    replicas: int = 1            # computational redundancy (§II-C)
    deadline: float = math.inf   # absolute sim-time deadline (scheduler sets)
    local_steps: int = 1         # client-side passes over the shard


class PendingQueue:
    """Uid-ordered pending units with O(|cache| + log P) sticky-first picks.

    Invariant (relied on for bit-identity with the old list version): units
    are appended in strictly increasing uid order (``_emit_epoch`` and
    ``requeue`` both mint fresh, monotone uids), so "list order" and "uid
    order" coincide and a lazy min-heap reproduces the old stable sort.
    Heap entries are invalidated lazily: a uid is live iff it is still in
    ``_units`` (uids are never reused across assignments)."""

    __slots__ = ("_units", "_all", "_by_shard")

    def __init__(self) -> None:
        self._units: Dict[int, WorkUnit] = {}     # uid -> unit (uid order)
        self._all: List[int] = []                 # uid min-heap (lazy)
        self._by_shard: Dict[int, List[int]] = {} # shard -> uid heap (lazy)

    def append(self, unit: WorkUnit) -> None:
        self._units[unit.uid] = unit
        heapq.heappush(self._all, unit.uid)
        heapq.heappush(self._by_shard.setdefault(unit.shard, []), unit.uid)

    def remove(self, unit: WorkUnit) -> None:
        del self._units[unit.uid]                 # heaps clean up lazily

    def __len__(self) -> int:
        return len(self._units)

    def __bool__(self) -> bool:
        return bool(self._units)

    def __iter__(self):
        return iter(self._units.values())

    def _peek(self, heap: List[int]) -> Optional[int]:
        while heap and heap[0] not in self._units:
            heapq.heappop(heap)
        return heap[0] if heap else None

    def peek_shard(self, shard: int) -> Optional[int]:
        """Smallest pending uid carrying ``shard`` (None if none)."""
        heap = self._by_shard.get(shard)
        if heap is None:
            return None
        uid = self._peek(heap)
        if uid is None:
            del self._by_shard[shard]             # keep the index bounded
        return uid

    def select(self, cache: Iterable[int], k: int) -> List[WorkUnit]:
        """Pop up to ``k`` units, sticky-first: units whose shard is in
        ``cache`` (snapshot at call entry — exactly like the old one-shot
        sort key) ordered by uid, then the rest by uid."""
        out: List[WorkUnit] = []
        if k <= 0 or not self._units:
            return out
        cache0 = tuple(cache)                     # stickiness snapshot
        while len(out) < k and self._units:
            best: Optional[int] = None
            for s in cache0:
                uid = self.peek_shard(s)
                if uid is not None and (best is None or uid < best):
                    best = uid
            if best is None:
                # no sticky unit pending -> global min is non-sticky
                best = self._peek(self._all)
                if best is None:
                    break
            out.append(self._units.pop(best))
        return out

    def prune_stale_epochs(self, epoch: int) -> None:
        """Drop every pending unit not belonging to ``epoch`` (leftover
        replicas of a finished epoch)."""
        stale = [uid for uid, u in self._units.items() if u.epoch != epoch]
        for uid in stale:
            del self._units[uid]


@dataclass
class Split:
    n_shards: int
    shard_index: np.ndarray      # [n_samples] -> shard id
    shard_sizes: np.ndarray      # [n_shards]


def split_dataset(n_samples: int, n_shards: int, *, seed: int = 0,
                  shuffle: bool = True) -> Split:
    """Deterministic near-even split; shuffled so shards are iid (the paper
    splits CIFAR10's 50k train rows into 50 shards of 1000)."""
    idx = np.arange(n_samples)
    if shuffle:
        idx = np.random.default_rng(seed).permutation(n_samples)
    shard_of = np.zeros(n_samples, np.int32)
    bounds = np.linspace(0, n_samples, n_shards + 1).astype(int)
    for s in range(n_shards):
        shard_of[idx[bounds[s]:bounds[s + 1]]] = s
    sizes = np.bincount(shard_of, minlength=n_shards)
    return Split(n_shards, shard_of, sizes)


def auto_split(n_samples: int, n_clients: int, tasks_per_client: int,
               min_shard: int = 64) -> int:
    """The paper's "best possible split" heuristic (§III-A): enough subtasks
    to keep every client slot busy ~2 rounds per epoch, but never shards so
    small that the client step is dominated by transfer overhead."""
    want = max(n_clients * tasks_per_client * 2, 1)
    cap = max(n_samples // min_shard, 1)
    return int(min(want, cap))


class WorkGenerator:
    """Epoch bookkeeping over subtasks.  The scheduler pulls from
    ``pending``; the parameter server calls ``complete(uid)`` after
    assimilation.  ``next_epoch`` rolls the epoch when all shards of the
    current epoch are assimilated."""

    def __init__(self, n_shards: int, *, replicas: int = 1,
                 local_steps: int = 1, max_epochs: int = 10 ** 6):
        self.n_shards = n_shards
        self.replicas = replicas
        self.local_steps = local_steps
        self.max_epochs = max_epochs
        self.epoch = 1
        self._uid = 0
        self.pending = PendingQueue()
        self.done_shards: set[int] = set()
        self.completed_units: Dict[int, WorkUnit] = {}
        self._emit_epoch()

    def _emit_epoch(self) -> None:
        for s in range(self.n_shards):
            for _ in range(self.replicas):
                self.pending.append(WorkUnit(
                    uid=self._uid, epoch=self.epoch, shard=s,
                    param_version=-1, replicas=self.replicas,
                    local_steps=self.local_steps))
                self._uid += 1

    def complete(self, unit: WorkUnit) -> bool:
        """Mark a shard's result assimilated. Returns True if this completed
        the epoch (and the next epoch was emitted)."""
        self.completed_units[unit.uid] = unit
        if unit.epoch != self.epoch:
            return False                   # stale replica of an old epoch
        self.done_shards.add(unit.shard)
        if len(self.done_shards) == self.n_shards:
            self.epoch += 1
            self.done_shards = set()
            # drop leftover replicas of the finished epoch
            self.pending.prune_stale_epochs(self.epoch)
            if self.epoch <= self.max_epochs:
                self._emit_epoch()
            return True
        return False

    def requeue(self, unit: WorkUnit) -> None:
        """Timeout reassignment (§III-B): the shard goes back to pending
        unless the epoch already finished without it (replica quorum)."""
        if unit.epoch == self.epoch and unit.shard not in self.done_shards:
            self.pending.append(WorkUnit(
                uid=self._uid, epoch=unit.epoch, shard=unit.shard,
                param_version=-1, replicas=unit.replicas,
                local_steps=unit.local_steps))
            self._uid += 1

    @property
    def exhausted(self) -> bool:
        return self.epoch > self.max_epochs
