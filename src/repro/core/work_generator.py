"""Work generator (§III-A): splits one DL training job into data-parallel
training subtasks (BOINC "workunits"), tracks epochs, and decides the split.

A subtask = (data shard, model + server parameter snapshot version, training
recipe).  An epoch completes when every subtask of that epoch has been
assimilated; the generator then emits the next epoch's subtasks (with the
current server parameter version) until the stop criterion is met.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class WorkUnit:
    uid: int
    epoch: int
    shard: int                   # index into the dataset split
    param_version: int           # server version the client starts from
    replicas: int = 1            # computational redundancy (§II-C)
    deadline: float = math.inf   # absolute sim-time deadline (scheduler sets)
    local_steps: int = 1         # client-side passes over the shard


@dataclass
class Split:
    n_shards: int
    shard_index: np.ndarray      # [n_samples] -> shard id
    shard_sizes: np.ndarray      # [n_shards]


def split_dataset(n_samples: int, n_shards: int, *, seed: int = 0,
                  shuffle: bool = True) -> Split:
    """Deterministic near-even split; shuffled so shards are iid (the paper
    splits CIFAR10's 50k train rows into 50 shards of 1000)."""
    idx = np.arange(n_samples)
    if shuffle:
        idx = np.random.default_rng(seed).permutation(n_samples)
    shard_of = np.zeros(n_samples, np.int32)
    bounds = np.linspace(0, n_samples, n_shards + 1).astype(int)
    for s in range(n_shards):
        shard_of[idx[bounds[s]:bounds[s + 1]]] = s
    sizes = np.bincount(shard_of, minlength=n_shards)
    return Split(n_shards, shard_of, sizes)


def auto_split(n_samples: int, n_clients: int, tasks_per_client: int,
               min_shard: int = 64) -> int:
    """The paper's "best possible split" heuristic (§III-A): enough subtasks
    to keep every client slot busy ~2 rounds per epoch, but never shards so
    small that the client step is dominated by transfer overhead."""
    want = max(n_clients * tasks_per_client * 2, 1)
    cap = max(n_samples // min_shard, 1)
    return int(min(want, cap))


class WorkGenerator:
    """Epoch bookkeeping over subtasks.  The scheduler pulls from
    ``pending``; the parameter server calls ``complete(uid)`` after
    assimilation.  ``next_epoch`` rolls the epoch when all shards of the
    current epoch are assimilated."""

    def __init__(self, n_shards: int, *, replicas: int = 1,
                 local_steps: int = 1, max_epochs: int = 10 ** 6):
        self.n_shards = n_shards
        self.replicas = replicas
        self.local_steps = local_steps
        self.max_epochs = max_epochs
        self.epoch = 1
        self._uid = 0
        self.pending: List[WorkUnit] = []
        self.done_shards: set[int] = set()
        self.completed_units: Dict[int, WorkUnit] = {}
        self._emit_epoch()

    def _emit_epoch(self) -> None:
        for s in range(self.n_shards):
            for _ in range(self.replicas):
                self.pending.append(WorkUnit(
                    uid=self._uid, epoch=self.epoch, shard=s,
                    param_version=-1, replicas=self.replicas,
                    local_steps=self.local_steps))
                self._uid += 1

    def complete(self, unit: WorkUnit) -> bool:
        """Mark a shard's result assimilated. Returns True if this completed
        the epoch (and the next epoch was emitted)."""
        self.completed_units[unit.uid] = unit
        if unit.epoch != self.epoch:
            return False                   # stale replica of an old epoch
        self.done_shards.add(unit.shard)
        if len(self.done_shards) == self.n_shards:
            self.epoch += 1
            self.done_shards = set()
            # drop leftover replicas of the finished epoch
            self.pending = [u for u in self.pending if u.epoch == self.epoch]
            if self.epoch <= self.max_epochs:
                self._emit_epoch()
            return True
        return False

    def requeue(self, unit: WorkUnit) -> None:
        """Timeout reassignment (§III-B): the shard goes back to pending
        unless the epoch already finished without it (replica quorum)."""
        if unit.epoch == self.epoch and unit.shard not in self.done_shards:
            self.pending.append(WorkUnit(
                uid=self._uid, epoch=unit.epoch, shard=unit.shard,
                param_version=-1, replicas=unit.replicas,
                local_steps=unit.local_steps))
            self._uid += 1

    @property
    def exhausted(self) -> bool:
        return self.epoch > self.max_epochs
