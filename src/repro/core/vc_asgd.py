"""VC-ASGD — the paper's parameter-update scheme (§III-C, Eq. 1/2).

    W_s <- alpha * W_s + (1 - alpha) * W_{c_i,j}            (Eq. 1)

applied immediately per arriving client result, in arrival order, with no
barrier.  The per-epoch closed form (Eq. 2) over n_t returning subtasks:

    W_{s,e} = alpha^{n_t} W_{s,e-1} + (1-alpha) sum_j alpha^{n_t-j} W_{c,j}

``assimilate_many`` evaluates Eq. 2 directly as one weighted sum — this is
what the pod-scale runtime uses (one fused collective instead of n_t
sequential lerps), and a hypothesis property test asserts it is exactly
the fold of Eq. 1.

Alpha schedules: constant, and the paper's epoch-varying
``alpha_e = e / (e + 1)`` (§III-C "Var"), plus a generalized power schedule
(beyond paper).  Staleness-aware damping (beyond paper) shrinks the client
weight geometrically with result staleness so stragglers still contribute
but cannot drag the server copy backwards.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Eq. 1 — the core server update
# ---------------------------------------------------------------------------

def vc_asgd_update(server, client, alpha: float | jnp.ndarray,
                   use_kernel: bool = False):
    """One assimilation: every leaf lerped toward the client copy.

    With ``use_kernel=True`` the fused Pallas kernel (kernels/vc_asgd_update)
    performs the lerp in one HBM pass per leaf (TPU target; interpret-mode
    validated on CPU).
    """
    if use_kernel:
        from repro.kernels import ops as K
        return jax.tree.map(lambda s, c: K.fused_lerp(s, c, alpha),
                            server, client)
    a = jnp.asarray(alpha, jnp.float32)
    return jax.tree.map(
        lambda s, c: (a * s.astype(jnp.float32)
                      + (1.0 - a) * c.astype(jnp.float32)).astype(s.dtype),
        server, client)


def vc_asgd_update_delta(server, delta, alpha: float | jnp.ndarray):
    """Delta form: W_s <- W_s + (1-alpha) * delta, where delta = W_c - W_s0.

    Algebraically identical to Eq. 1 when delta is taken against the same
    server copy; at LLM scale the delta is what travels cross-pod (it
    compresses well — core/compression.py)."""
    a = jnp.asarray(alpha, jnp.float32)
    return jax.tree.map(
        lambda s, d: (s.astype(jnp.float32)
                      + (1.0 - a) * d.astype(jnp.float32)).astype(s.dtype),
        server, delta)


# ---------------------------------------------------------------------------
# Eq. 2 — batched assimilation (order-deterministic weighted sum)
# ---------------------------------------------------------------------------

def assimilation_weights(n: int, alpha: float) -> List[float]:
    """Weight of client j (arrival order j = 0..n-1) plus the server weight.

    Returns [w_server, w_0, ..., w_{n-1}] with
    w_server = alpha^n, w_j = (1-alpha) * alpha^{n-1-j}; sums to 1."""
    ws = [alpha ** n] + [(1.0 - alpha) * alpha ** (n - 1 - j) for j in range(n)]
    return ws


def assimilate_many(server, clients: Sequence, alpha: float):
    """Eq. 2 as a single weighted sum over [server, client_0, ..., client_n-1]
    in arrival order.  Exactly equal to folding Eq. 1 n times."""
    n = len(clients)
    if n == 0:
        return server
    w = assimilation_weights(n, alpha)

    def merge(s, *cs):
        acc = w[0] * s.astype(jnp.float32)
        for wi, c in zip(w[1:], cs):
            acc = acc + wi * c.astype(jnp.float32)
        return acc.astype(s.dtype)

    return jax.tree.map(merge, server, *clients)


# ---------------------------------------------------------------------------
# Flat-bus forms (core/flat.py): the whole model as ONE contiguous buffer.
# These are what the runtime/simulator actually execute — the per-leaf
# tree.map forms above remain as the reference semantics.
# ---------------------------------------------------------------------------

def vc_asgd_update_flat(server, client, alpha: float | jnp.ndarray,
                        use_kernel: bool = False):
    """Eq. 1 on the flat bus: one lerp over the whole model.

    ``server`` is a FlatParams; ``client`` is a FlatParams or a raw buffer
    with the same layout.  Returns a FlatParams.  With ``use_kernel=True``
    the single blocked Pallas grid (kernels/vc_asgd_update) performs the
    pass — ONE launch for the whole model, not one per leaf."""
    from repro.core.flat import FlatParams
    c = client.buf if isinstance(client, FlatParams) else client
    if use_kernel:
        from repro.kernels import ops as K
        return server.with_buf(K.fused_lerp_flat(server.buf, c, alpha))
    if isinstance(server.buf, np.ndarray) and isinstance(c, np.ndarray):
        # numpy-backed bus (flat task protocol, fleet-scale sims): the
        # same lerp without per-event JAX dispatch.  Scalar and buffer
        # math both run in f32 with separate mul/add (no FMA), matching
        # the eager jnp form bit-for-bit.
        a_np = np.float32(alpha)
        out = (a_np * server.buf.astype(np.float32)
               + (np.float32(1.0) - a_np) * c.astype(np.float32))
        return server.with_buf(out.astype(server.buf.dtype))
    a = jnp.asarray(alpha, jnp.float32)
    s32 = server.buf.astype(jnp.float32)
    return server.with_buf(
        (a * s32 + (1.0 - a) * c.astype(jnp.float32)).astype(server.buf.dtype))


def vc_asgd_update_delta_flat(server, delta, alpha: float | jnp.ndarray):
    """Delta form on the flat bus: W_s <- W_s + (1-alpha) * delta."""
    from repro.core.flat import FlatParams
    d = delta.buf if isinstance(delta, FlatParams) else delta
    a = jnp.asarray(alpha, jnp.float32)
    s32 = server.buf.astype(jnp.float32)
    return server.with_buf(
        (s32 + (1.0 - a) * d.astype(jnp.float32)).astype(server.buf.dtype))


def assimilate_many_flat(server, clients, alpha: float,
                         weights: Optional[Sequence[float]] = None,
                         use_kernel: bool = False):
    """Eq. 2 on the flat bus: ONE fused weighted reduction over a stacked
    [n_clients, N] buffer instead of n sequential per-leaf lerps.

    ``clients`` is a [n, padded] matrix (stack_flats) or a list of
    FlatParams.  ``weights`` overrides the Eq. 2 weights — this is how the
    staleness-damped variant rides the same pass (per-client effective
    alphas collapse into per-client weights).  Accumulation order matches
    ``assimilate_many`` exactly, so the result is bit-for-bit identical to
    the per-leaf fold in f32."""
    from repro.core.flat import FlatParams, stack_flats
    if isinstance(clients, (list, tuple)):
        if len(clients) == 0:
            return server
        clients = stack_flats(clients) if isinstance(clients[0], FlatParams) \
            else jnp.stack(clients)
    n = clients.shape[0]
    if n == 0:
        return server
    w = list(weights) if weights is not None else assimilation_weights(n, alpha)
    if len(w) != n + 1:
        raise ValueError(f"need {n + 1} weights, got {len(w)}")
    if use_kernel:
        from repro.kernels import ops as K
        return server.with_buf(K.fused_assimilate_flat(server.buf, clients, w))
    acc = w[0] * server.buf.astype(jnp.float32)
    for j in range(n):
        acc = acc + w[j + 1] * clients[j].astype(jnp.float32)
    return server.with_buf(acc.astype(server.buf.dtype))


def staleness_weights(n: int, alpha: float, staleness, gamma: float = 0.7
                      ) -> List[float]:
    """Per-client Eq. 2 weights with staleness damping folded in: client j's
    effective alpha is staleness_alpha(alpha, staleness[j]); the weights are
    the exact fold of Eq. 1 with those alphas, so the damped variant rides
    the same fused flat reduction."""
    alphas = [staleness_alpha(alpha, float(s), gamma) for s in staleness]
    cw: List[float] = []
    for j in range(n):
        w = 1.0 - alphas[j]
        for a in alphas[j + 1:]:
            w *= a
        cw.append(w)
    return [math.prod(alphas)] + cw


# ---------------------------------------------------------------------------
# alpha schedules
# ---------------------------------------------------------------------------

AlphaSchedule = Callable[[int], float]


def const_alpha(alpha: float) -> AlphaSchedule:
    return lambda e: alpha


def var_alpha() -> AlphaSchedule:
    """The paper's §III-C schedule: alpha_e = e/(e+1), rising 0.5 -> ~1."""
    return lambda e: e / (e + 1.0)


def power_alpha(alpha_min: float = 0.5, alpha_max: float = 0.99,
                tau: float = 10.0) -> AlphaSchedule:
    """Beyond paper: exponential approach to alpha_max with time-scale tau."""
    return lambda e: alpha_max - (alpha_max - alpha_min) * math.exp(-e / tau)


def staleness_alpha(alpha: float, staleness: float, gamma: float = 0.7) -> float:
    """Beyond paper: effective alpha for a result computed against a server
    copy that is `staleness` versions old.  The client weight (1 - alpha)
    decays geometrically: 1-a_eff = (1-a) * gamma^staleness."""
    return 1.0 - (1.0 - alpha) * (gamma ** staleness)


# ---------------------------------------------------------------------------
# delay compensation (DC-ASGD, Zheng et al. [18]) — used by baselines and by
# the fused kernel's optional DC term
# ---------------------------------------------------------------------------

def dc_asgd_gradient(grad, w_now, w_backup, lam: float = 0.04):
    """g_dc = g + lam * g (.) g (.) (W_now - W_backup): a diagonal Hessian
    approximation compensating for gradient delay."""
    return jax.tree.map(
        lambda g, wn, wb: g + lam * g * g * (wn.astype(g.dtype)
                                             - wb.astype(g.dtype)),
        grad, w_now, w_backup)


# ---------------------------------------------------------------------------
# convenience: convex-combination invariants (used by property tests and by
# the elastic runtime's sanity guards)
# ---------------------------------------------------------------------------

def is_convex_combination(n: int, alpha: float, atol=1e-9) -> bool:
    w = assimilation_weights(n, alpha)
    return (abs(sum(w) - 1.0) < atol) and all(x >= -atol for x in w)


def tree_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def tree_max_abs(tree) -> jnp.ndarray:
    return max(jnp.max(jnp.abs(x.astype(jnp.float32)))
               for x in jax.tree.leaves(tree))
