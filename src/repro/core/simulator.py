"""Discrete-event simulator of the full VC training system (§III, §IV).

Everything the paper measures comes out of this one engine:

* Pn parameter servers (each processes results serially; §IV-B's
  client/server imbalance), sharing state through a Strong or Eventual
  ParameterStore (§III-D / §IV-D),
* Cn heterogeneous clients with WAN latency and preemption (§III-B, §III-E),
* Tn simultaneous subtasks per client (vertical scaling),
* BOINC-style scheduler with timeout reassignment + sticky shards,
* a WorkGenerator splitting the dataset into subtasks,
* any ServerScheme (VC-ASGD or a baseline).

The protocol plumbing — leases, residual ledger, wire encode/decode,
transport — is owned by the ``Coordinator`` (repro.protocol); this loop
only decides WHEN things happen (the discrete-event clock) and drives the
same coordinator object a real runtime does (launch/vc_serve.py).

ACCURACY IS REAL: clients run actual JAX training on actual data shards;
only wall-clock time is simulated (from the paper's measured transfer
sizes, §IV-D update latencies, and Table I instance speeds).  The virtual
clock makes every figure reproducible in seconds of CPU time.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import flat
from repro.core.consistency import EventualStore, StoreStats, StrongStore
from repro.core.preemption import (ClientModel, LatencyModel, PreemptionModel,
                                   make_fleet)
from repro.core.scheduler import Scheduler
from repro.core.work_generator import WorkGenerator, split_dataset
from repro.protocol import (Aggregator, Coordinator, HandoutService,
                            ServerScheme, as_flat, as_tree)
from repro.transfer import wire
from repro.transfer.transport import LoopbackTransport, Transport, TransportStats


@dataclass
class SimConfig:
    n_param_servers: int = 3          # Pn
    n_clients: int = 3                # Cn
    tasks_per_client: int = 4         # Tn
    n_shards: int = 50                # paper: 50 CIFAR subsets
    max_epochs: int = 40
    target_accuracy: Optional[float] = None
    local_steps: int = 60             # client minibatch steps per subtask
    timeout_s: float = 1800.0
    consistency: str = "eventual"     # "eventual" (Redis) | "strong" (MySQL)
    preemptible: bool = False
    mean_lifetime_s: float = 5400.0
    restart_delay_s: float = 120.0
    # transfer sizes (paper §IV-A): params 21.2MB, data shard 3.9MB, model
    # 269KB.  NEITHER leg is simulated from an assumed size any more: the
    # DOWNLOAD leg encodes the handout to real wire frames at lease issue
    # (per-shard delta frames over a sharded bus, one dense frame
    # otherwise) and times the transfer from the summed frame lengths;
    # the UPLOAD leg encodes the result payload and times it from the
    # frame length.  ``param_bytes``/``upload_bytes`` are the
    # paper-calibration overrides (figure reproductions pin both to the
    # measured 21.2MB .h5); None = real frames.
    param_bytes: Optional[float] = None
    shard_bytes: float = 3.9e6
    model_bytes: float = 269e3
    upload_bytes: Optional[float] = None
    # server-side per-result processing (assimilation compute + validation)
    server_proc_s: float = 2.0
    # reference client compute per subtask on the 1.0-speed instance
    subtask_compute_s: float = 180.0
    seed: int = 0
    # ---- fleet-scale knobs -------------------------------------------------
    # shard count of the SERVER parameter bus (1 = dense handout frames;
    # >1 puts the version-vector delta-handout ledger in the sim path)
    bus_shards: int = 1
    # evaluate validation accuracy every k-th assimilation (1 = every one,
    # bit-identical to the historical behaviour; >1 bounds the per-event
    # jnp cost at fleet scale — epoch stats then summarize the sampled
    # subset)
    eval_stride: int = 1
    # custom fleet builder: cfg -> list[ClientModel].  The scenario
    # registry uses this to inject spot-price / correlated-reclaim /
    # diurnal preemption models and heterogeneous tiers; None = the
    # historical make_fleet path (bit-identical)
    fleet_fn: Optional[Callable] = None
    # ---- hierarchical aggregation tier -------------------------------------
    # 0 = flat (every client leases from the hub; bit-identical to the
    # pre-tier engine).  N > 0 inserts N edge aggregators: client cid
    # leases from aggregator cid % N, each aggregator folds its window's
    # arrivals with the scheme's own per-arrival assimilate and ships ONE
    # merged KIND_AGG frame upstream per flush — the hub transport then
    # carries only upstream traffic (the fan-in reduction the ROADMAP
    # "millions of users" item asks for).  Aggregators are modelled as
    # infrastructure (not preemptible); losing one is covered by
    # Aggregator.fail() property tests, not the preemption process.
    aggregators: int = 0
    # ---- content-addressed handout serving ---------------------------------
    # download-leg frame dtype: "float32" (pinned default) or "bfloat16"
    # (half-width dense frames, f32 masters, bf16-exact reconstruction)
    handout_dtype: str = "float32"
    # read-only subscribers (protocol/handout.py): N model pullers served
    # from the coordinator's content-addressed frame cache.  0 = off
    # (bit-identical to the pre-serving engine — no extra events, and a
    # version bump is content-driven so the serving path never changes
    # which frames training clients are sent)
    subscribers: int = 0
    # arrival process: "flash" (the whole crowd re-pulls within
    # sub_jitter_s of each sub_interval_s cadence tick — release-day),
    # "uniform" / "lognormal" (independent re-pull intervals with mean
    # sub_interval_s; lognormal is the heavy-tailed lagged distribution)
    sub_lag: str = "flash"
    sub_interval_s: float = 600.0
    sub_jitter_s: float = 30.0
    # read-serving frontends: serial processors (like parameter servers)
    # whose per-pull service time is a fixed overhead plus encode time
    # for the bytes THIS pull was first to request (cache misses) — the
    # flash-crowd p99 shows exactly the encode-once vs encode-per-client
    # difference.  Transfer then rides the subscriber downlink.
    sub_frontends: int = 4
    sub_serve_overhead_s: float = 0.001
    sub_encode_gbps: float = 1.0
    sub_bandwidth_gbps: float = 0.3


@dataclass
class EpochPoint:
    epoch: int
    t_complete: float
    acc_mean: float
    acc_min: float
    acc_max: float
    acc_std: float


@dataclass
class SimResult:
    points: List[EpochPoint]
    wall_time_s: float
    epochs_done: int
    final_accuracy: float
    store_stats: StoreStats
    reassignments: int
    preemptions: int
    results_assimilated: int
    cost_hours: float = 0.0
    # REAL bytes on the wire (transfer/): frame counts and byte totals on
    # BOTH legs are measured off the encoded payloads, never assumed.
    # wire.bytes_sent == handout_bytes + sum of upload frame lengths.
    wire: Optional[TransportStats] = None
    wire_dense_frames: int = 0
    wire_sparse_frames: int = 0
    handout_frames: int = 0           # download-leg frames (issue time)
    handout_bytes: int = 0            # summed handout frame lengths
    # coordinator lease lifecycle counters (expire wired to the scheduler
    # timeout sweep; drops from preemption / stale arrivals)
    leases_expired: int = 0
    leases_dropped: int = 0
    # total events popped off the heap (events/sec = this / bench wall)
    events_processed: int = 0
    # final server-side SchemeState (typed; replicas/backups inspectable)
    scheme_state: Any = None
    # ---- aggregation tier (cfg.aggregators > 0) ----------------------------
    # In tier mode ``wire``/``handout_*`` cover the HUB transport only —
    # upstream merged frames down, window-base handouts up — which is the
    # measurable fan-in reduction; ``edge_wire`` sums the per-aggregator
    # edge transports (client handouts + result uploads), and the dense/
    # sparse frame counters above already include the edge legs.
    aggregators: int = 0
    agg_flushes: int = 0              # merged frames shipped upstream
    wire_agg_frames: int = 0          # KIND_AGG frames the hub assimilated
    edge_wire: Optional[TransportStats] = None
    # ---- content-addressed handout serving ---------------------------------
    # Cache stats cover the hub coordinator's WHOLE download leg (client
    # handouts + subscriber pulls): unique bytes encoded vs bytes served
    # is the dedup ratio the flash-crowd scenarios measure.  sub_* fill
    # only when cfg.subscribers > 0.
    handout_unique_bytes_encoded: int = 0
    handout_bytes_served: int = 0
    handout_dedup_ratio: float = 0.0
    subscribers: int = 0
    sub_pulls: int = 0
    sub_frames_served: int = 0
    sub_bytes_served: int = 0
    sub_latency_p50_s: float = 0.0
    sub_latency_p99_s: float = 0.0

    def acc_at_time(self, t: float) -> float:
        """Accuracy of the LATEST epoch completed at or before ``t`` (0.0
        before the first point) — the value an observer reading the
        validation curve at time t would see, not a running best."""
        acc = 0.0
        for p in self.points:
            if p.t_complete <= t:
                acc = p.acc_mean
        return acc


# event kinds (small ints: the heap carries only (t, seq, kind, cid)
# tuples — payloads live out-of-band, keyed by seq).  The monotone seq is
# the EXPLICIT same-timestamp tie-breaker: two events at equal t pop in
# push order, never by kind or payload, so batching/refactoring the
# handlers can never reorder a pinned trace.
_BOOT = 0
_RESPAWN = 1
_DISPATCH = 2               # client pulls new work (post-commit)
_UPLOAD = 3                 # client finished local training; starts upload
_ARRIVE = 4                 # result lands at the web server
_AGG_ARRIVE = 5             # merged edge frame lands at the hub (tier mode)
_WINDOW_OPEN = 6            # aggregator handout downloaded; window usable
_SUB_PULL = 7               # read-only subscriber pulls the model


def _pick_server(ps_busy) -> int:
    """Earliest-free parameter server (§IV-B serial processors): a result
    goes to the PS that frees up first, never queueing behind a busy one
    while another sits idle (blind round-robin mismodelled exactly that).
    Ties break to the lowest index — deterministic."""
    return min(range(len(ps_busy)), key=lambda i: (ps_busy[i], i))


def run_simulation(task, data, scheme: ServerScheme, cfg: SimConfig,
                   *, transport: Optional[Transport] = None) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    split = split_dataset(len(data.x_train), cfg.n_shards, seed=cfg.seed)
    shards = [np.flatnonzero(split.shard_index == s)
              for s in range(cfg.n_shards)]

    gen = WorkGenerator(cfg.n_shards, local_steps=cfg.local_steps,
                        max_epochs=cfg.max_epochs)
    sched = Scheduler(gen, timeout_s=cfg.timeout_s,
                      tasks_per_client=cfg.tasks_per_client)

    if cfg.fleet_fn is not None:
        fleet = cfg.fleet_fn(cfg)
    else:
        pre = PreemptionModel(mean_lifetime_s=cfg.mean_lifetime_s,
                              restart_delay_s=cfg.restart_delay_s,
                              enabled=cfg.preemptible)
        fleet = make_fleet(cfg.n_clients, seed=cfg.seed, preemption=pre)
    for c in fleet:
        c.spawn(0.0)

    # server state rides the flat bus (core/flat.py): the store versions ONE
    # contiguous buffer (the paper's Redis value IS one blob), and every
    # scheme's update is a single fused pass — the same code path as the
    # pod-scale runtime.  Clients stay tree-world; as_tree() is the boundary.
    # ``bus_shards > 1`` lays the bus out sharded, so handouts ship as
    # per-shard delta frames through the version-vector ledger.
    # Flat task protocol: a task may provide flat-bus-native hooks
    # (init_params_flat / client_train_flat / evaluate_flat) and then the
    # whole run stays in buffer-world — no per-event tree<->bus crossing,
    # and a numpy-backed bus (ProbeTask) never touches JAX dispatch.
    # Tasks without the hooks take the exact tree path below, unchanged.
    init_flat = getattr(task, "init_params_flat", None)
    train_flat = getattr(task, "client_train_flat", None)
    eval_flat = getattr(task, "evaluate_flat", None)
    if init_flat is not None:
        params0 = init_flat(key, cfg.bus_shards)
    elif cfg.bus_shards > 1:
        params0 = flat.flatten_sharded(task.init_params(key), cfg.bus_shards)
    else:
        params0 = as_flat(task.init_params(key))
    eventual = cfg.consistency == "eventual"
    store = EventualStore(params0) if eventual else StrongStore(params0)
    # the Coordinator owns the protocol: scheme state, leases, residual
    # ledger, wire encode/decode, transport.  This loop owns only time.
    coord = Coordinator(scheme, params0, transport=transport,
                        timeout_s=cfg.timeout_s,
                        handout_dtype=cfg.handout_dtype)
    # parameter servers: independent serial processors sharing the store;
    # each result lands on the earliest-free one (_pick_server)
    ps_busy = [0.0] * cfg.n_param_servers

    # ---- the aggregation tier (cfg.aggregators > 0) ------------------------
    # Each edge aggregator is a REAL Aggregator over its own loopback
    # transport: client handouts/uploads cross the EDGE transport, the hub
    # transport carries only upstream window handouts and merged KIND_AGG
    # frames.  A window admits one dispatch per assigned client (its
    # fan-in) and flushes when every lease it issued has terminated;
    # clients pulling against a closed/full window are deferred and
    # drained when the next window opens.  Aggregators are server-class
    # infrastructure: their upstream transfers draw from a dedicated
    # per-aggregator rng stream (never the clients' — the flat event
    # trace is untouched) through the shared LatencyModel at 10 Gbps.
    n_agg = cfg.aggregators
    aggs: List[Aggregator] = []
    if n_agg:
        aggs = [Aggregator(scheme, coord, agg_id=a,
                           transport=LoopbackTransport(),
                           timeout_s=cfg.timeout_s,
                           handout_dtype=cfg.handout_dtype)
                for a in range(n_agg)]
        agg_lat = LatencyModel()
        agg_rngs = [np.random.default_rng((cfg.seed, 0xA66, a))
                    for a in range(n_agg)]
        fan = [0] * n_agg               # clients assigned per aggregator
        for c in fleet:
            fan[c.cid % n_agg] += 1
        agg_open = [False] * n_agg      # window accepting dispatches
        agg_disp = [0] * n_agg          # dispatches admitted this window
        agg_rv = [0] * n_agg            # store version of the window base
        agg_busy = [0.0] * n_agg        # serial fold chain (like a PS)
        agg_deferred: List[List[int]] = [[] for _ in range(n_agg)]
        agg_def_set: List[set] = [set() for _ in range(n_agg)]
    upstream_live = 0                   # merged frames in flight to the hub
    pending_rolls: List[int] = []       # epochs rolled, awaiting hub commit

    # validation accuracy per assimilated subtask, grouped by epoch
    epoch_accs: Dict[int, List[float]] = {}
    epoch_done_t: Dict[int, float] = {}
    points: List[EpochPoint] = []

    # the heap carries ONLY (t, seq, kind, cid) tuples; upload/arrive
    # payloads (unit, lease) live out-of-band keyed by seq and are popped
    # when the event fires.  seq is globally monotone, so equal-time
    # events pop in push order — comparison never reaches kind/cid.
    events: List[Tuple[float, int, int, int]] = []
    payloads: Dict[int, tuple] = {}
    eid = itertools.count()
    preemptions = 0
    assimilated = 0
    events_processed = 0

    def push(t, kind, cid, payload=None):
        seq = next(eid)
        if payload is not None:
            payloads[seq] = payload
        heapq.heappush(events, (t, seq, kind, cid))

    # preemption heap: (alive_until, spawn_generation, cid).  An entry is
    # live iff its generation matches the client's current spawn; each
    # sweep collects every due client and handles them in ascending-cid
    # order — exactly the old per-event `for c in fleet` scan, minus the
    # O(n_clients) walk per event.
    preempt_heap: List[Tuple[float, int, int]] = []
    spawn_gen = [0] * cfg.n_clients
    preemptible = cfg.preemptible

    def track_spawn(c):
        spawn_gen[c.cid] += 1
        if preemptible and c.alive_until < math.inf:
            heapq.heappush(preempt_heap,
                           (c.alive_until, spawn_gen[c.cid], c.cid))

    for c in fleet:
        track_spawn(c)

    def maybe_flush(a: int, now: float):
        """Flush aggregator ``a``'s window iff it is open, admitted at
        least one dispatch, and every lease it issued has terminated
        (folded, expired, or dropped) — called after every event that can
        retire an edge lease.  The merged frame's upstream transfer is
        timed off its REAL encoded length; a window whose every result
        was lost flushes to nothing (the upstream lease is dropped, never
        submitted) and reopens immediately."""
        nonlocal upstream_live
        agg = aggs[a]
        if not agg_open[a] or agg_disp[a] == 0 or agg.in_flight != 0:
            return
        agg_open[a] = False
        t_flush = max(now, agg_busy[a])
        up = agg.flush(now=t_flush)
        if up is None:
            reopen_window(a, t_flush)
            return
        ul = agg_lat.sample(agg_rngs[a], up.frame_bytes, 10.0)
        upstream_live += 1
        push(t_flush + ul, _AGG_ARRIVE, a, (up,))

    def reopen_window(a: int, t: float):
        """Take the next upstream lease for aggregator ``a`` — the window
        base is the store snapshot at ``t``, encoded over the HUB
        transport — and schedule _WINDOW_OPEN once the handout download
        lands at the edge.  Upstream leases never time out (math.inf
        deadline): an aggregator is infrastructure, its loss is modelled
        by Aggregator.fail(), not the BOINC timeout sweep."""
        agg = aggs[a]
        base_fp, _ = store.read_at(t)
        up = agg.open_window(round=gen.epoch, now=t, base=base_fp,
                             read_version=store.version,
                             deadline=math.inf)
        agg_rv[a] = store.version
        dl = agg_lat.sample(agg_rngs[a], up.handout_bytes, 10.0)
        push(t + dl, _WINDOW_OPEN, a)

    def dispatch(cid: int, now: float):
        """Client pulls work; each unit's lease is issued HERE — the
        handout crosses the transport as real wire frames at dispatch, so
        the download leg is timed from the summed frame lengths
        (``cfg.param_bytes`` overrides it for paper-calibrated figure
        reproductions) and the client trains from the DECODED bytes."""
        client = fleet[cid]
        if n_agg:
            # tier mode: the client leases from ITS aggregator, against
            # the aggregator's live fold state (round 0 of a window this
            # is the decoded hub base, bit-identical to what a flat hub
            # would hand out).  A closed or full window defers the pull.
            a = cid % n_agg
            agg = aggs[a]
            if not agg_open[a] or agg_disp[a] >= fan[a]:
                if cid not in agg_def_set[a]:
                    agg_def_set[a].add(cid)
                    agg_deferred[a].append(cid)
                return
            units = sched.request_work(cid, now)
            if units:
                agg_disp[a] += 1
            for unit in units:
                unit.param_version = agg_rv[a]
                lease = agg.issue(cid=cid, uid=unit.uid, round=unit.epoch,
                                  shard=unit.shard,
                                  read_version=agg.state.version,
                                  base=agg.state.params, now=now,
                                  deadline=unit.deadline)
                dl_bytes = (cfg.param_bytes if cfg.param_bytes is not None
                            else lease.handout_bytes) + cfg.model_bytes
                dl = client.transfer_time(dl_bytes)
                comp = client.compute_time(cfg.subtask_compute_s)
                push(now + dl + comp, _UPLOAD, cid, (unit, lease))
            if not units and agg.window_merged:
                # an empty pull must not wedge FOLDED results in a window
                # nothing else will close (every remaining unit may be in
                # flight at other aggregators); an empty idle window just
                # stays open — no flush/reopen churn from polling
                maybe_flush(a, now)
            return
        units = sched.request_work(cid, now)
        for unit in units:
            unit.param_version = store.version
            # ---- the lease: every handout is explicit, and REAL bytes --
            # The client downloads the store snapshot as of now (replica
            # schemes substitute client-local state via scheme.handout);
            # issue() encodes it to handout frames through the transport
            # and rebuilds the reconstruction base from the decoded bytes
            # (bit-identical).  DC-ASGD's backup hooks off on_issue.
            # (cid, uid) is fresh by construction: every timeout/failure
            # reassignment mints a NEW uid (WorkGenerator.requeue), so a
            # duplicate-issue LeaseError here would mean the scheduler
            # leaked an assignment.
            base_fp, _ = store.read_at(now)
            lease = coord.issue(cid=cid, uid=unit.uid, round=unit.epoch,
                                shard=unit.shard, read_version=store.version,
                                base=base_fp, now=now,
                                deadline=unit.deadline)
            # download params (+ shard if not cached — request_work marked
            # it): the param leg is the measured handout frame total
            dl_bytes = (cfg.param_bytes if cfg.param_bytes is not None
                        else lease.handout_bytes) + cfg.model_bytes
            dl = client.transfer_time(dl_bytes)
            comp = client.compute_time(cfg.subtask_compute_s)
            push(now + dl + comp, _UPLOAD, cid, (unit, lease))

    # boot: every client asks for work at t=0 (staggered a little)
    for c in fleet:
        push(0.001 * c.cid, _BOOT, c.cid)

    # ---- read-only subscribers (cfg.subscribers > 0) -----------------------
    # Served from the hub coordinator's content-addressed frame cache via
    # HandoutService: the version-vector ledger picks the chunks, the
    # cache guarantees one encode per (round, chunk, write-version) no
    # matter how many subscribers pull.  A dedicated rng stream keeps the
    # trainer trace bit-identical with subscribers on.
    n_sub = cfg.subscribers
    service: Optional[HandoutService] = None
    sub_lat: List[float] = []
    if n_sub:
        service = HandoutService(coord)
        sub_rng = np.random.default_rng((cfg.seed, 0x5EB5))
        sub_busy = [0.0] * max(cfg.sub_frontends, 1)
        sub_encode_bps = cfg.sub_encode_gbps * 1e9 / 8.0
        sub_bw_bps = cfg.sub_bandwidth_gbps * 1e9 / 8.0

        def next_pull(now: float) -> float:
            if cfg.sub_lag == "flash":
                # the whole crowd lands in the jitter window after the
                # next cadence tick
                k = math.floor(now / cfg.sub_interval_s) + 1
                return (k * cfg.sub_interval_s
                        + cfg.sub_jitter_s * float(sub_rng.random()))
            if cfg.sub_lag == "lognormal":
                # heavy-tailed lag, mean sub_interval_s (mu = ln m - s^2/2)
                return now + float(sub_rng.lognormal(
                    math.log(cfg.sub_interval_s) - 0.5, 1.0))
            return now + cfg.sub_interval_s * (0.5 + float(sub_rng.random()))

        for s in range(n_sub):
            t0 = (cfg.sub_jitter_s * float(sub_rng.random())
                  if cfg.sub_lag == "flash"
                  else cfg.sub_interval_s * float(sub_rng.random()))
            push(t0, _SUB_PULL, s)

    if n_agg:
        # first windows open instantly at t=0 (the edge starts warm — W0
        # is already resident, like the store replicas), so boot pulls
        # are admitted at the exact instants the flat engine dispatches
        # them.  Aggregators with no assigned clients never open.
        for a in range(n_agg):
            if fan[a] == 0:
                continue
            base_fp, _ = store.read_at(0.0)
            aggs[a].open_window(round=0, now=0.0, base=base_fp,
                                read_version=store.version,
                                deadline=math.inf)
            agg_rv[a] = store.version
            agg_open[a] = True

    t_now = 0.0
    hard_stop = 10 ** 9
    target_hit = False

    # flat mode drains exactly like the historical loop (upstream_live is
    # always 0); tier mode keeps popping until in-flight merged frames
    # land — the work the edges folded must reach the hub — while every
    # other post-exhaustion event is discarded unprocessed.
    while events and not target_hit:
        if gen.exhausted and upstream_live == 0:
            break
        t_now, seq, kind, cid = heapq.heappop(events)
        if t_now > hard_stop:
            break
        events_processed += 1
        if gen.exhausted and kind != _AGG_ARRIVE:
            payloads.pop(seq, None)
            continue

        # preemption check: every client whose lifetime expired before
        # t_now, in ascending-cid order (= the old full-fleet scan order).
        # O(1) heap peek per event when nobody died.
        if preemptible and preempt_heap and preempt_heap[0][0] <= t_now:
            dead: List[int] = []
            while preempt_heap and preempt_heap[0][0] <= t_now:
                _, g, dcid = heapq.heappop(preempt_heap)
                if g == spawn_gen[dcid]:
                    dead.append(dcid)
            dead.sort()
            for dcid in dead:
                c = fleet[dcid]
                lost = sched.fail_client(dcid, t_now)
                if lost:
                    preemptions += 1
                # releases the client's leases (bases freed, in-flight
                # frames dropped), its residual, and scheme-local state —
                # held by the client's AGGREGATOR in tier mode, whose
                # window may become flushable right here
                if n_agg:
                    aggs[dcid % n_agg].drop_client(dcid)
                    maybe_flush(dcid % n_agg, t_now)
                else:
                    coord.drop_client(dcid)
                c.spawn(t_now + c.preemption.restart_delay_s)
                track_spawn(c)
                push(t_now + c.preemption.restart_delay_s, _RESPAWN, dcid)

        # timeout sweep: the scheduler requeues overdue units AND the
        # coordinator expires their leases in the same breath — both key
        # off the identical deadlines, so a timed-out unit's lease never
        # lingers holding its reconstruction base until the stale arrival
        # happens to fire (the stale upload/arrival handlers below then
        # find the unit gone and the lease already consumed)
        sched.expire_timeouts(t_now)
        coord.expire(t_now)
        if n_agg:
            # edge leases carry the same BOINC deadlines; an expiry can
            # leave a window with nothing in flight — flush it.  O(1)
            # heap-root peek per aggregator when nothing is due.
            for a in range(n_agg):
                if aggs[a].expire(t_now):
                    maybe_flush(a, t_now)

        if kind == _SUB_PULL:
            # read-only subscriber: pull whatever chunks moved since its
            # last pull, all served from the content-addressed cache.
            # Latency = wait for a free frontend + service (overhead +
            # encode time for the bytes THIS pull was first to want) +
            # transfer on the subscriber downlink.  A flash crowd behind
            # one content change pays ONE encode; everyone else queues
            # behind millisecond-class cache serves.
            snap, _ = store.read_at(t_now)
            st_p = service.pull(cid, snap, round=gen.epoch)
            fe = _pick_server(sub_busy)
            t_done = (max(t_now, sub_busy[fe]) + cfg.sub_serve_overhead_s
                      + st_p.encoded_bytes / sub_encode_bps)
            sub_busy[fe] = t_done
            sub_lat.append(t_done + st_p.bytes / sub_bw_bps - t_now)
            push(next_pull(t_now), _SUB_PULL, cid)
            continue

        if kind <= _DISPATCH:           # boot / respawn / dispatch
            # dispatch runs AT the event time, never ahead of it: the
            # lease issue reads the store (and encodes the handout) at
            # ``now``, so it can only see commits that causally precede
            # the client's download — a post-commit pull is deferred to a
            # _DISPATCH event at t_commit rather than evaluated eagerly
            # inside the arrival handler (which would miss commits
            # landing in (t_arrival, t_commit])
            dispatch(cid, t_now)
            continue

        if kind == _WINDOW_OPEN:
            # the aggregator's fresh window base finished downloading:
            # admit pulls again and drain every client deferred while the
            # previous window was closed or full (same order they asked)
            a = cid
            agg_open[a] = True
            agg_disp[a] = 0
            drain = agg_deferred[a]
            agg_deferred[a] = []
            agg_def_set[a].clear()
            for dcid in drain:
                dispatch(dcid, t_now)
            continue

        if kind == _AGG_ARRIVE:
            # ONE merged frame lands at the hub: deliver/assimilate via
            # the identical PS + store path a flat result takes — the
            # scheme folds it with assimilate_aggregate
            # (W' = M + (1 - w)(W - B)), exact adoption of the merge when
            # the hub hasn't moved since the window opened
            a = cid
            (up,) = payloads.pop(seq)
            upstream_live -= 1
            payload_w = coord.deliver(up)
            ps = _pick_server(ps_busy)
            t_free = max(t_now, ps_busy[ps])
            server_version = store.version
            if eventual:
                snap, _ = store.read_at(t_free)
                state = coord.assimilate(up, payload_w,
                                         server_version=server_version,
                                         t_arrival=t_now,
                                         params_override=snap)
                t_commit = store.commit(t_free, t_free + cfg.server_proc_s,
                                        state.params)
            else:
                def txn(head):
                    st = coord.assimilate(up, payload_w,
                                          server_version=server_version,
                                          t_arrival=t_now,
                                          params_override=head)
                    return st.params
                t_commit = store.transact(t_free + cfg.server_proc_s, txn)
            ps_busy[ps] = t_commit

            # validation reads the HUB store, so it only moves at flush
            # commits; epoch points emit at the first hub commit after
            # the generator rolled (the rolling fold itself reaches the
            # hub no later than this frame)
            if coord.assimilated % cfg.eval_stride == 0:
                acc = (eval_flat(store.head(), data.x_val, data.y_val)
                       if eval_flat is not None
                       else task.evaluate(as_tree(store.head()),
                                          data.x_val, data.y_val))
                epoch_accs.setdefault(up.round, []).append(acc)
            while pending_rolls:
                e = pending_rolls.pop(0)
                accs = np.array(epoch_accs.get(e) or [0.0])
                points.append(EpochPoint(
                    epoch=e, t_complete=t_commit,
                    acc_mean=float(accs.mean()), acc_min=float(accs.min()),
                    acc_max=float(accs.max()), acc_std=float(accs.std())))
                epoch_accs.pop(e, None)
                scheme.on_epoch(coord.state, gen.epoch)
                if (cfg.target_accuracy is not None
                        and accs.mean() >= cfg.target_accuracy):
                    target_hit = True
            if not gen.exhausted:
                reopen_window(a, t_commit)
            continue

        if kind == _UPLOAD:
            unit, lease = payloads.pop(seq)
            client = fleet[cid]
            if cfg.preemptible and client.alive_until <= t_now:
                continue                    # died mid-compute; the preemption
                                            # sweep dropped the lease; timeout
                                            # recovers the unit
            if unit.uid not in sched.inflight:
                # timed out and reassigned while computing (the expiry
                # sweep above already consumed the lease); result discarded
                dispatch(cid, t_now)
                continue

            # ---- client-side REAL training --------------------------------
            # Conversions happen at the boundary ONLY: one unflatten per
            # dispatch (the client trains a real tree), one flatten per
            # result (the trained tree onto the bus); the scheme then stays
            # in buffer-world.
            idx = shards[unit.shard]
            steps = unit.local_steps * max(1, len(idx) // task.batch)
            seed = cfg.seed * 1000003 + unit.uid
            if train_flat is not None:
                trained_buf = train_flat(
                    lease.base, data.x_train[idx], data.y_train[idx],
                    steps=steps, seed=seed)
            else:
                base = as_tree(lease.base)
                trained = task.client_train(
                    base, data.x_train[idx], data.y_train[idx],
                    steps=steps, seed=seed)
                trained_buf = flat.flatten_like(trained, lease.base.spec)

            # ---- the wire: REAL bytes, REAL upload time -------------------
            # submit() encodes the payload (applying error feedback) to a
            # wire-format frame and pushes it through the transport (the
            # client's EDGE transport in tier mode); the upload leg's
            # duration comes from the frame's actual length
            # (cfg.upload_bytes overrides it for paper-calibrated figure
            # reproductions).
            srv = aggs[cid % n_agg] if n_agg else coord
            srv.submit(lease, trained_buf)
            ul = client.transfer_time(cfg.upload_bytes
                                      if cfg.upload_bytes is not None
                                      else lease.frame_bytes)
            push(t_now + ul, _ARRIVE, cid, (unit, lease))
            continue

        if kind == _ARRIVE:
            unit, lease = payloads.pop(seq)
            client = fleet[cid]
            if n_agg:
                # result lands at the client's EDGE aggregator: folded
                # into the window with the scheme's own per-arrival
                # assimilate on a serial per-aggregator chain (the edge
                # is one processor, like a PS).  Terminating the lease —
                # fold, stale drop, or death — can complete the window.
                a = cid % n_agg
                agg = aggs[a]
                if cfg.preemptible and client.alive_until <= t_now:
                    agg.drop(lease)
                    maybe_flush(a, t_now)
                    continue
                if unit.uid not in sched.inflight:
                    agg.drop(lease)
                    maybe_flush(a, t_now)
                    dispatch(cid, t_now)
                    continue
                sched.complete(unit.uid, t_now)
                payload_w = agg.deliver(lease)
                t_free = max(t_now, agg_busy[a])
                agg.assimilate(lease, payload_w,
                               server_version=agg.state.version,
                               t_arrival=t_now)
                t_commit = t_free + cfg.server_proc_s
                agg_busy[a] = t_commit
                assimilated += 1
                if gen.complete(unit):
                    # the hub hasn't seen this yet: the EpochPoint emits
                    # at the next merged-frame commit (_AGG_ARRIVE)
                    pending_rolls.append(unit.epoch)
                push(t_commit, _DISPATCH, cid)
                maybe_flush(a, t_commit)
                continue
            if cfg.preemptible and client.alive_until <= t_now:
                # died mid-upload; bytes wasted, lease released (the
                # preemption sweep may already have dropped it — idempotent)
                coord.drop(lease)
                continue
            if unit.uid not in sched.inflight:
                # timed out and reassigned while uploading (or the lease
                # was already released by the preemption sweep — fail_client
                # and drop_client retire a cid's uids and leases together,
                # and reassignments run under NEW uids, so a stale arrival
                # always lands here); result discarded, drop is idempotent
                coord.drop(lease)
                dispatch(cid, t_now)
                continue
            sched.complete(unit.uid, t_now)
            # take delivery: decode validates magic/version/length/crc —
            # a torn frame raises and is never assimilated
            payload_w = coord.deliver(lease)

            # ---- server-side assimilation ---------------------------------
            ps = _pick_server(ps_busy)
            t_free = max(t_now, ps_busy[ps])
            server_version = store.version
            if eventual:
                # PS reads its snapshot when it starts processing; its write
                # clobbers any commit racing within the processing window
                snap, _ = store.read_at(t_free)
                state = coord.assimilate(lease, payload_w,
                                         server_version=server_version,
                                         t_arrival=t_now,
                                         params_override=snap)
                t_commit = store.commit(t_free, t_free + cfg.server_proc_s,
                                        state.params)
            else:
                # serializable read-modify-write against the head
                def txn(head):
                    st = coord.assimilate(lease, payload_w,
                                          server_version=server_version,
                                          t_arrival=t_now,
                                          params_override=head)
                    return st.params
                t_commit = store.transact(t_free + cfg.server_proc_s, txn)
            ps_busy[ps] = t_commit
            assimilated += 1

            # validation accuracy: every assimilation at stride 1 (the
            # historical, pinned behaviour); every k-th at fleet scale —
            # epoch stats then summarize the sampled subset
            if assimilated % cfg.eval_stride == 0:
                acc = (eval_flat(store.head(), data.x_val, data.y_val)
                       if eval_flat is not None
                       else task.evaluate(as_tree(store.head()),
                                          data.x_val, data.y_val))
                epoch_accs.setdefault(unit.epoch, []).append(acc)

            rolled = gen.complete(unit)
            if rolled:
                accs = np.array(epoch_accs.get(unit.epoch) or [0.0])
                points.append(EpochPoint(
                    epoch=unit.epoch, t_complete=t_commit,
                    acc_mean=float(accs.mean()), acc_min=float(accs.min()),
                    acc_max=float(accs.max()), acc_std=float(accs.std())))
                # the epoch summarized into its EpochPoint: release the
                # per-result list (stale late arrivals of this epoch are
                # never read again)
                epoch_accs.pop(unit.epoch, None)
                scheme.on_epoch(coord.state, gen.epoch)
                if (cfg.target_accuracy is not None
                        and accs.mean() >= cfg.target_accuracy):
                    target_hit = True
            push(t_commit, _DISPATCH, cid)

    edge_stats: Optional[TransportStats] = None
    if n_agg:
        # windows still open at exit (exhaustion / target hit / hard
        # stop) are abandoned exactly as a lost aggregator would be:
        # downstream leases, residuals and the upstream lease all release
        # through the protocol — nothing leaks into the counters below
        for agg in aggs:
            if agg.in_flight or agg.window_open:
                agg.fail()
        edge_stats = TransportStats()
        for agg in aggs:
            s = agg.wire_stats
            edge_stats.frames_sent += s.frames_sent
            edge_stats.bytes_sent += s.bytes_sent
            edge_stats.frames_recv += s.frames_recv
            edge_stats.bytes_recv += s.bytes_recv
            edge_stats.frames_dropped += s.frames_dropped
            edge_stats.bytes_dropped += s.bytes_dropped

    final_acc = (eval_flat(store.head(), data.x_val, data.y_val)
                 if eval_flat is not None
                 else task.evaluate(as_tree(store.head()),
                                    data.x_val, data.y_val))
    return SimResult(
        points=points, wall_time_s=t_now,
        epochs_done=len(points), final_accuracy=final_acc,
        store_stats=store.stats, reassignments=sched.reassignments,
        preemptions=preemptions, results_assimilated=assimilated,
        cost_hours=t_now / 3600.0, wire=coord.wire_stats,
        wire_dense_frames=(coord.frames[wire.KIND_DENSE]
                           + sum(a.frames[wire.KIND_DENSE] for a in aggs)),
        wire_sparse_frames=(coord.frames[wire.KIND_SPARSE]
                            + sum(a.frames[wire.KIND_SPARSE] for a in aggs)),
        handout_frames=coord.handout_frames,
        handout_bytes=coord.handout_bytes,
        leases_expired=coord.expired + sum(a.expired for a in aggs),
        leases_dropped=coord.dropped + sum(a.dropped for a in aggs),
        events_processed=events_processed,
        scheme_state=coord.state,
        aggregators=n_agg,
        agg_flushes=sum(a.flushes for a in aggs),
        wire_agg_frames=coord.frames[wire.KIND_AGG],
        edge_wire=edge_stats,
        handout_unique_bytes_encoded=int(coord.handout_cache.encoded_bytes),
        handout_bytes_served=int(coord.handout_cache.served_bytes),
        handout_dedup_ratio=float(coord.handout_cache.dedup_ratio),
        subscribers=n_sub,
        sub_pulls=service.pulls if service else 0,
        sub_frames_served=service.frames_served if service else 0,
        sub_bytes_served=service.bytes_served if service else 0,
        sub_latency_p50_s=(float(np.percentile(sub_lat, 50))
                           if sub_lat else 0.0),
        sub_latency_p99_s=(float(np.percentile(sub_lat, 99))
                           if sub_lat else 0.0))


@dataclass
class PreemptibleTrainResult:
    """Trajectory of run_preemptible_training: ``losses[step]`` is the loss
    of global step `step` (recomputed steps overwrite with — by
    construction — identical values), so two runs compare at matching
    steps regardless of how often either was killed."""
    losses: Dict[int, float]
    restores: int
    recomputed_steps: int
    steps_done: int
    final_params: Any                      # FlatParams

    def trajectory(self) -> List[Tuple[int, float]]:
        return sorted(self.losses.items())


def run_preemptible_training(task, data, *, steps: int = 40, batch: int = 64,
                             ckpt_every: int = 10, ckpt_dir,
                             kill_schedule=None, seed: int = 0,
                             use_kernel: bool = False, on_step=None
                             ) -> PreemptibleTrainResult:
    """Kill-and-restore harness on the flat bus — the correctness argument
    for the one-pass train checkpoints (checkpoint/store.py).

    A coordinator trains with params + Adam state as lanes of ONE
    contiguous buffer (runtime/train.py::make_flat_train_step), writing a
    single-record checkpoint every ``ckpt_every`` steps.  At every step
    listed in ``kill_schedule`` (core/preemption.py::KillSchedule) the
    coordinator 'dies': all in-memory state is discarded and training
    resumes from the last checkpoint — params AND m/v/step restored
    atomically from one record.  Batches are keyed by the GLOBAL step
    index, so a restored run recomputes the lost steps bit-identically
    and the loss trajectory at matching steps equals the uninterrupted
    run's (tests/test_simulator.py asserts this)."""
    from repro.checkpoint import CheckpointManager
    from repro.optim import Adam
    from repro.runtime.train import make_flat_train_step

    fp0 = as_flat(task.init_params(jax.random.PRNGKey(seed)))
    opt = Adam(lr=task.lr)
    fos0 = opt.init_flat(fp0)
    step_fn = make_flat_train_step(
        lambda p, b: task._loss(p, b[0], b[1]), opt, use_kernel=use_kernel)

    def batch_for(step: int):
        k = jax.random.fold_in(jax.random.PRNGKey(seed + 987654), step)
        idx = np.asarray(jax.random.randint(k, (batch,), 0,
                                            len(data.x_train)))
        return (jax.numpy.asarray(data.x_train[idx]),
                jax.numpy.asarray(data.y_train[idx]))

    # sync saves: the 'process' may die right after a step, and the resume
    # guarantee is only as strong as the last COMMITTED record
    mgr = CheckpointManager(ckpt_dir, async_save=False)
    (fp, fos), _, step = mgr.restore_train_or_init(fp0, lambda: (fp0, fos0))

    kills = list(kill_schedule.kill_steps) if kill_schedule is not None else []
    losses: Dict[int, float] = {}
    restores = recomputed = 0
    max_reached = step
    while step < steps:
        if kills and step == kills[0]:
            kills.pop(0)
            # preemption: in-memory state is gone; the last one-pass record
            # is the ONLY survivor
            (fp, fos), _, step = mgr.restore_train_or_init(
                fp0, lambda: (fp0, fos0))
            restores += 1
            continue
        fp, fos, loss = step_fn(fp, fos, batch_for(step))
        if step < max_reached:
            recomputed += 1
        losses[step] = float(loss)
        step += 1
        max_reached = max(max_reached, step)
        if step % ckpt_every == 0:
            mgr.save_train(step, fp, fos, {"step": step})
        if on_step is not None:
            # host-side hook (pacing/telemetry in the SIGKILL harness —
            # tests/test_checkpoint.py kills the process mid-run here)
            on_step(step)
    return PreemptibleTrainResult(losses=losses, restores=restores,
                                  recomputed_steps=recomputed,
                                  steps_done=max_reached, final_params=fp)


def run_single_instance(task, data, *, max_epochs: int = 40,
                        steps_per_epoch: int = 100, seed: int = 0,
                        epoch_time_s: float = 1200.0) -> SimResult:
    """The paper's Fig. 6 baseline: serial synchronous training on one
    standard instance (same machine class as the server)."""
    key = jax.random.PRNGKey(seed)
    params = task.init_params(key)
    points = []
    for e in range(1, max_epochs + 1):
        params = task.client_train(params, data.x_train, data.y_train,
                                   steps=steps_per_epoch, seed=seed + e)
        acc = task.evaluate(params, data.x_val, data.y_val)
        points.append(EpochPoint(epoch=e, t_complete=e * epoch_time_s,
                                 acc_mean=acc, acc_min=acc, acc_max=acc,
                                 acc_std=0.0))
    return SimResult(points=points, wall_time_s=max_epochs * epoch_time_s,
                     epochs_done=max_epochs,
                     final_accuracy=points[-1].acc_mean,
                     store_stats=StoreStats(), reassignments=0, preemptions=0,
                     results_assimilated=max_epochs)
