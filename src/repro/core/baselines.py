"""Server parameter-update schemes: VC-ASGD plus every baseline the paper
discusses (§II-B, §III-C), behind one interface the simulator drives.

* VC-ASGD    — Eq. 1 lerp per arriving result; alpha schedule per epoch.
* Downpour   — clients push accumulated deltas (n_push == one subtask), the
               server applies them directly (Dean et al. [4]).
* EASGD      — elastic averaging with moving rate beta; the paper shows its
               VC-equivalent is VC-ASGD with alpha = 1 - beta = 0.999
               (§IV-C); a persistent-client variant exposes its
               fault-INtolerance under preemption.
* DC-ASGD    — Downpour + diagonal-Hessian delay compensation (Zheng [18]).
* SyncBSP    — barriered weight averaging per round (the cluster paradigm);
               included to show why synchrony fails on preemptible fleets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import vc_asgd as V


@dataclass
class ResultMeta:
    cid: int
    unit_uid: int
    epoch: int
    shard: int
    read_version: int          # server version the client started from
    server_version: int        # server version at assimilation time
    t_arrival: float = 0.0

    @property
    def staleness(self) -> int:
        return max(0, self.server_version - self.read_version)


class ServerScheme:
    """Stateless-client contract: a client downloads server params, trains
    on its shard, uploads a payload; the server assimilates payloads in
    arrival order.  Fault tolerance == dropping any subset of payloads
    leaves the server state valid."""

    name = "base"
    requires_all_clients = False    # True -> not fault tolerant (BSP/EASGD-p)

    def init_state(self, params0) -> Dict[str, Any]:
        return {"params": params0, "version": 0}

    def params_for_client(self, state):
        return state["params"]

    def client_payload(self, trained, start):
        """What travels client -> server. Default: full weights (the paper)."""
        return trained

    def assimilate(self, state, payload, meta: ResultMeta) -> Dict[str, Any]:
        raise NotImplementedError

    def on_epoch(self, state, epoch: int) -> None:
        pass


class VCASGD(ServerScheme):
    def __init__(self, alpha: float | Callable[[int], float] = 0.95,
                 staleness_gamma: Optional[float] = None):
        self.alpha = alpha if callable(alpha) else V.const_alpha(alpha)
        self.staleness_gamma = staleness_gamma
        self.name = "vc-asgd"

    def assimilate(self, state, payload, meta: ResultMeta):
        a = self.alpha(meta.epoch)
        if self.staleness_gamma is not None:
            a = V.staleness_alpha(a, meta.staleness, self.staleness_gamma)
        state["params"] = V.vc_asgd_update(state["params"], payload, a)
        state["version"] += 1
        return state


class Downpour(ServerScheme):
    """Client sends delta = trained - start (the accumulated update of its
    n_push local iterations); server adds it, Hogwild-style."""

    def __init__(self, server_lr: float = 1.0):
        self.server_lr = server_lr
        self.name = "downpour"

    def client_payload(self, trained, start):
        return jax.tree.map(lambda t, s: t - s, trained, start)

    def assimilate(self, state, payload, meta: ResultMeta):
        state["params"] = jax.tree.map(
            lambda p, d: p + self.server_lr * d, state["params"], payload)
        state["version"] += 1
        return state


class DCASGD(Downpour):
    """Delay-compensated: server keeps the per-client backup of the params
    it handed out; the compensation term uses (W_now - W_backup)."""

    def __init__(self, server_lr: float = 1.0, lam: float = 0.1):
        super().__init__(server_lr)
        self.lam = lam
        self.name = "dc-asgd"
        self._backups: Dict[int, Any] = {}

    def params_for_client(self, state):
        return state["params"]

    def note_handout(self, cid: int, params):
        self._backups[cid] = params

    def assimilate(self, state, payload, meta: ResultMeta):
        backup = self._backups.get(meta.cid, state["params"])
        # payload is a delta ~ -lr * accumulated grad; compensate elementwise
        comp = jax.tree.map(
            lambda d, wn, wb: d + self.lam * d * d *
            jnp.sign(d) * (wn - wb),
            payload, state["params"], backup)
        state["params"] = jax.tree.map(
            lambda p, d: p + self.server_lr * d, state["params"], comp)
        state["version"] += 1
        return state


class EASGDPersistent(ServerScheme):
    """Elastic averaging with persistent client replicas (Zhang et al. [17]).
    Clients keep local params between rounds; both sides move toward each
    other with moving rate beta.  NOT fault tolerant: a preempted client
    loses its replica (it must restart from the center), and the method
    assumes updates from all clients."""

    requires_all_clients = True

    def __init__(self, beta: float = 0.001):
        self.beta = beta
        self.name = "easgd-persistent"
        self.replicas: Dict[int, Any] = {}

    def params_for_client(self, state, cid: Optional[int] = None):
        if cid is not None and cid in self.replicas:
            return self.replicas[cid]
        return state["params"]

    def assimilate(self, state, payload, meta: ResultMeta):
        center = state["params"]
        diff = jax.tree.map(lambda x, c: x - c, payload, center)
        state["params"] = jax.tree.map(
            lambda c, d: c + self.beta * d, center, diff)
        self.replicas[meta.cid] = jax.tree.map(
            lambda x, d: x - self.beta * d, payload, diff)
        state["version"] += 1
        return state

    def drop_client(self, cid: int) -> None:
        self.replicas.pop(cid, None)       # preemption loses the replica


class SyncBSP(ServerScheme):
    """Bulk-synchronous: buffer weights until EVERY shard of the round has
    reported, then average.  Under preemption the barrier stalls until
    timeout reassignment refills the missing shards."""

    requires_all_clients = True

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.name = "sync-bsp"
        self._buf: Dict[int, Any] = {}

    def assimilate(self, state, payload, meta: ResultMeta):
        self._buf[meta.shard] = payload
        if len(self._buf) == self.n_shards:
            ws = list(self._buf.values())
            state["params"] = jax.tree.map(
                lambda *xs: sum(xs) / len(xs), *ws)
            state["version"] += 1
            self._buf.clear()
        return state
