"""Server parameter-update schemes: VC-ASGD plus every baseline the paper
discusses (§II-B, §III-C), on the typed protocol API (repro.protocol).

Every scheme is a pure algorithm folded over a typed, pytree-registered
``SchemeState`` (``state.params`` rides the FlatParams bus — ONE
contiguous buffer, so every update is a single fused pass over the whole
model).  The protocol bookkeeping the old ``ServerScheme`` accreted —
handout dicts, drop hooks, residual-norm ledgers — lives in the
``Coordinator`` now; reconstruction bases arrive on the lease
(``ResultMeta.base``, rebuilt from the DECODED download-leg frames, so
what a scheme reconstructs from is exactly what crossed the wire),
client-side compression is the pure ``encode_payload``, and schemes keep
only genuinely algorithmic state (replicas, backups, barrier buffers) in
their state dataclasses.

* VC-ASGD    — Eq. 1 lerp per arriving result; alpha schedule per epoch.
* Downpour   — clients push accumulated deltas (n_push == one subtask), the
               server applies them directly (Dean et al. [4]).
* EASGD      — elastic averaging with moving rate beta; the paper shows its
               VC-equivalent is VC-ASGD with alpha = 1 - beta = 0.999
               (§IV-C); a persistent-client variant exposes its
               fault-INtolerance under preemption.
* DC-ASGD    — Downpour + diagonal-Hessian delay compensation (Zheng [18]).
* SyncBSP    — barriered weight averaging per round (the cluster paradigm);
               included to show why synchrony fails on preemptible fleets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set

import jax.numpy as jnp

from repro.core import flat as F
from repro.core import vc_asgd as V
# the import direction is protocol -> baselines consumers: baselines
# depends on the protocol types, never the other way around.  ResultMeta /
# as_flat / as_tree are re-exported for older call sites.
from repro.protocol.scheme import ServerScheme
from repro.protocol.types import (Lease, ResultMeta, SchemeState, as_flat,
                                  as_tree, scheme_state)

__all__ = [
    "ServerScheme", "SchemeState", "ResultMeta", "as_flat", "as_tree",
    "VCASGD", "CompressedVCASGD", "Downpour", "DCASGD", "EASGDPersistent",
    "EASGDFlatPod", "SyncBSP", "easgd_elastic_update",
    "DCASGDState", "EASGDState", "PodState", "BSPState",
]


def easgd_elastic_update(center_buf: jnp.ndarray, replicas_buf: jnp.ndarray,
                         beta: float, *, use_kernel: bool = False):
    """One fused elastic round over the whole pod: center [N] and replicas
    [n, N] move toward each other in a single pass.  The jnp form IS the
    oracle (kernels/ref.py ``easgd_elastic`` — one definition, no drift);
    ``use_kernel=True`` routes through the single-launch Pallas kernel."""
    if use_kernel:
        from repro.kernels import ops as K
        return K.fused_easgd_flat(center_buf, replicas_buf, beta)
    from repro.kernels import ref as R
    return R.easgd_elastic(center_buf, replicas_buf, beta)


class VCASGD(ServerScheme):
    def __init__(self, alpha: float | Callable[[int], float] = 0.95,
                 staleness_gamma: Optional[float] = None):
        self.alpha = alpha if callable(alpha) else V.const_alpha(alpha)
        self.staleness_gamma = staleness_gamma
        self.name = "vc-asgd"

    def assimilate(self, state, payload, meta: ResultMeta):
        a = self.alpha(meta.epoch)
        if self.staleness_gamma is not None:
            a = V.staleness_alpha(a, meta.staleness, self.staleness_gamma)
        fp = state.params
        c_buf = self._payload_buf(fp, payload)
        state.params = V.vc_asgd_update_flat(fp, c_buf, a)
        state.version += 1
        return state

    def assimilation_retention(self, meta: ResultMeta) -> float:
        """Eq. 1 retains exactly alpha of the pre-update server mass per
        arrival (incl. staleness damping) — what the aggregation tier
        multiplies across a flush window to form the merged weight."""
        a = self.alpha(meta.epoch)
        if self.staleness_gamma is not None:
            a = V.staleness_alpha(a, meta.staleness, self.staleness_gamma)
        return a


class CompressedVCASGD(VCASGD):
    """VC-ASGD whose client -> server payload is the ``compress_flat``
    sparse delta (GLOBAL top-k + int8 with error feedback,
    core/compression.py) instead of the full weight buffer — the payload
    that actually rides the wire as a SPARSE frame (transfer/wire.py).

    ``encode_payload`` is pure: it compresses (trained - base) with the
    residual the Coordinator carries for the client; the server
    reconstructs W_c = base + dequantized delta from the lease's
    reconstruction-base ref (``meta.base`` — keyed per lease, so Tn
    concurrent subtasks can't clobber each other) and assimilates via the
    ordinary Eq. 1 lerp.  A preempted client loses its residual (the
    Coordinator drops it with the client), which error feedback tolerates
    by design."""

    def __init__(self, alpha=0.95, density: float = 0.05,
                 staleness_gamma: Optional[float] = None):
        super().__init__(alpha, staleness_gamma)
        self.density = density
        self.name = "vc-asgd-compressed"

    def encode_payload(self, trained_buf, base: F.FlatParams, residual):
        from repro.core import compression as C
        delta = trained_buf - base.buf
        return C.compress_flat(delta, density=self.density,
                               logical_n=base.spec.n, residual=residual)

    def assimilate(self, state, payload, meta: ResultMeta):
        from repro.core import compression as C
        if isinstance(payload, C.CompressedDelta):
            base = (meta.base.buf if meta.base is not None
                    else state.params.buf)
            payload = base + C.decompress_flat(payload)
        return super().assimilate(state, payload, meta)


class Downpour(ServerScheme):
    """Client sends delta = trained - base (the accumulated update of its
    n_push local iterations); server adds it, Hogwild-style."""

    def __init__(self, server_lr: float = 1.0):
        self.server_lr = server_lr
        self.name = "downpour"

    def encode_payload(self, trained_buf, base: F.FlatParams, residual):
        return trained_buf - base.buf, None

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = state.params
        d_buf = self._payload_buf(fp, payload)
        state.params = fp.with_buf(fp.buf + self.server_lr * d_buf)
        state.version += 1
        return state


@scheme_state
@dataclass
class DCASGDState(SchemeState):
    """Downpour state + the per-client delay-compensation backups (the
    LATEST handout per client, per Zheng et al.'s one-outstanding-task
    formulation — deliberately not per lease)."""

    _tree_fields = ("params", "backups")

    backups: Dict[int, F.FlatParams] = field(default_factory=dict)


class DCASGD(Downpour):
    """Delay-compensated: the per-client backup of the latest handed-out
    params is recorded at lease issue (``on_issue``); the compensation
    term uses (W_now - W_backup)."""

    def __init__(self, server_lr: float = 1.0, lam: float = 0.1):
        super().__init__(server_lr)
        self.lam = lam
        self.name = "dc-asgd"

    def init_state(self, params0) -> DCASGDState:
        return DCASGDState(params=as_flat(params0))

    def on_issue(self, state: DCASGDState, lease: Lease) -> None:
        state.backups[lease.cid] = lease.base

    def assimilate(self, state: DCASGDState, payload, meta: ResultMeta):
        fp = state.params
        backup = state.backups.get(meta.cid, fp)
        # payload is a delta ~ -lr * accumulated grad; compensate elementwise
        d = self._payload_buf(fp, payload)
        comp = d + self.lam * d * d * jnp.sign(d) * (fp.buf - backup.buf)
        state.params = fp.with_buf(fp.buf + self.server_lr * comp)
        state.version += 1
        return state


@scheme_state
@dataclass
class EASGDState(SchemeState):
    """Elastic center (``params``) + persistent per-client replicas."""

    _tree_fields = ("params", "replicas")

    replicas: Dict[int, F.FlatParams] = field(default_factory=dict)


class EASGDPersistent(ServerScheme):
    """Elastic averaging with persistent client replicas (Zhang et al. [17]).
    Clients keep local params between rounds; both sides move toward each
    other with moving rate beta.  NOT fault tolerant: a preempted client
    loses its replica (it must restart from the center), and the method
    assumes updates from all clients."""

    requires_all_clients = True
    has_local_replicas = True

    def __init__(self, beta: float = 0.001):
        self.beta = beta
        self.name = "easgd-persistent"

    def init_state(self, params0) -> EASGDState:
        return EASGDState(params=as_flat(params0))

    def handout(self, state: EASGDState, cid: int, default):
        return state.replicas.get(cid, state.params)

    def assimilate(self, state: EASGDState, payload, meta: ResultMeta):
        center = state.params
        x_buf = self._payload_buf(center, payload)
        diff = x_buf - center.buf
        state.params = center.with_buf(center.buf + self.beta * diff)
        state.replicas[meta.cid] = center.with_buf(x_buf - self.beta * diff)
        state.version += 1
        return state

    def drop_client(self, state: EASGDState, cid: int) -> None:
        state.replicas.pop(cid, None)      # preemption loses the replica


@scheme_state
@dataclass
class PodState(SchemeState):
    """Pod-scale elastic state: center (``params``), ALL replicas as one
    [n_replicas, padded] matrix, and the round-barrier bookkeeping.
    ``pending`` buffers rows arriving mid-round (one entry per slot, like
    BSP) and stacks ONCE at the barrier — updating the matrix per payload
    would copy it n times per round."""

    _tree_fields = ("params", "replicas", "pending")

    replicas: Optional[jnp.ndarray] = None          # [n_replicas, padded]
    pending: Dict[int, jnp.ndarray] = field(default_factory=dict)
    lost: Set[int] = field(default_factory=set)     # restart from center
    slot_owner: Dict[int, int] = field(default_factory=dict)


class EASGDFlatPod(ServerScheme):
    """EASGD at pod scale on the flat bus: the elastic center is ONE
    contiguous buffer and all replicas live in one [n_replicas, N] matrix;
    when every replica of the round has reported, a single fused elastic
    update (``easgd_elastic_update`` / the single-launch Pallas kernel)
    moves center and all replicas at once — no per-client dict, no leaf
    walk.  Like every elastic scheme the round is synchronous, so it is
    NOT fault tolerant: a preempted client's replica resets to the center
    and the round barrier re-waits for it.

    One client per replica slot: the fleet size must equal ``n_replicas``
    (slot = cid % n_replicas, and a slot claimed by one cid rejects
    payloads from another — silently overwriting a colliding client's
    round, or waiting forever on a slot no client maps to, would corrupt
    the barrier).

    With ``compress_density`` set the replica payload rides the wire as a
    ``compress_flat`` SPARSE frame (top-k + int8 with per-client error
    feedback, carried by the Coordinator) instead of the dense buffer:
    ``encode_payload`` compresses (trained - base), the server
    reconstructs from the lease's base ref.  A preempted client loses its
    residual with its replica."""

    requires_all_clients = True
    has_local_replicas = True

    def __init__(self, n_replicas: int, beta: float = 0.05,
                 use_kernel: bool = False,
                 compress_density: Optional[float] = None):
        self.n_replicas = n_replicas
        self.beta = beta
        self.use_kernel = use_kernel
        self.compress_density = compress_density
        self.name = "easgd-flat-pod"

    def _slot(self, state: PodState, cid: int) -> int:
        slot = cid % self.n_replicas
        owner = state.slot_owner.setdefault(slot, cid)
        if owner != cid:
            raise ValueError(
                f"EASGDFlatPod needs one client per replica slot "
                f"(n_replicas={self.n_replicas}): cid {cid} collides with "
                f"cid {owner} on slot {slot}")
        return slot

    def init_state(self, params0) -> PodState:
        fp = as_flat(params0)
        return PodState(params=fp,
                        replicas=jnp.tile(fp.buf[None, :],
                                          (self.n_replicas, 1)))

    def handout(self, state: PodState, cid: int, default):
        fp = state.params
        if state.replicas is None or self._slot(state, cid) in state.lost:
            return fp
        return fp.with_buf(state.replicas[self._slot(state, cid)])

    def encode_payload(self, trained_buf, base: F.FlatParams, residual):
        if self.compress_density is None:
            return trained_buf, None
        from repro.core import compression as C
        delta = trained_buf - base.buf
        return C.compress_flat(delta, density=self.compress_density,
                               logical_n=base.spec.n, residual=residual)

    def assimilate(self, state: PodState, payload, meta: ResultMeta):
        from repro.core import compression as C
        fp = state.params
        slot = self._slot(state, meta.cid)
        if isinstance(payload, C.CompressedDelta):
            base = (meta.base.buf if meta.base is not None else fp.buf)
            payload = base + C.decompress_flat(payload)
        state.pending[slot] = self._payload_buf(fp, payload)
        state.lost.discard(slot)
        if len(state.pending) == self.n_replicas:
            stacked = jnp.stack([state.pending[s]
                                 for s in range(self.n_replicas)])
            center, state.replicas = easgd_elastic_update(
                fp.buf, stacked, self.beta, use_kernel=self.use_kernel)
            state.params = fp.with_buf(center)
            state.version += 1
            state.pending.clear()
        return state

    def drop_client(self, state: PodState, cid: int) -> None:
        if state.replicas is None:
            return
        slot = self._slot(state, cid)
        state.pending.pop(slot, None)      # the barrier re-waits for it
        state.lost.add(slot)


@scheme_state
@dataclass
class BSPState(SchemeState):
    """Synchronous barrier buffer: weights per shard until the round is
    complete."""

    _tree_fields = ("params", "pending")

    pending: Dict[int, jnp.ndarray] = field(default_factory=dict)


class SyncBSP(ServerScheme):
    """Bulk-synchronous: buffer weights until EVERY shard of the round has
    reported, then average — one fused mean over the stacked flat buffers.
    Under preemption the barrier stalls until timeout reassignment refills
    the missing shards."""

    requires_all_clients = True

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.name = "sync-bsp"

    def init_state(self, params0) -> BSPState:
        return BSPState(params=as_flat(params0))

    def assimilate(self, state: BSPState, payload, meta: ResultMeta):
        fp = state.params
        state.pending[meta.shard] = self._payload_buf(fp, payload)
        if len(state.pending) == self.n_shards:
            stacked = jnp.stack(list(state.pending.values()))
            state.params = fp.with_buf(stacked.mean(axis=0))
            state.version += 1
            state.pending.clear()
        return state
