"""Server parameter-update schemes: VC-ASGD plus every baseline the paper
discusses (§II-B, §III-C), behind one interface the simulator drives.

Server state rides the flat bus (core/flat.py): ``state["params"]`` is a
``FlatParams`` — ONE contiguous buffer — so every scheme's update is a
single fused pass over the whole model, the same code path the pod-scale
runtime uses (core/vc_asgd.py flat forms).  Clients remain tree-world
(they train real models); payloads are flattened once at assimilation.

* VC-ASGD    — Eq. 1 lerp per arriving result; alpha schedule per epoch.
* Downpour   — clients push accumulated deltas (n_push == one subtask), the
               server applies them directly (Dean et al. [4]).
* EASGD      — elastic averaging with moving rate beta; the paper shows its
               VC-equivalent is VC-ASGD with alpha = 1 - beta = 0.999
               (§IV-C); a persistent-client variant exposes its
               fault-INtolerance under preemption.
* DC-ASGD    — Downpour + diagonal-Hessian delay compensation (Zheng [18]).
* SyncBSP    — barriered weight averaging per round (the cluster paradigm);
               included to show why synchrony fails on preemptible fleets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as F
from repro.core import vc_asgd as V


@dataclass
class ResultMeta:
    cid: int
    unit_uid: int
    epoch: int
    shard: int
    read_version: int          # server version the client started from
    server_version: int        # server version at assimilation time
    t_arrival: float = 0.0

    @property
    def staleness(self) -> int:
        return max(0, self.server_version - self.read_version)


def as_flat(params) -> F.FlatParams:
    """Coerce a tree onto the flat bus (no-op for FlatParams)."""
    return params if isinstance(params, F.FlatParams) else F.flatten(params)


def as_tree(params):
    """Inverse boundary: what clients/evaluators consume."""
    return F.unflatten(params) if isinstance(params, F.FlatParams) else params


def _payload_buf(fp: F.FlatParams, payload) -> jnp.ndarray:
    """Boundary-only conversion: a payload still in tree form is flattened
    exactly ONCE here; flat payloads (the simulator's hot path — it
    flattens the trained tree once per result and every scheme then works
    on buffers) pass through untouched."""
    if isinstance(payload, F.FlatParams):
        return payload.buf
    if isinstance(payload, jnp.ndarray):
        return payload
    return F.flatten_like(payload, fp.spec)


def easgd_elastic_update(center_buf: jnp.ndarray, replicas_buf: jnp.ndarray,
                         beta: float, *, use_kernel: bool = False):
    """One fused elastic round over the whole pod: center [N] and replicas
    [n, N] move toward each other in a single pass.  The jnp form IS the
    oracle (kernels/ref.py ``easgd_elastic`` — one definition, no drift);
    ``use_kernel=True`` routes through the single-launch Pallas kernel."""
    if use_kernel:
        from repro.kernels import ops as K
        return K.fused_easgd_flat(center_buf, replicas_buf, beta)
    from repro.kernels import ref as R
    return R.easgd_elastic(center_buf, replicas_buf, beta)


class ServerScheme:
    """Stateless-client contract: a client downloads server params, trains
    on its shard, uploads a payload; the server assimilates payloads in
    arrival order.  Fault tolerance == dropping any subset of payloads
    leaves the server state valid.

    ``state["params"]`` is a FlatParams; conversions happen at the BOUNDARY
    only: the simulator unflattens once per dispatch (clients train real
    trees) and flattens the trained tree once per result; ``payload_flat``
    and ``assimilate`` then stay in buffer-world — a scheme performs ZERO
    tree<->bus conversions per round (core/flat.py counts them;
    tests/test_simulator.py pins the per-result budget)."""

    name = "base"
    requires_all_clients = False    # True -> not fault tolerant (BSP/EASGD-p)
    has_local_replicas = False      # True -> params_for_client needs the cid

    def init_state(self, params0) -> Dict[str, Any]:
        return {"params": as_flat(params0), "version": 0}

    def params_for_client(self, state, cid: Optional[int] = None):
        return state["params"]

    def client_payload(self, trained, start):
        """Tree-world legacy form of ``payload_flat`` (kept for direct
        scheme use outside the simulator). Default: full weights."""
        return trained

    def payload_flat(self, trained_buf: jnp.ndarray, start: F.FlatParams,
                     *, cid: Optional[int] = None):
        """What travels client -> server, on the bus: ``trained_buf`` is
        the trained tree flattened once at the boundary, ``start`` the
        flat params the client trained from.  The return value is what
        gets wire-encoded (transfer/wire.py): a raw buffer ships as a
        dense frame, a CompressedDelta as a sparse one.  ``cid`` lets
        compressed schemes keep per-client error-feedback residuals.
        Default: full weights."""
        return trained_buf

    def assimilate(self, state, payload, meta: ResultMeta) -> Dict[str, Any]:
        raise NotImplementedError

    def on_epoch(self, state, epoch: int) -> None:
        pass

    def drop_client(self, cid: int) -> None:
        """Preemption hook: schemes with client-local state lose it here."""

    def note_handout(self, cid: int, params, uid: Optional[int] = None) -> None:
        """Hook: the server handed ``params`` to client ``cid`` for work
        unit ``uid`` (DC-ASGD keeps them as the delay-compensation backup;
        compressed schemes key the delta-reconstruction base by uid)."""

    def drop_result(self, cid: int, uid: Optional[int] = None) -> None:
        """Hook: unit ``uid``'s in-flight result was discarded (timeout
        reassignment or mid-upload death) — schemes release any per-unit
        state noted at handout, or it would leak one [padded] buffer per
        discarded result."""

    def residual_norm(self, cid: Optional[int] = None) -> float:
        """Error-feedback bookkeeping for the wire header: l2 norm of the
        residual the client carries after its latest payload (0.0 for
        uncompressed schemes)."""
        return 0.0


class VCASGD(ServerScheme):
    def __init__(self, alpha: float | Callable[[int], float] = 0.95,
                 staleness_gamma: Optional[float] = None):
        self.alpha = alpha if callable(alpha) else V.const_alpha(alpha)
        self.staleness_gamma = staleness_gamma
        self.name = "vc-asgd"

    def assimilate(self, state, payload, meta: ResultMeta):
        a = self.alpha(meta.epoch)
        if self.staleness_gamma is not None:
            a = V.staleness_alpha(a, meta.staleness, self.staleness_gamma)
        fp = as_flat(state["params"])
        c_buf = _payload_buf(fp, payload)
        state["params"] = V.vc_asgd_update_flat(fp, c_buf, a)
        state["version"] += 1
        return state


class CompressedVCASGD(VCASGD):
    """VC-ASGD whose client -> server payload is the ``compress_flat``
    sparse delta (GLOBAL top-k + int8 with error feedback,
    core/compression.py) instead of the full weight buffer — the payload
    that actually rides the wire as a SPARSE frame (transfer/wire.py).

    The client compresses (trained - start) with its carried residual; the
    server reconstructs W_c = start + dequantized delta from the copy it
    handed out for that unit (keyed by uid — with Tn concurrent subtasks a
    per-client key would be clobbered by the next handout) and assimilates
    via the ordinary Eq. 1 lerp.  A preempted client loses its residual
    (it lived client-side), which error feedback tolerates by design."""

    def __init__(self, alpha=0.95, density: float = 0.05,
                 staleness_gamma: Optional[float] = None):
        super().__init__(alpha, staleness_gamma)
        self.density = density
        self.name = "vc-asgd-compressed"
        self._handout: Dict[tuple, jnp.ndarray] = {}    # (cid, uid) -> buf
        self._residuals: Dict[int, jnp.ndarray] = {}    # cid -> [padded]
        self._res_norms: Dict[int, float] = {}          # cid -> l2 norm

    def note_handout(self, cid: int, params, uid: Optional[int] = None):
        self._handout[(cid, uid)] = as_flat(params).buf

    def drop_result(self, cid: int, uid: Optional[int] = None) -> None:
        self._handout.pop((cid, uid), None)

    def residual_norm(self, cid: Optional[int] = None) -> float:
        return self._res_norms.get(cid, 0.0)

    def payload_flat(self, trained_buf, start: F.FlatParams, *,
                     cid: Optional[int] = None):
        from repro.core import compression as C
        delta = trained_buf - start.buf
        payload, res = C.compress_flat(delta, density=self.density,
                                       logical_n=start.spec.n,
                                       residual=self._residuals.get(cid))
        if cid is not None:
            self._residuals[cid] = res
            self._res_norms[cid] = float(jnp.linalg.norm(res))
        return payload

    def assimilate(self, state, payload, meta: ResultMeta):
        from repro.core import compression as C
        fp = as_flat(state["params"])
        if isinstance(payload, C.CompressedDelta):
            base = self._handout.pop((meta.cid, meta.unit_uid), fp.buf)
            payload = base + C.decompress_flat(payload)
        return super().assimilate(state, payload, meta)

    def drop_client(self, cid: int) -> None:
        self._residuals.pop(cid, None)
        self._res_norms.pop(cid, None)
        for key in [k for k in self._handout if k[0] == cid]:
            self._handout.pop(key, None)


class Downpour(ServerScheme):
    """Client sends delta = trained - start (the accumulated update of its
    n_push local iterations); server adds it, Hogwild-style."""

    def __init__(self, server_lr: float = 1.0):
        self.server_lr = server_lr
        self.name = "downpour"

    def client_payload(self, trained, start):
        return jax.tree.map(lambda t, s: t - s, trained, start)

    def payload_flat(self, trained_buf, start: F.FlatParams, *,
                     cid: Optional[int] = None):
        return trained_buf - start.buf

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        d_buf = _payload_buf(fp, payload)
        state["params"] = fp.with_buf(fp.buf + self.server_lr * d_buf)
        state["version"] += 1
        return state


class DCASGD(Downpour):
    """Delay-compensated: server keeps the per-client backup of the params
    it handed out; the compensation term uses (W_now - W_backup)."""

    def __init__(self, server_lr: float = 1.0, lam: float = 0.1):
        super().__init__(server_lr)
        self.lam = lam
        self.name = "dc-asgd"
        self._backups: Dict[int, F.FlatParams] = {}

    def note_handout(self, cid: int, params, uid: Optional[int] = None):
        self._backups[cid] = as_flat(params)

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        backup = as_flat(self._backups.get(meta.cid, fp))
        # payload is a delta ~ -lr * accumulated grad; compensate elementwise
        d = _payload_buf(fp, payload)
        comp = d + self.lam * d * d * jnp.sign(d) * (fp.buf - backup.buf)
        state["params"] = fp.with_buf(fp.buf + self.server_lr * comp)
        state["version"] += 1
        return state


class EASGDPersistent(ServerScheme):
    """Elastic averaging with persistent client replicas (Zhang et al. [17]).
    Clients keep local params between rounds; both sides move toward each
    other with moving rate beta.  NOT fault tolerant: a preempted client
    loses its replica (it must restart from the center), and the method
    assumes updates from all clients."""

    requires_all_clients = True
    has_local_replicas = True

    def __init__(self, beta: float = 0.001):
        self.beta = beta
        self.name = "easgd-persistent"
        self.replicas: Dict[int, F.FlatParams] = {}

    def params_for_client(self, state, cid: Optional[int] = None):
        if cid is not None and cid in self.replicas:
            return self.replicas[cid]
        return state["params"]

    def assimilate(self, state, payload, meta: ResultMeta):
        center = as_flat(state["params"])
        x_buf = _payload_buf(center, payload)
        diff = x_buf - center.buf
        state["params"] = center.with_buf(center.buf + self.beta * diff)
        self.replicas[meta.cid] = center.with_buf(x_buf - self.beta * diff)
        state["version"] += 1
        return state

    def drop_client(self, cid: int) -> None:
        self.replicas.pop(cid, None)       # preemption loses the replica


class EASGDFlatPod(ServerScheme):
    """EASGD at pod scale on the flat bus: the elastic center is ONE
    contiguous buffer and all replicas live in one [n_replicas, N] matrix;
    when every replica of the round has reported, a single fused elastic
    update (``easgd_elastic_update`` / the single-launch Pallas kernel)
    moves center and all replicas at once — no per-client dict, no leaf
    walk.  Like every elastic scheme the round is synchronous, so it is
    NOT fault tolerant: a preempted client's replica resets to the center
    and the round barrier re-waits for it.

    One client per replica slot: the fleet size must equal ``n_replicas``
    (slot = cid % n_replicas, and a slot claimed by one cid rejects
    payloads from another — silently overwriting a colliding client's
    round, or waiting forever on a slot no client maps to, would corrupt
    the barrier).

    With ``compress_density`` set the replica payload rides the wire as a
    ``compress_flat`` SPARSE frame (top-k + int8 with per-slot error
    feedback) instead of the dense buffer: the client compresses
    (trained - start), the server reconstructs from the copy it handed
    out for that unit.  A preempted slot loses its residual with its
    replica."""

    requires_all_clients = True
    has_local_replicas = True

    def __init__(self, n_replicas: int, beta: float = 0.05,
                 use_kernel: bool = False,
                 compress_density: Optional[float] = None):
        self.n_replicas = n_replicas
        self.beta = beta
        self.use_kernel = use_kernel
        self.compress_density = compress_density
        self.name = "easgd-flat-pod"
        self.replicas: Optional[jnp.ndarray] = None     # [n_replicas, padded]
        # rows arriving mid-round buffer here (one dict entry per slot, like
        # SyncBSP._buf) and stack ONCE at the barrier — updating the
        # [n_replicas, N] matrix per payload would copy it n times per round
        self._pending: Dict[int, jnp.ndarray] = {}
        self._lost: set = set()            # preempted slots restart from center
        self._slot_owner: Dict[int, int] = {}
        self._handout: Dict[tuple, jnp.ndarray] = {}    # (slot, uid) -> buf
        self._residuals: Dict[int, jnp.ndarray] = {}    # slot -> [padded]
        self._res_norms: Dict[int, float] = {}          # slot -> l2 norm

    def _slot(self, cid: int) -> int:
        slot = cid % self.n_replicas
        owner = self._slot_owner.setdefault(slot, cid)
        if owner != cid:
            raise ValueError(
                f"EASGDFlatPod needs one client per replica slot "
                f"(n_replicas={self.n_replicas}): cid {cid} collides with "
                f"cid {owner} on slot {slot}")
        return slot

    def init_state(self, params0) -> Dict[str, Any]:
        state = super().init_state(params0)
        buf = state["params"].buf
        self.replicas = jnp.tile(buf[None, :], (self.n_replicas, 1))
        self._pending.clear()
        self._lost.clear()
        self._slot_owner.clear()
        self._handout.clear()
        self._residuals.clear()
        self._res_norms.clear()
        return state

    def params_for_client(self, state, cid: Optional[int] = None):
        fp = state["params"]
        if cid is None or self.replicas is None \
                or self._slot(cid) in self._lost:
            return fp
        return fp.with_buf(self.replicas[self._slot(cid)])

    def note_handout(self, cid: int, params, uid: Optional[int] = None):
        if self.compress_density is not None:
            self._handout[(self._slot(cid), uid)] = as_flat(params).buf

    def drop_result(self, cid: int, uid: Optional[int] = None) -> None:
        self._handout.pop((self._slot(cid), uid), None)

    def residual_norm(self, cid: Optional[int] = None) -> float:
        return self._res_norms.get(self._slot(cid), 0.0) \
            if cid is not None else 0.0

    def payload_flat(self, trained_buf, start: F.FlatParams, *,
                     cid: Optional[int] = None):
        if self.compress_density is None:
            return trained_buf
        from repro.core import compression as C
        slot = self._slot(cid)
        delta = trained_buf - start.buf
        payload, res = C.compress_flat(delta, density=self.compress_density,
                                       logical_n=start.spec.n,
                                       residual=self._residuals.get(slot))
        self._residuals[slot] = res
        self._res_norms[slot] = float(jnp.linalg.norm(res))
        return payload

    def assimilate(self, state, payload, meta: ResultMeta):
        from repro.core import compression as C
        fp = as_flat(state["params"])
        slot = self._slot(meta.cid)
        if isinstance(payload, C.CompressedDelta):
            base = self._handout.pop((slot, meta.unit_uid), fp.buf)
            payload = base + C.decompress_flat(payload)
        self._pending[slot] = _payload_buf(fp, payload)
        self._lost.discard(slot)
        if len(self._pending) == self.n_replicas:
            stacked = jnp.stack([self._pending[s]
                                 for s in range(self.n_replicas)])
            center, self.replicas = easgd_elastic_update(
                fp.buf, stacked, self.beta, use_kernel=self.use_kernel)
            state["params"] = fp.with_buf(center)
            state["version"] += 1
            self._pending.clear()
        return state

    def drop_client(self, cid: int) -> None:
        if self.replicas is None:
            return
        slot = self._slot(cid)
        self._pending.pop(slot, None)      # the barrier re-waits for it
        self._lost.add(slot)
        self._residuals.pop(slot, None)    # residual lived with the replica
        self._res_norms.pop(slot, None)
        for key in [k for k in self._handout if k[0] == slot]:
            self._handout.pop(key, None)


class SyncBSP(ServerScheme):
    """Bulk-synchronous: buffer weights until EVERY shard of the round has
    reported, then average — one fused mean over the stacked flat buffers.
    Under preemption the barrier stalls until timeout reassignment refills
    the missing shards."""

    requires_all_clients = True

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.name = "sync-bsp"
        self._buf: Dict[int, jnp.ndarray] = {}

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        self._buf[meta.shard] = _payload_buf(fp, payload)
        if len(self._buf) == self.n_shards:
            stacked = jnp.stack(list(self._buf.values()))
            state["params"] = fp.with_buf(stacked.mean(axis=0))
            state["version"] += 1
            self._buf.clear()
        return state
