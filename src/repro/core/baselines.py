"""Server parameter-update schemes: VC-ASGD plus every baseline the paper
discusses (§II-B, §III-C), behind one interface the simulator drives.

Server state rides the flat bus (core/flat.py): ``state["params"]`` is a
``FlatParams`` — ONE contiguous buffer — so every scheme's update is a
single fused pass over the whole model, the same code path the pod-scale
runtime uses (core/vc_asgd.py flat forms).  Clients remain tree-world
(they train real models); payloads are flattened once at assimilation.

* VC-ASGD    — Eq. 1 lerp per arriving result; alpha schedule per epoch.
* Downpour   — clients push accumulated deltas (n_push == one subtask), the
               server applies them directly (Dean et al. [4]).
* EASGD      — elastic averaging with moving rate beta; the paper shows its
               VC-equivalent is VC-ASGD with alpha = 1 - beta = 0.999
               (§IV-C); a persistent-client variant exposes its
               fault-INtolerance under preemption.
* DC-ASGD    — Downpour + diagonal-Hessian delay compensation (Zheng [18]).
* SyncBSP    — barriered weight averaging per round (the cluster paradigm);
               included to show why synchrony fails on preemptible fleets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as F
from repro.core import vc_asgd as V


@dataclass
class ResultMeta:
    cid: int
    unit_uid: int
    epoch: int
    shard: int
    read_version: int          # server version the client started from
    server_version: int        # server version at assimilation time
    t_arrival: float = 0.0

    @property
    def staleness(self) -> int:
        return max(0, self.server_version - self.read_version)


def as_flat(params) -> F.FlatParams:
    """Coerce a tree onto the flat bus (no-op for FlatParams)."""
    return params if isinstance(params, F.FlatParams) else F.flatten(params)


def as_tree(params):
    """Inverse boundary: what clients/evaluators consume."""
    return F.unflatten(params) if isinstance(params, F.FlatParams) else params


class ServerScheme:
    """Stateless-client contract: a client downloads server params, trains
    on its shard, uploads a payload; the server assimilates payloads in
    arrival order.  Fault tolerance == dropping any subset of payloads
    leaves the server state valid.

    ``state["params"]`` is a FlatParams; ``client_payload`` receives and
    returns trees (the client side); ``assimilate`` flattens the payload
    onto the server's layout and updates the flat buffer in one pass."""

    name = "base"
    requires_all_clients = False    # True -> not fault tolerant (BSP/EASGD-p)

    def init_state(self, params0) -> Dict[str, Any]:
        return {"params": as_flat(params0), "version": 0}

    def params_for_client(self, state):
        return state["params"]

    def client_payload(self, trained, start):
        """What travels client -> server. Default: full weights (the paper)."""
        return trained

    def assimilate(self, state, payload, meta: ResultMeta) -> Dict[str, Any]:
        raise NotImplementedError

    def on_epoch(self, state, epoch: int) -> None:
        pass


class VCASGD(ServerScheme):
    def __init__(self, alpha: float | Callable[[int], float] = 0.95,
                 staleness_gamma: Optional[float] = None):
        self.alpha = alpha if callable(alpha) else V.const_alpha(alpha)
        self.staleness_gamma = staleness_gamma
        self.name = "vc-asgd"

    def assimilate(self, state, payload, meta: ResultMeta):
        a = self.alpha(meta.epoch)
        if self.staleness_gamma is not None:
            a = V.staleness_alpha(a, meta.staleness, self.staleness_gamma)
        fp = as_flat(state["params"])
        c_buf = F.flatten_like(payload, fp.spec)
        state["params"] = V.vc_asgd_update_flat(fp, c_buf, a)
        state["version"] += 1
        return state


class Downpour(ServerScheme):
    """Client sends delta = trained - start (the accumulated update of its
    n_push local iterations); server adds it, Hogwild-style."""

    def __init__(self, server_lr: float = 1.0):
        self.server_lr = server_lr
        self.name = "downpour"

    def client_payload(self, trained, start):
        return jax.tree.map(lambda t, s: t - s, trained, start)

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        d_buf = F.flatten_like(payload, fp.spec)
        state["params"] = fp.with_buf(fp.buf + self.server_lr * d_buf)
        state["version"] += 1
        return state


class DCASGD(Downpour):
    """Delay-compensated: server keeps the per-client backup of the params
    it handed out; the compensation term uses (W_now - W_backup)."""

    def __init__(self, server_lr: float = 1.0, lam: float = 0.1):
        super().__init__(server_lr)
        self.lam = lam
        self.name = "dc-asgd"
        self._backups: Dict[int, F.FlatParams] = {}

    def params_for_client(self, state):
        return state["params"]

    def note_handout(self, cid: int, params):
        self._backups[cid] = as_flat(params)

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        backup = as_flat(self._backups.get(meta.cid, fp))
        # payload is a delta ~ -lr * accumulated grad; compensate elementwise
        d = F.flatten_like(payload, fp.spec)
        comp = d + self.lam * d * d * jnp.sign(d) * (fp.buf - backup.buf)
        state["params"] = fp.with_buf(fp.buf + self.server_lr * comp)
        state["version"] += 1
        return state


class EASGDPersistent(ServerScheme):
    """Elastic averaging with persistent client replicas (Zhang et al. [17]).
    Clients keep local params between rounds; both sides move toward each
    other with moving rate beta.  NOT fault tolerant: a preempted client
    loses its replica (it must restart from the center), and the method
    assumes updates from all clients."""

    requires_all_clients = True

    def __init__(self, beta: float = 0.001):
        self.beta = beta
        self.name = "easgd-persistent"
        self.replicas: Dict[int, F.FlatParams] = {}

    def params_for_client(self, state, cid: Optional[int] = None):
        if cid is not None and cid in self.replicas:
            return self.replicas[cid]
        return state["params"]

    def assimilate(self, state, payload, meta: ResultMeta):
        center = as_flat(state["params"])
        x_buf = F.flatten_like(payload, center.spec)
        diff = x_buf - center.buf
        state["params"] = center.with_buf(center.buf + self.beta * diff)
        self.replicas[meta.cid] = center.with_buf(x_buf - self.beta * diff)
        state["version"] += 1
        return state

    def drop_client(self, cid: int) -> None:
        self.replicas.pop(cid, None)       # preemption loses the replica


class SyncBSP(ServerScheme):
    """Bulk-synchronous: buffer weights until EVERY shard of the round has
    reported, then average — one fused mean over the stacked flat buffers.
    Under preemption the barrier stalls until timeout reassignment refills
    the missing shards."""

    requires_all_clients = True

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.name = "sync-bsp"
        self._buf: Dict[int, jnp.ndarray] = {}

    def assimilate(self, state, payload, meta: ResultMeta):
        fp = as_flat(state["params"])
        self._buf[meta.shard] = F.flatten_like(payload, fp.spec)
        if len(self._buf) == self.n_shards:
            stacked = jnp.stack(list(self._buf.values()))
            state["params"] = fp.with_buf(stacked.mean(axis=0))
            state["version"] += 1
            self._buf.clear()
        return state
