"""Training-cost model (§IV-E): standard vs preemptible fleets, horizontal
vs vertical scaling price curves.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.preemption import PAPER_FLEET, SERVER_INSTANCE, InstanceType


@dataclass(frozen=True)
class CostReport:
    hours: float
    fleet_std_per_hr: float
    fleet_pre_per_hr: float
    total_std: float
    total_pre: float
    saving_frac: float


def fleet_cost(itypes: Sequence[InstanceType], hours: float,
               include_server: bool = False) -> CostReport:
    std = sum(t.price_standard for t in itypes)
    pre = sum(t.price_preemptible for t in itypes)
    if include_server:
        std += SERVER_INSTANCE.price_standard
        pre += SERVER_INSTANCE.price_standard     # server stays on-demand
    return CostReport(
        hours=hours, fleet_std_per_hr=std, fleet_pre_per_hr=pre,
        total_std=std * hours, total_pre=pre * hours,
        saving_frac=1.0 - pre / std if std else 0.0)


def paper_p5c5_fleet() -> Sequence[InstanceType]:
    """The §IV-E experiment: 5 instances, 40 vCPU, 160 GB total."""
    return PAPER_FLEET


def preemption_overhead_hours(base_hours: float, preempt_rate_per_hr: float,
                              n_clients: int, restart_delay_s: float,
                              lost_work_s: float) -> float:
    """Expected extra wall-clock from preemptions: each event costs the
    restart delay plus the lost (reassigned) subtask work, amortized over the
    fleet.  Used for the cost-vs-reliability trade-off table."""
    events = preempt_rate_per_hr * n_clients * base_hours
    extra_s = events * (restart_delay_s + lost_work_s) / max(n_clients, 1)
    return base_hours + extra_s / 3600.0
