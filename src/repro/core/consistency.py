"""Parameter stores with strong vs eventual consistency (§III-D, §IV-D).

The paper stores the whole parameter set as one value in Redis (eventual,
main-memory) and compares against MySQL LONGBLOB (strong).  Measured
per-update latencies: 0.87 s (Redis) vs 1.29 s (MySQL) — strong consistency
serializes concurrent parameter-server transactions; eventual consistency
lets them proceed concurrently and occasionally loses an update
(last-writer-wins clobbers a racing commit), which SGD-family training
tolerates (Downpour/Adam/Petuum evidence cited in the paper).

Semantics here are faithful:

* ``EventualStore`` — a parameter server reads a snapshot when it starts
  processing; its later write clobbers any commit that landed in between
  (those updates are LOST — really lost: future reads never see them).
  Writes never queue.
* ``StrongStore`` — serializable read-modify-write: the transaction takes a
  global lock, so the base of every update is the latest head and nothing
  is ever lost — but commits queue behind each other (1.29 s each).
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

# measured per-update transaction latencies from §IV-D
REDIS_UPDATE_S = 0.87
MYSQL_UPDATE_S = 1.29


@dataclass
class StoreStats:
    updates: int = 0
    lost_updates: int = 0
    total_latency_s: float = 0.0
    queue_wait_s: float = 0.0


class EventualStore:
    """Last-writer-wins with snapshot reads (Redis analog)."""

    def __init__(self, params: Any, update_latency_s: float = REDIS_UPDATE_S,
                 history: int = 64):
        self._hist: List[Tuple[float, Any]] = [(-1e18, params)]
        self._times: List[float] = [-1e18]      # parallel commit times
        self._hist_cap = history
        self.update_latency_s = update_latency_s
        self.stats = StoreStats()
        self.version = 0

    def read_at(self, t: float) -> Tuple[Any, int]:
        """Snapshot: the latest value committed at or before t (bisect
        over the parallel times list; the oldest retained entry when
        everything is newer — same as the old linear scan)."""
        i = bisect_right(self._times, t) - 1
        return self._hist[max(i, 0)][1], self.version

    def head(self) -> Any:
        return self._hist[-1][1]

    def commit(self, t_read: float, t_ready: float, new_params: Any
               ) -> float:
        """Write computed from a snapshot taken at t_read; lands at
        t_ready + latency.  Commits in (t_read, t_write) are clobbered."""
        t_write = t_ready + self.update_latency_s
        lost = sum(1 for tc, _ in self._hist if t_read < tc < t_write)
        self.stats.lost_updates += lost
        # drop clobbered entries: future reads must never see them
        self._hist = [(tc, p) for tc, p in self._hist if tc <= t_read]
        self._hist.append((t_write, new_params))
        self._hist = self._hist[-self._hist_cap:]
        self._times = [tc for tc, _ in self._hist]
        self.version += 1
        self.stats.updates += 1
        self.stats.total_latency_s += self.update_latency_s
        return t_write


class StrongStore:
    """Serializable transactions (MySQL analog): read-modify-write under a
    global lock; base is always the head; commits queue."""

    def __init__(self, params: Any, update_latency_s: float = MYSQL_UPDATE_S):
        self._params = params
        self.update_latency_s = update_latency_s
        self.stats = StoreStats()
        self.version = 0
        self._busy_until = -1e18

    def transact(self, t_ready: float, update_fn: Callable[[Any], Any]
                 ) -> float:
        """Acquire the lock at max(t_ready, busy), apply update_fn to the
        head, release after the transaction latency."""
        t_start = max(t_ready, self._busy_until)
        self.stats.queue_wait_s += t_start - t_ready
        self._params = update_fn(self._params)
        t_done = t_start + self.update_latency_s
        self._busy_until = t_done
        self.version += 1
        self.stats.updates += 1
        self.stats.total_latency_s += t_done - t_ready
        return t_done

    def head(self) -> Any:
        return self._params

    def read_at(self, t: float) -> Tuple[Any, int]:
        return self._params, self.version
