"""Update compression for cross-pod / WAN transfer (beyond paper; DESIGN §4).

The paper ships whole 21.2 MB .h5 parameter files and leans on BOINC's
gzip.  At LLM scale the assimilation payload is the parameter *delta*
(W_c - W_s0), which is compressible:

* magnitude top-k sparsification with **error feedback** (the residual is
  carried into the next round, so nothing is permanently lost — the same
  "lossy but convergent" philosophy as the paper's eventual consistency),
* symmetric per-block int8 quantization of the surviving values.

Both have pure-jnp forms here and fused Pallas kernels (kernels/topk_mask,
kernels/quantize) for the TPU hot path.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedDelta(NamedTuple):
    values: jnp.ndarray      # int8 quantized surviving values [k]
    scales: jnp.ndarray      # f32 per-block scales [k / block]
    indices: jnp.ndarray     # int32 flat indices [k]
    shape: tuple             # original shape
    density: float


def topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest-|x| entries (flat)."""
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh)


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8: returns (q int8 [n], scales f32 [n/block])."""
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                    block: int = 256) -> jnp.ndarray:
    pad = (-n) % block
    qf = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (qf * scales[:, None]).reshape(-1)[:n]


def compress_delta(delta: jnp.ndarray, *, density: float = 0.05,
                   block: int = 256) -> Tuple[CompressedDelta, jnp.ndarray]:
    """Top-k + int8. Returns (payload, residual) — residual is the error-
    feedback carry (what was NOT transmitted, plus quantization error)."""
    flat = delta.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * density))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    q, scales = quantize_int8(sel, block)
    deq = dequantize_int8(q, scales, k, block)
    transmitted = jnp.zeros_like(flat).at[idx].set(deq)
    residual = (flat - transmitted).reshape(delta.shape)
    payload = CompressedDelta(values=q, scales=scales,
                              indices=idx.astype(jnp.int32),
                              shape=delta.shape, density=density)
    return payload, residual


def decompress_delta(p: CompressedDelta) -> jnp.ndarray:
    n = 1
    for s in p.shape:
        n *= s
    deq = dequantize_int8(p.values, p.scales, p.values.size)
    flat = jnp.zeros((n,), jnp.float32).at[p.indices].set(deq)
    return flat.reshape(p.shape)


def payload_bytes(p: CompressedDelta) -> int:
    return int(p.values.size * 1 + p.scales.size * 4 + p.indices.size * 4)


def compression_ratio(p: CompressedDelta, dtype_bytes: int = 4) -> float:
    n = 1
    for s in p.shape:
        n *= s
    return n * dtype_bytes / payload_bytes(p)
