"""Update compression for cross-pod / WAN transfer (beyond paper; DESIGN §4).

The paper ships whole 21.2 MB .h5 parameter files and leans on BOINC's
gzip.  At LLM scale the assimilation payload is the parameter *delta*
(W_c - W_s0), which is compressible:

* magnitude top-k sparsification with **error feedback** (the residual is
  carried into the next round, so nothing is permanently lost — the same
  "lossy but convergent" philosophy as the paper's eventual consistency),
* symmetric per-block int8 quantization of the surviving values.

Both have pure-jnp forms here and fused Pallas kernels (kernels/topk_mask,
kernels/quantize) for the TPU hot path.

Two selection granularities: ``compress_delta`` (per-tensor, the original
form) and ``compress_flat``/``compress_tree_global`` — ONE top-k over the
whole model on the FlatParams bus (core/flat.py), which retains at least
as much update mass at equal density and is what the runtime ships.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressedDelta(NamedTuple):
    values: jnp.ndarray      # int8 quantized surviving values [k]
    scales: jnp.ndarray      # f32 per-block scales [k / block]
    indices: jnp.ndarray     # int32 flat indices [k]
    shape: tuple             # original shape
    density: float
    block: int = 256         # quantization block (the wire format ships it)


def topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest-|x| entries (flat)."""
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh)


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8: returns (q int8 [n], scales f32 [n/block])."""
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                    block: int = 256) -> jnp.ndarray:
    pad = (-n) % block
    qf = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (qf * scales[:, None]).reshape(-1)[:n]


def compress_delta(delta: jnp.ndarray, *, density: float = 0.05,
                   block: int = 256) -> Tuple[CompressedDelta, jnp.ndarray]:
    """Top-k + int8 on one tensor. Returns (payload, residual) — residual is
    the error-feedback carry (what was NOT transmitted, plus quantization
    error).  Thin shape-preserving wrapper over compress_flat (one canonical
    top-k/quantize/error-feedback pipeline)."""
    payload, residual = compress_flat(delta.reshape(-1), density=density,
                                      block=block)
    return (payload._replace(shape=delta.shape),
            residual.reshape(delta.shape))


def decompress_delta(p: CompressedDelta) -> jnp.ndarray:
    n = 1
    for s in p.shape:
        n *= s
    deq = dequantize_int8(p.values, p.scales, p.values.size, block=p.block)
    flat = jnp.zeros((n,), jnp.float32).at[p.indices].set(deq)
    return flat.reshape(p.shape)


# ---------------------------------------------------------------------------
# flat-bus forms (core/flat.py): ONE global top-k over the whole model.
# A global (whole-model) magnitude top-k at density d never keeps a smaller
# mass than per-leaf top-k at the same d: the per-leaf selection is a
# feasible point of the global selection problem.  This is the Hivemind-
# style flat, globally-sparsified update buffer.
# ---------------------------------------------------------------------------

def compress_flat(delta_buf: jnp.ndarray, *, density: float = 0.05,
                  block: int = 256, logical_n: Optional[int] = None,
                  residual: Optional[jnp.ndarray] = None
                  ) -> Tuple[CompressedDelta, jnp.ndarray]:
    """Global top-k + int8 with error feedback on a flat [padded] buffer.

    ``logical_n`` (spec.n) sizes k so tail padding never inflates the
    density budget; ``residual`` is the error-feedback carry from the
    previous round (added to the delta BEFORE selection, so nothing is
    permanently lost).  Returns (payload, new_residual [padded])."""
    flat = delta_buf.reshape(-1).astype(jnp.float32)
    if residual is not None:
        flat = flat + residual.reshape(-1).astype(jnp.float32)
    n = int(logical_n) if logical_n is not None else flat.size
    k = max(1, min(n, int(n * density)))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    q, scales = quantize_int8(sel, block)
    deq = dequantize_int8(q, scales, k, block)
    transmitted = jnp.zeros_like(flat).at[idx].set(deq)
    new_residual = flat - transmitted
    payload = CompressedDelta(values=q, scales=scales,
                              indices=idx.astype(jnp.int32),
                              shape=(flat.size,), density=density,
                              block=block)
    return payload, new_residual


def decompress_flat(p: CompressedDelta) -> jnp.ndarray:
    """Rebuild the dense flat [padded] buffer from a global payload."""
    return decompress_delta(p)


def compress_tree_global(delta_tree, *, density: float = 0.05,
                         block: int = 256,
                         residual: Optional[jnp.ndarray] = None):
    """Whole-model compression of a delta TREE through the flat bus.
    Returns (payload, new_residual_buf, spec) — decompress with
    ``flat.unflatten(FlatParams(decompress_flat(p), spec))``."""
    from repro.core import flat as F
    fp = F.flatten(delta_tree)
    payload, res = compress_flat(fp.buf, density=density, block=block,
                                 logical_n=fp.spec.n, residual=residual)
    return payload, res, fp.spec


def payload_bytes(p: CompressedDelta) -> int:
    return int(p.values.size * 1 + p.scales.size * 4 + p.indices.size * 4)


def compression_ratio(p: CompressedDelta, dtype_bytes: int = 4) -> float:
    n = 1
    for s in p.shape:
        n *= s
    return n * dtype_bytes / payload_bytes(p)
