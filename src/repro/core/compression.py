"""Update compression for cross-pod / WAN transfer (beyond paper; DESIGN §4).

The paper ships whole 21.2 MB .h5 parameter files and leans on BOINC's
gzip.  At LLM scale the assimilation payload is the parameter *delta*
(W_c - W_s0), which is compressible:

* magnitude top-k sparsification with **error feedback** (the residual is
  carried into the next round, so nothing is permanently lost — the same
  "lossy but convergent" philosophy as the paper's eventual consistency),
* symmetric per-block int8 quantization of the surviving values.

Both have pure-jnp forms here and fused Pallas kernels (kernels/topk_mask,
kernels/quantize) for the TPU hot path.

Two selection granularities: ``compress_delta`` (per-tensor, the original
form) and ``compress_flat``/``compress_tree_global`` — ONE top-k over the
whole model on the FlatParams bus (core/flat.py), which retains at least
as much update mass at equal density and is what the runtime ships.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressedDelta(NamedTuple):
    values: jnp.ndarray      # int8 quantized surviving values [k]
    scales: jnp.ndarray      # f32 per-block scales [k / block]
    indices: jnp.ndarray     # int32 flat indices [k], ASCENDING (canonical)
    shape: tuple             # original shape
    density: float
    block: int = 256         # quantization block (the wire format ships it)


# ---------------------------------------------------------------------------
# blocked exact top-k selection (replaces the global O(N log N) sort).
#
# The full-buffer ``jax.lax.top_k`` was the measured soft spot of the
# compressed path (ROADMAP perf trajectory: compressed_flat 0.47x vs the
# per-leaf walk).  Selection only needs the SET of the k largest-|x|
# entries, and that set is determined by one scalar: the k-th magnitude.
# Magnitudes compare exactly as their float bit patterns (bitcast of |x|
# is monotone for non-negative floats), so the whole pipeline runs in
# uint32 bit space with zero float-compare subtleties:
#
#   1. sample: sort a strided sample of the magnitude bits and pick a
#      conservative lower bracket ``lo`` (count(bits >= lo) lands in
#      [k, k + _MARGIN] w.h.p. — one O(N) count pass verifies),
#   2. stats pass: ONE memory-bound pass packs the ``bits >= lo`` mask
#      into uint32 words (the blocked kernel form is
#      kernels/topk_mask.py::blocked_topk_stats — per-block packed words
#      + per-block counts), so the rank scan that follows runs over
#      N/32 words instead of N elements,
#   3. refinement: popcount-cumsum over the words + binary rank search
#      extracts the <= k + _MARGIN candidate positions; sorting just the
#      candidate bits (tiny vs N) yields the EXACT k-th magnitude tau,
#   4. exact-k ties: candidates equal to tau keep only the first
#      ``k - count(bits > tau)`` by index — deterministic under any tie
#      multiplicity (lowest flat index wins, the same tie order
#      ``lax.top_k`` uses).
#
# If the sampled bracket misses (adversarial or near-constant data, e.g.
# an all-zero delta), a ``lax.cond`` falls back to ``lax.top_k`` — exact
# either way, the bracket only decides speed.  Indices are returned
# ASCENDING: that is the canonical payload order (block-ordered output of
# the stats kernel; also the faster scatter order for error feedback and
# decompression).
# ---------------------------------------------------------------------------

_SAMPLE = 1 << 16            # strided threshold sample size
_MARGIN = 1 << 15            # candidate headroom above k (>= 10 sigma)
_MIN_FAST_N = 16 * _SAMPLE   # below this the global sort wins (the sample
                             # sort alone would rival sorting the input)


def _magnitude_bits(flat: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.abs(flat), jnp.uint32)


def _rank_positions(words: jnp.ndarray, cum: jnp.ndarray,
                    ranks: jnp.ndarray) -> jnp.ndarray:
    """Positions of the rank-th set bits (1-based ranks) of a packed mask:
    binary rank search over the word cumsum, then a 5-step popcount
    bisection inside the word."""
    nw = words.shape[0]
    widx = jnp.minimum(jnp.searchsorted(cum, ranks, side="left"), nw - 1)
    base = jnp.where(widx > 0, cum[jnp.maximum(widx - 1, 0)], 0)
    r_in = ranks - base
    word = words[widx]
    pos = jnp.zeros_like(r_in)
    for shift in (16, 8, 4, 2, 1):
        trial = pos + shift
        below = jax.lax.population_count(
            word & ((jnp.uint32(1) << trial.astype(jnp.uint32))
                    - jnp.uint32(1))).astype(jnp.int32)
        pos = jnp.where(below < r_in, trial, pos)
    return widx * 32 + pos


def select_topk(flat: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices (int32, ascending) of the exact k largest-|flat| entries.

    Deterministic under magnitude ties: the lowest flat indices win —
    exactly ``lax.top_k``'s tie rule, so the selected SET is identical to
    the sort-based selection it replaced."""
    flat = flat.reshape(-1)
    n = flat.shape[0]
    k = int(k)
    if k + _MARGIN >= n or n < _MIN_FAST_N or n % 32:
        # small problems: the global sort is already cheap (and handles
        # every edge case: k == n, unpadded lengths, ...).  f32 top_k, not
        # bits: XLA CPU's integer top_k path is ~10x slower than float.
        return jnp.sort(jax.lax.top_k(jnp.abs(flat), k)[1]).astype(jnp.int32)
    bits = _magnitude_bits(flat)

    nw = n // 32
    cap = k + _MARGIN
    pow2 = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

    # 1. sampled threshold bracket
    stride = n // _SAMPLE
    sample = jnp.sort(bits[::stride][:_SAMPLE])
    frac = k / n
    sigma = int((_SAMPLE * frac * (1.0 - frac)) ** 0.5) + 1
    off = min(_SAMPLE - 1, (_SAMPLE * k) // n + 6 * sigma + 64)
    lo = sample[_SAMPLE - 1 - off]
    c_lo = jnp.sum((bits >= lo).astype(jnp.int32))
    bracket_ok = (c_lo >= k) & (c_lo <= cap)

    ranks = jnp.arange(1, cap + 1, dtype=jnp.int32)

    def fast(_):
        # 2. blocked stats pass: packed candidate mask + word counts
        #    (jnp form of kernels/topk_mask.py::blocked_topk_stats)
        words = jnp.sum(jnp.where((bits >= lo).reshape(nw, 32),
                                  pow2[None, :], jnp.uint32(0)),
                        axis=1, dtype=jnp.uint32)
        cum = jnp.cumsum(jax.lax.population_count(words).astype(jnp.int32))
        # 3. candidate extraction + exact tau from the candidate sort
        ext = _rank_positions(words, cum, ranks)         # [cap] ascending
        valid = ranks <= c_lo
        xbits = jnp.where(valid, bits[ext], jnp.uint32(0xFFFFFFFF))
        srt = jnp.sort(xbits)             # invalid tail sorts to the top
        tau = srt[c_lo - k]               # exact k-th magnitude bits
        c_le = jnp.searchsorted(srt, tau, side="right")
        need = k - (c_lo - c_le)          # ties of tau that survive
        # 4. exact-k keep mask over the candidates (lowest index wins)
        gt = valid & (xbits > tau)
        tie = valid & (xbits == tau)
        tie_rank = jnp.cumsum(tie.astype(jnp.int32)) - tie
        keep = gt | (tie & (tie_rank < need))
        c2 = jnp.cumsum(keep.astype(jnp.int32))
        at = jnp.searchsorted(c2, jnp.arange(1, k + 1, dtype=jnp.int32),
                              side="left")
        return ext[at].astype(jnp.int32)

    def slow(_):
        return jnp.sort(jax.lax.top_k(jnp.abs(flat), k)[1]).astype(jnp.int32)

    return jax.lax.cond(bracket_ok, fast, slow, None)


def topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest-|x| entries — EXACTLY k set bits.

    (The old ``|x| >= thresh`` form kept more than k entries on magnitude
    ties, so sparse frame sizes wobbled with the data; ties now resolve
    deterministically to the lowest flat indices, like ``lax.top_k``.)"""
    idx = select_topk(x.reshape(-1), k)
    return (jnp.zeros((x.size,), bool).at[idx].set(True)).reshape(x.shape)


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8: returns (q int8 [n], scales f32 [n/block])."""
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                    block: int = 256) -> jnp.ndarray:
    pad = (-n) % block
    qf = jnp.pad(q.astype(jnp.float32), (0, pad)).reshape(-1, block)
    return (qf * scales[:, None]).reshape(-1)[:n]


def compress_delta(delta: jnp.ndarray, *, density: float = 0.05,
                   block: int = 256) -> Tuple[CompressedDelta, jnp.ndarray]:
    """Top-k + int8 on one tensor. Returns (payload, residual) — residual is
    the error-feedback carry (what was NOT transmitted, plus quantization
    error).  Thin shape-preserving wrapper over compress_flat (one canonical
    top-k/quantize/error-feedback pipeline)."""
    payload, residual = compress_flat(delta.reshape(-1), density=density,
                                      block=block)
    return (payload._replace(shape=delta.shape),
            residual.reshape(delta.shape))


def decompress_delta(p: CompressedDelta) -> jnp.ndarray:
    n = 1
    for s in p.shape:
        n *= s
    deq = dequantize_int8(p.values, p.scales, p.values.size, block=p.block)
    flat = jnp.zeros((n,), jnp.float32).at[p.indices].set(deq)
    return flat.reshape(p.shape)


# ---------------------------------------------------------------------------
# flat-bus forms (core/flat.py): ONE global top-k over the whole model.
# A global (whole-model) magnitude top-k at density d never keeps a smaller
# mass than per-leaf top-k at the same d: the per-leaf selection is a
# feasible point of the global selection problem.  This is the Hivemind-
# style flat, globally-sparsified update buffer.
# ---------------------------------------------------------------------------

def compress_flat(delta_buf: jnp.ndarray, *, density: float = 0.05,
                  block: int = 256, logical_n: Optional[int] = None,
                  residual: Optional[jnp.ndarray] = None
                  ) -> Tuple[CompressedDelta, jnp.ndarray]:
    """Global top-k + int8 with error feedback on a flat [padded] buffer.

    ``logical_n`` (spec.n) sizes k so tail padding never inflates the
    density budget; ``residual`` is the error-feedback carry from the
    previous round (added to the delta BEFORE selection, so nothing is
    permanently lost).  Returns (payload, new_residual [padded])."""
    flat = delta_buf.reshape(-1).astype(jnp.float32)
    if residual is not None:
        flat = flat + residual.reshape(-1).astype(jnp.float32)
    n = int(logical_n) if logical_n is not None else flat.size
    k = max(1, min(n, int(n * density)))
    idx = select_topk(flat, k)          # exact top-k set, ascending indices
    sel = flat[idx]
    q, scales = quantize_int8(sel, block)
    deq = dequantize_int8(q, scales, k, block)
    # error feedback: subtract what was transmitted, in place at the kept
    # indices (bit-exact vs the dense ``flat - scatter(deq)`` form: the
    # indices are unique, and IEEE a - b == a + (-b))
    new_residual = flat.at[idx].add(-deq)
    payload = CompressedDelta(values=q, scales=scales,
                              indices=idx,
                              shape=(flat.size,), density=density,
                              block=block)
    return payload, new_residual


def decompress_flat(p: CompressedDelta) -> jnp.ndarray:
    """Rebuild the dense flat [padded] buffer from a global payload."""
    return decompress_delta(p)


def compress_tree_global(delta_tree, *, density: float = 0.05,
                         block: int = 256,
                         residual: Optional[jnp.ndarray] = None):
    """Whole-model compression of a delta TREE through the flat bus.
    Returns (payload, new_residual_buf, spec) — decompress with
    ``flat.unflatten(FlatParams(decompress_flat(p), spec))``."""
    from repro.core import flat as F
    fp = F.flatten(delta_tree)
    payload, res = compress_flat(fp.buf, density=density, block=block,
                                 logical_n=fp.spec.n, residual=residual)
    return payload, res, fp.spec


def payload_bytes(p: CompressedDelta) -> int:
    return int(p.values.size * 1 + p.scales.size * 4 + p.indices.size * 4)


def compression_ratio(p: CompressedDelta, dtype_bytes: int = 4) -> float:
    n = 1
    for s in p.shape:
        n *= s
    return n * dtype_bytes / payload_bytes(p)
