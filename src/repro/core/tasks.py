"""Laptop-scale training tasks for the VC simulator.

The paper trains ResNetV2/CIFAR10.  The simulator needs thousands of client
training calls, so the default task is a small MLP on a synthetic
teacher-labeled classification problem (deterministic, learnable, with a
real generalization gap).  A small CNN on 8x8x3 synthetic images is
provided for higher-fidelity (slower) runs — same API.

Accuracy curves produced by these tasks are REAL training dynamics (actual
JAX SGD on actual data); only wall-clock time is simulated.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TaskData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray


def make_classification_data(n_train: int = 5000, n_val: int = 1000,
                             dim: int = 32, n_classes: int = 10,
                             seed: int = 0) -> TaskData:
    """Teacher-MLP labeled Gaussian features + label noise -> learnable but
    not saturating instantly (mirrors CIFAR10's ~0.73/0.82 plateau shape)."""
    rng = np.random.default_rng(seed)
    n = n_train + n_val
    x = rng.standard_normal((n, dim)).astype(np.float32)
    w1 = rng.standard_normal((dim, 64)).astype(np.float32) / np.sqrt(dim)
    w2 = rng.standard_normal((64, n_classes)).astype(np.float32) / 8.0
    logits = np.maximum(x @ w1, 0) @ w2
    y = logits.argmax(-1).astype(np.int32)
    flip = rng.random(n) < 0.08                       # 8% label noise
    y[flip] = rng.integers(0, n_classes, flip.sum())
    return TaskData(x[:n_train], y[:n_train], x[n_train:], y[n_train:])


def make_image_data(n_train: int = 5000, n_val: int = 1000, res: int = 8,
                    n_classes: int = 10, seed: int = 0) -> TaskData:
    rng = np.random.default_rng(seed)
    n = n_train + n_val
    x = rng.standard_normal((n, res, res, 3)).astype(np.float32)
    # class templates + noise
    templates = rng.standard_normal((n_classes, res, res, 3)).astype(np.float32)
    y = rng.integers(0, n_classes, n).astype(np.int32)
    x = 0.8 * x + 1.2 * templates[y]
    return TaskData(x[:n_train], y[:n_train], x[n_train:], y[n_train:])


class MLPTask:
    """dim -> 128 -> 64 -> n_classes MLP, Adam client training."""

    def __init__(self, dim: int = 32, n_classes: int = 10, lr: float = 1e-3,
                 batch: int = 50):
        self.dim, self.n_classes, self.lr, self.batch = dim, n_classes, lr, batch
        self._train = jax.jit(self._train_impl, static_argnames=("steps",))
        self._eval = jax.jit(self._eval_impl)

    def init_params(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        d, h1, h2, c = self.dim, 128, 64, self.n_classes
        init = jax.nn.initializers.he_normal()
        return {
            "w1": init(k1, (d, h1)), "b1": jnp.zeros((h1,)),
            "w2": init(k2, (h1, h2)), "b2": jnp.zeros((h2,)),
            "w3": init(k3, (h2, c)), "b3": jnp.zeros((c,)),
        }

    def _fwd(self, p, x):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]

    def _loss(self, p, x, y):
        lg = self._fwd(p, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(y.shape[0]), y])

    def _train_impl(self, p, x, y, key, steps: int):
        """Adam over `steps` minibatches sampled from (x, y) — the client-side
        training of one subtask (the paper: TF/Adam, lr 1e-3, no momentum
        tricks)."""
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        b1, b2, eps = 0.9, 0.999, 1e-8

        def step(carry, i):
            p, m, v = carry
            idx = jax.random.randint(jax.random.fold_in(key, i), (self.batch,),
                                     0, x.shape[0])
            g = jax.grad(self._loss)(p, x[idx], y[idx])
            m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
            v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
            t = i + 1.0
            mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
            p = jax.tree.map(lambda pp, a, b: pp - self.lr * a /
                             (jnp.sqrt(b) + eps), p, mh, vh)
            return (p, m, v), ()

        (p, _, _), _ = jax.lax.scan(step, (p, m, v),
                                    jnp.arange(steps, dtype=jnp.float32))
        return p

    def client_train(self, params, x, y, *, steps: int, seed: int):
        return self._train(params, jnp.asarray(x), jnp.asarray(y),
                           jax.random.PRNGKey(seed), steps=steps)

    def _eval_impl(self, p, x, y):
        return jnp.mean(jnp.argmax(self._fwd(p, x), -1) == y)

    def evaluate(self, params, x, y) -> float:
        return float(self._eval(params, jnp.asarray(x), jnp.asarray(y)))


class CNNTask(MLPTask):
    """Small conv net on [res, res, 3] synthetic images (ResNet stand-in)."""

    def __init__(self, res: int = 8, n_classes: int = 10, lr: float = 1e-3,
                 batch: int = 50):
        self.res = res
        super().__init__(dim=res * res * 3, n_classes=n_classes, lr=lr,
                         batch=batch)

    def init_params(self, key):
        ks = jax.random.split(key, 4)
        init = jax.nn.initializers.he_normal()
        c = self.n_classes
        return {
            "c1": init(ks[0], (3, 3, 3, 16)), "bc1": jnp.zeros((16,)),
            "c2": init(ks[1], (3, 3, 16, 32)), "bc2": jnp.zeros((32,)),
            "w": init(ks[2], ((self.res // 4) ** 2 * 32, 64)),
            "b": jnp.zeros((64,)),
            "w2": init(ks[3], (64, c)), "b2": jnp.zeros((c,)),
        }

    def _fwd(self, p, x):
        x = x.reshape(x.shape[0], self.res, self.res, 3)
        h = jax.lax.conv_general_dilated(x, p["c1"], (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + p["bc1"])
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        h = jax.lax.conv_general_dilated(h, p["c2"], (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + p["bc2"])
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["w"] + p["b"])
        return h @ p["w2"] + p["b2"]

    def client_train(self, params, x, y, *, steps: int, seed: int):
        x = np.asarray(x).reshape(x.shape[0], -1)
        return self._train(params, jnp.asarray(x), jnp.asarray(y),
                           jax.random.PRNGKey(seed), steps=steps)

    def evaluate(self, params, x, y) -> float:
        x = np.asarray(x).reshape(x.shape[0], -1)
        return float(self._eval(params, jnp.asarray(x), jnp.asarray(y)))
