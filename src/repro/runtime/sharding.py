"""Sharding planner: decides, per architecture and mesh, how every parameter
and activation is laid out (DESIGN.md §6).

Divisibility is engineered, never assumed (JAX rejects non-divisible
shardings):

* attention: head-TP when ``n_heads % tp == 0`` (KV heads sharded too when
  they divide, else replicated); context-parallel otherwise; fully local for
  tiny models (whisper),
* FFN: always TP over ``model`` (every assigned d_ff divides 16),
* embeddings: vocab over ``model`` (padded to a multiple of 16),
* FSDP: the non-TP dim of every >=2D parameter is sharded over ``data``
  when divisible,
* decode caches: heads over ``model`` when KV divides, else the two-tier
  chunk-sharded layout (seq over ``model``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.plan import NullPlan


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclass
class MeshPlan(NullPlan):
    """Concrete plan for (cfg, mesh).  ``act`` applies sharding constraints;
    ``param_spec`` assigns PartitionSpecs to the parameter pytree."""
    mesh: Mesh = None
    cfg: ModelConfig = None
    data_axis: Any = "data"          # may be ("pod", "data") for multi-pod DP
    model_axis: str = "model"
    tp: int = 1
    dp: int = 1
    fsdp: bool = True
    kv_sharded: bool = False         # kv heads divide tp
    cache_mode: str = "seq"          # "heads" | "seq"

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, cfg: ModelConfig, mesh: Mesh,
              data_axis="data", model_axis="model",
              attn_mode: Optional[str] = None,
              decode_batch: Optional[int] = None,
              moe_ep: bool = False,
              zero_dp: bool = False) -> "MeshPlan":
        """zero_dp: fold the model axis into data — pure DP with ZeRO-style
        fully-sharded params/optimizer, replicated compute (the right plan
        for small-dense models where TP activation all-reduces dominate)."""
        if zero_dp:
            data_axis = (*_tup(data_axis), *_tup(model_axis))
            model_axis = None
            attn_mode = attn_mode or "local"
        tp = int(np.prod([mesh.shape[a] for a in _tup(model_axis)]))
        dp = int(np.prod([mesh.shape[a] for a in _tup(data_axis)]))
        if moe_ep:
            assert cfg.moe is not None and cfg.moe.n_virtual % dp == 0, \
                "set cfg.moe.ep_virtual so n_virtual divides the data axis " \
                "(use ep_tune)"
        if attn_mode is None:
            if cfg.d_model < 1024:
                attn_mode = "local"          # tiny model: replicate attention
            elif _divides(cfg.n_heads, tp):
                attn_mode = "head_tp"
            else:
                attn_mode = "cp"
        kv_sharded = attn_mode == "head_tp" and _divides(cfg.n_kv_heads, tp)
        cache_mode = "heads" if kv_sharded else "seq"
        chunks = tp if cache_mode == "seq" else 1
        # batch-1 long-context decode: nothing to shard over `data`, so the
        # cache chunk dim takes BOTH axes (seq sharded 256/512-way)
        if (decode_batch is not None and not _divides(decode_batch, dp)
                and cache_mode == "seq"):
            chunks = tp * dp
        return cls(mesh=mesh, cfg=cfg, data_axis=data_axis,
                   model_axis=model_axis, tp=tp, dp=dp,
                   attn_mode=attn_mode, cp=(tp if attn_mode == "cp" else 1),
                   kv_sharded=kv_sharded, cache_mode=cache_mode,
                   cache_chunks=chunks, moe_ep=moe_ep,
                   ep=(dp if moe_ep else 1))

    # ------------------------------------------------------------------
    def _axis_size(self, a) -> int:
        return int(np.prod([self.mesh.shape[x] for x in _tup(a)]))

    def _fit(self, spec: P, shape) -> P:
        """Drop sharding on any dim the shape cannot divide (e.g. batch=1)."""
        out = []
        for i, a in enumerate(spec):
            if a is None or i >= len(shape):
                out.append(a)
                continue
            out.append(a if shape[i] % self._axis_size(a) == 0 else None)
        return P(*out)

    @property
    def chunk_axes(self):
        """Mesh axes carrying the decode-cache chunk dim."""
        if self.cache_chunks > self.tp:
            return (*_tup(self.data_axis), *_tup(self.model_axis))
        return self.model_axis

    # ------------------------------------------------------------------
    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def act(self, x, kind: str):
        spec = self.act_spec(kind, getattr(x, "ndim", None))
        if spec is None:
            return x
        spec = self._fit(spec, x.shape)
        return jax.lax.with_sharding_constraint(x, self._ns(spec))

    def act_spec(self, kind: str, ndim: Optional[int] = None) -> Optional[P]:
        D, M = self.data_axis, self.model_axis
        table = {
            "bsd": P(D, None, None),
            "enc_bsd": P(D, None, None),
            "cp_bpsd": P(D, M, None, None),
            "q_bshd": P(D, None, M if self.attn_mode == "head_tp" else None,
                        None),
            "kv_bshd": P(D, None, M if self.kv_sharded else None, None),
            "q_bpshd": P(D, M, None, None, None),
            "kv_rep": P(D, None, None, None),
            "kv_gather": P(D, M, None, None, None, None),
            "logits": P(D, None, M),
            "dec_x": P(D, None),
            "dec_q": P(D, M if self.kv_sharded else None, None),
            "dec_logits": P(D, M),
            "cache_old": (P(D, M, None, None, None)
                          if self.cache_mode == "heads"
                          else P(D, None, M, None, None)),
            "cache_old_L": (P(None, D, M, None, None, None)
                            if self.cache_mode == "heads"
                            else P(None, D, None, M, None, None)),
            # expert-parallel MoE layouts
            "ep_tokens": P(D, None, None),
            "ep_dispatched": P(D, None, None, None, None),
            "ep_returned": P(D, None, None, None),
            "ep_w_in": P(D, None, None, M),
            "ep_w_out": P(D, None, M, None),
        }
        return table.get(kind)

    # ------------------------------------------------------------------
    # parameter shardings (path-pattern rules)
    # ------------------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        D, M = self.data_axis, self.model_axis
        tp, dp = self.tp, self.dp
        name = path.split("/")[-1]

        def fsdp_ok(dim: int) -> bool:
            return self.fsdp and _divides(shape[dim], dp) and \
                int(np.prod(shape)) >= 65536

        def with_fsdp(spec: Tuple, free_dim: int) -> P:
            s = list(spec)
            if s[free_dim] is None and fsdp_ok(free_dim):
                s[free_dim] = D
            return P(*s)

        if len(shape) <= 1:
            return P(*([None] * len(shape)))           # 1D / scalars replicated

        attn_tp = self.attn_mode == "head_tp"
        # ---- embeddings ------------------------------------------------
        if name == "table":                            # [Vp, d]
            return with_fsdp((M, None), 1) if _divides(shape[0], tp) else \
                with_fsdp((None, None), 1)
        if name == "unembed":                          # [d, Vp]
            return with_fsdp((None, M), 0)
        if name == "pos_table":
            return P(None, None)
        # ---- attention ---------------------------------------------------
        if name in ("wq",):                            # [d, h*hd]
            return with_fsdp((None, M), 0) if attn_tp else \
                with_fsdp((None, None), 0)
        if name in ("wk", "wv"):                       # [d, kv*hd]
            return with_fsdp((None, M), 0) if self.kv_sharded else \
                with_fsdp((None, None), 0)
        if name == "wo" and "attn" in path:            # [h*hd, d]
            return with_fsdp((M, None), 1) if attn_tp else \
                with_fsdp((None, None), 1)
        # ---- rwkv time-mix (head-TP always: heads divide for rwkv6) ------
        if "rwkv_tm" in path:
            if name in ("wr", "wk", "wv", "wg"):       # [d, d=h*hd]
                return with_fsdp((None, M), 0)
            if name == "wo":                           # [d, d]
                return with_fsdp((M, None), 1)
            if name in ("lora_a", "w_a"):
                return with_fsdp((None, None), 0)
            if name in ("lora_b", "w_b"):
                return P(*([None] * len(shape)))
            if name == "u":
                return P(M, None) if _divides(shape[0], tp) else P(None, None)
            return P(*([None] * len(shape)))
        if "rwkv_cm" in path:
            if name == "wk":                           # [d, f]
                return with_fsdp((None, M), 0)
            if name == "wv":                           # [f, d]
                return with_fsdp((M, None), 1)
            if name == "wr":
                return with_fsdp((None, None), 0)
        # ---- mamba --------------------------------------------------------
        if "mamba" in path:
            if name == "in_proj":                      # [d, 2*di]
                return with_fsdp((None, M), 0)
            if name == "conv_w":                       # [dc, di]
                return P(None, M)
            if name == "x_proj":                       # [di, dtr+2ds]
                return P(M, None)
            if name == "dt_proj":                      # [dtr, di]
                return P(None, M)
            if name == "a_log":                        # [di, ds]
                return P(M, None)
            if name == "out_proj":                     # [di, d]
                return with_fsdp((M, None), 1)
        # ---- MoE ----------------------------------------------------------
        if name == "router":                           # [d, e]
            return with_fsdp((None, None), 0)
        if "moe" in path and name in ("wi", "wg"):     # [E, d, fv]
            if self.moe_ep:
                return P(D, None, M)                   # experts over data (EP)
            s = [None, None, M]
            if fsdp_ok(1):
                s[1] = D
            return P(*s)
        if "moe" in path and name == "wo":             # [E, fv, d]
            if self.moe_ep:
                return P(D, M, None)
            s = [None, M, None]
            if fsdp_ok(2):
                s[2] = D
            return P(*s)
        # ---- dense mlp ----------------------------------------------------
        if name in ("wi", "wg"):                       # [d, f]
            return with_fsdp((None, M), 0)
        if name == "wo":                               # [f, d]
            return with_fsdp((M, None), 1)
        if name in ("w1", "w2"):                       # vis_proj
            return with_fsdp((None, None), 0)
        return P(*([None] * len(shape)))

    def param_shardings(self, params_tree) -> Any:
        """Pytree of NamedShardings matching params (stacked scan dims get a
        leading None)."""
        def spec_for(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            shape = leaf.shape
            # scan-stacked group params carry a leading repeats dim
            stacked = pstr.startswith("group") or pstr.split("/")[0] in ("enc", "dec")
            if stacked:
                inner = self.param_spec(pstr, shape[1:])
                return self._ns(P(None, *inner))
            return self._ns(self.param_spec(pstr, shape))

        return jax.tree_util.tree_map_with_path(spec_for, params_tree)

    # ------------------------------------------------------------------
    def batch_shardings(self, batch_tree, lead_dims: int = 0) -> Any:
        """lead_dims: unsharded leading dims (e.g. 1 for [accum, b, ...])."""
        D = self.data_axis

        def spec_for(leaf):
            nd = len(leaf.shape)
            spec = P(*([None] * lead_dims), D,
                     *([None] * (nd - 1 - lead_dims)))
            return self._ns(self._fit(spec, leaf.shape))

        return jax.tree.map(spec_for, batch_tree)

    def cache_shardings(self, cache_tree) -> Any:
        """Decode-cache shardings: dispatch on the state NamedTuple types,
        padding leading (scan-stack / layer) dims with None."""
        from repro.models.layers import DecodeCache
        from repro.models.mamba import MambaState
        from repro.models.rwkv import RWKVState
        from repro.models.whisper import CrossCache, WhisperDecCache
        D, M = self.data_axis, self.model_axis

        def pad(leaf, spec):
            nd = len(leaf.shape)
            full = (*([None] * (nd - len(spec))), *spec)[-nd:]
            return self._ns(self._fit(P(*full), leaf.shape))

        CH = self.chunk_axes

        def walk(node):
            if isinstance(node, DecodeCache):
                old = ((D, M, None, None, None) if self.cache_mode == "heads"
                       else (D, None, CH, None, None))
                return DecodeCache(
                    k_old=pad(node.k_old, old), v_old=pad(node.v_old, old),
                    old_pos=pad(node.old_pos, (None, None)),
                    k_rec=pad(node.k_rec, (D, None, None, None)),
                    v_rec=pad(node.v_rec, (D, None, None, None)),
                    rec_pos=pad(node.rec_pos, (None,)))
            if isinstance(node, MambaState):
                return MambaState(conv=pad(node.conv, (D, M, None)),
                                  ssm=pad(node.ssm, (D, M, None)))
            if isinstance(node, RWKVState):
                hs = (self.cfg.rwkv is not None and
                      _divides(self.cfg.d_model // self.cfg.rwkv.head_dim,
                               self.tp))
                wkv = (D, M, None, None) if hs else (D, None, None, None)
                return RWKVState(wkv=pad(node.wkv, wkv),
                                 tm_prev=pad(node.tm_prev, (D, None)),
                                 cm_prev=pad(node.cm_prev, (D, None)))
            if isinstance(node, CrossCache):
                return CrossCache(k=pad(node.k, (D, None, None, None)),
                                  v=pad(node.v, (D, None, None, None)))
            if isinstance(node, WhisperDecCache):
                return WhisperDecCache(self_cache=walk(node.self_cache),
                                       cross=walk(node.cross))
            if isinstance(node, (tuple, list)):
                return type(node)(walk(c) for c in node)
            raise TypeError(f"unknown cache node {type(node)}")

        return walk(cache_tree)


# ---------------------------------------------------------------------------
# ShardedFlat: the FlatParams bus (core/flat.py) partitioned over a mesh
# axis.  With a ShardedTreeSpec layout every device owns one contiguous
# BLOCK-padded segment, so the fused flat kernels (Eq. 1/2, Adam, EASGD —
# all elementwise over the bus) run PER SHARD under shard_map with no
# gather, and their results are bit-identical to the single-host flat pass
# at every shard count (tests/test_sharded_flat.py asserts this).
# ---------------------------------------------------------------------------

from jax.experimental.shard_map import shard_map  # noqa: E402


def flat_sharding(mesh: Mesh, axis: str = "pod") -> NamedSharding:
    """NamedSharding placing a 1-D flat buffer as contiguous per-device
    segments along ``axis`` (replicated over any other mesh axes)."""
    return NamedSharding(mesh, P(axis))


def _check_shardable(buf_len: int, mesh: Mesh, axis: str) -> int:
    a = int(mesh.shape[axis])
    if buf_len % a:
        raise ValueError(
            f"flat buffer of {buf_len} elements does not divide the "
            f"{a}-way mesh axis {axis!r}; lay it out with "
            f"flat.shard_spec/flatten_sharded(n_shards={a})")
    return a


def shard_flat(fp, mesh: Mesh, axis: Optional[str] = None):
    """Place a FlatParams' buffer on the mesh: each device gets its own
    contiguous segment.  ``axis`` defaults to the ShardedTreeSpec's axis."""
    from repro.core import flat as F
    if axis is None:
        axis = fp.spec.axis if isinstance(fp.spec, F.ShardedTreeSpec) \
            else "pod"
    _check_shardable(fp.buf.size, mesh, axis)
    return fp.with_buf(jax.device_put(fp.buf, flat_sharding(mesh, axis)))


def _weights_arr(weights) -> jnp.ndarray:
    if isinstance(weights, jnp.ndarray):
        return weights.astype(jnp.float32)
    return jnp.stack([jnp.asarray(w, jnp.float32).reshape(())
                      for w in weights])


def sharded_lerp_flat(server_buf, client_buf, alpha, mesh: Mesh,
                      axis: str = "pod", *, use_kernel: bool = False):
    """Eq. 1 per shard: every device lerps its own segment."""
    _check_shardable(server_buf.size, mesh, axis)
    a = jnp.asarray(alpha, jnp.float32)

    def local(s, c, a_):
        if use_kernel:
            from repro.kernels import ops as K
            return K.fused_lerp_flat(s, c, a_)
        from repro.kernels import ref as R
        return R.vc_asgd_lerp(s, c, a_)

    return shard_map(local, mesh=mesh, in_specs=(P(axis), P(axis), P()),
                     out_specs=P(axis), check_rep=False)(
        server_buf, client_buf, a)


def sharded_assimilate_flat(server_buf, clients_buf, weights, mesh: Mesh,
                            axis: str = "pod", *, use_kernel: bool = False):
    """Eq. 2 per shard: server [N] + clients [n, N] -> [N], each device
    reducing its own contiguous segment over all n client streams in
    arrival order — the same fold as kernels assimilate_flat, so the
    result is bit-identical to the single-host flat pass."""
    _check_shardable(server_buf.size, mesh, axis)
    n = int(clients_buf.shape[0])
    w = _weights_arr(weights)
    if w.shape[0] != n + 1:
        raise ValueError(f"need {n + 1} weights, got {w.shape[0]}")

    def local(w_, s, c):
        if use_kernel:
            from repro.kernels import ops as K
            return K.fused_assimilate_flat(s, c, [w_[i] for i in range(n + 1)])
        acc = w_[0] * s.astype(jnp.float32)
        for j in range(n):
            acc = acc + w_[j + 1] * c[j].astype(jnp.float32)
        return acc.astype(s.dtype)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(axis), P(None, axis)),
                     out_specs=P(axis), check_rep=False)(
        w, server_buf, clients_buf)


def sharded_adam_update_flat(p_buf, g_buf, m_buf, v_buf, lr, b1, b2, eps,
                             weight_decay, c1, c2, mesh: Mesh,
                             axis: str = "pod", *, use_kernel: bool = False):
    """Fused Adam per shard: each device updates the (p, m, v) lanes of its
    own segment — zero cross-device traffic (scalars are replicated)."""
    _check_shardable(p_buf.size, mesh, axis)
    # lr/c1/c2 may be traced (schedules, step-dependent bias correction);
    # b1/b2/eps/weight_decay are static hyperparameters and stay Python
    # floats (ref.adam_update branches on weight_decay's truthiness)
    scal = _weights_arr([lr, c1, c2])

    def local(sc, p, g, m, v):
        if use_kernel:
            from repro.kernels import ops as K
            return K.fused_adam_flat(p, g, m, v, sc[0], b1, b2, eps,
                                     weight_decay, sc[1], sc[2])
        from repro.kernels import ref as R
        return R.adam_update(p, g, m, v, lr=sc[0], b1=b1, b2=b2,
                             eps=eps, c1=sc[1], c2=sc[2],
                             weight_decay=weight_decay)

    blk = P(axis)
    return shard_map(local, mesh=mesh,
                     in_specs=(P(), blk, blk, blk, blk),
                     out_specs=(blk, blk, blk), check_rep=False)(
        scal, p_buf, g_buf, m_buf, v_buf)


def sharded_broadcast_flat(server_buf, n_pods: int, mesh: Mesh,
                           axis: str = "pod"):
    """Redistribution leg per shard: server [N] -> islands [n_pods, N]
    with every device broadcasting ONLY its own contiguous segment (no
    gather — the output stays sharded along ``axis`` on the bus dim).
    Values are plain copies, so the result is bit-identical to the
    single-host ``broadcast_to`` at every pod count."""
    _check_shardable(server_buf.size, mesh, axis)

    def local(s):
        return jnp.broadcast_to(s[None], (n_pods,) + s.shape)

    return shard_map(local, mesh=mesh, in_specs=(P(axis),),
                     out_specs=P(None, axis), check_rep=False)(server_buf)


def sharded_easgd_flat(center_buf, replicas_buf, beta, mesh: Mesh,
                       axis: str = "pod", *, use_kernel: bool = False):
    """Fused elastic EASGD round per shard: center [N] + replicas [n, N]
    updated segment-by-segment, no gather."""
    _check_shardable(center_buf.size, mesh, axis)
    b = jnp.asarray(beta, jnp.float32)

    def local(c, x, b_):
        if use_kernel:
            from repro.kernels import ops as K
            return K.fused_easgd_flat(c, x, b_)
        from repro.kernels import ref as R
        return R.easgd_elastic(c, x, b_)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis), P(None, axis), P()),
                     out_specs=(P(axis), P(None, axis)), check_rep=False)(
        center_buf, replicas_buf, b)


def ep_tune(cfg: ModelConfig, dp: int) -> ModelConfig:
    """Set moe.ep_virtual so n_experts * v divides the dp-way EP axis and
    the per-expert f dim splits evenly."""
    import dataclasses
    if cfg.moe is None:
        return cfg
    e, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
    v = 1
    while (e * v) % dp != 0 or f % v != 0:
        v += 1
        if v > dp:
            raise ValueError(f"no virtual factor for e={e}, f={f}, dp={dp}")
    return cfg.replace(moe=dataclasses.replace(cfg.moe, ep_virtual=v))


def _tup(x):
    if x is None:
        return ()
    return x if isinstance(x, tuple) else (x,)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _is_stacked(names) -> bool:
    return bool(names) and names[0].startswith("group")
