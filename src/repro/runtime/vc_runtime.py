"""VC-ASGD at pod scale (DESIGN.md §4): client islands = pods.

Each island holds its own full parameter/optimizer replica (leading ``pod``
dim, sharded over the pod mesh axis; inner dims follow the single-pod
MeshPlan).  One **VC round** =

  1. ``k`` local train steps per island — vmapped over the pod dim, so there
     is NO cross-pod collective inside the round (the paper's asynchronous,
     barrier-free client training),
  2. assimilation — Eq. 2 as a single weighted reduction over the pod axis,
     with a survivor mask: islands that died this round (preemption) simply
     get weight zero and the weights renormalize (fault tolerance is
     algebraic, not protocol-level),
  3. redistribution — the new server copy travels back over pods as a
     per-shard broadcast ON THE FLAT BUS (each device copies only its own
     contiguous segment under shard_map — no gather; the paper's clients
     always start a subtask from the server snapshot).  The protocol
     runtime ships the same segments as per-shard handout frames
     (wire.KIND_SHARD) through the Transport at lease issue.

The optional compressed path ships int8 top-k deltas with error feedback
(core/compression.py) instead of raw weights across the DCN — globally
sparsified over the whole model on the FlatParams bus (core/flat.py), one
compression + one accumulate per island.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import vc_asgd as V
from repro.models.registry import Model
from repro.optim import Adam, clip_by_global_norm
from repro.runtime.sharding import MeshPlan
from repro.transfer.transport import Transport


def island_weights(n_pods: int, alpha: float, survivors: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 2 weights [w_0..w_{n-1}] (island order = arrival order) with dead
    islands zeroed; returns (w_islands [n_pods], w_server scalar)."""
    j = jnp.arange(n_pods, dtype=jnp.float32)
    w = (1.0 - alpha) * alpha ** (n_pods - 1.0 - j)
    w = w * survivors.astype(jnp.float32)
    return w, 1.0 - w.sum()


def assimilate_flat(server_buf, islands_buf, w, w_s, *,
                    mesh=None, shard_axis=None, use_kernel: bool = False):
    """Eq. 2 over the FLAT bus with survivor masking: server [N] +
    islands [n_pods, N] -> [N].

    Select-before-multiply: a dead island may hold inf/nan (it crashed
    mid-step) and ``0 * inf`` would poison the server, so dead streams are
    zeroed BEFORE the weighted reduction.  The reduction itself is
    elementwise over the bus, so with ``mesh``/``shard_axis`` set it runs
    per contiguous shard segment under shard_map (runtime/sharding.py) —
    no gather, bit-identical to the single-host pass at every pod count.
    ``use_kernel=True`` routes the masked reduction through the fused
    single-launch Pallas kernel (kernels assimilate_flat)."""
    wi = w.reshape((-1, 1)).astype(jnp.float32)
    islands_buf = jnp.where(wi > 0.0, islands_buf.astype(jnp.float32), 0.0)
    if use_kernel:
        n = int(islands_buf.shape[0])
        weights = [w_s] + [w[j] for j in range(n)]
        if mesh is not None:
            from repro.runtime.sharding import sharded_assimilate_flat
            return sharded_assimilate_flat(server_buf, islands_buf, weights,
                                           mesh, shard_axis, use_kernel=True)
        from repro.kernels import ops as K
        return K.fused_assimilate_flat(server_buf, islands_buf, weights)

    # NOT routed through sharding.sharded_assimilate_flat's jnp form: that
    # helper folds client streams SEQUENTIALLY (the kernel's order), while
    # the retained per-leaf oracle (assimilate_islands_per_leaf) reduces
    # with jnp.sum over the pod axis — bit-exactness against the oracle
    # pins this reduction order, sharded and unsharded alike.
    def local(s, isl, w_, ws_):
        wj = w_.reshape((-1, 1)).astype(jnp.float32)
        contrib = jnp.sum(wj * isl.astype(jnp.float32), axis=0)
        return (ws_ * s.astype(jnp.float32) + contrib).astype(s.dtype)

    if mesh is None:
        return local(server_buf, islands_buf, w, w_s)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as SP
    return shard_map(local, mesh=mesh,
                     in_specs=(SP(shard_axis), SP(None, shard_axis),
                               SP(), SP()),
                     out_specs=SP(shard_axis), check_rep=False)(
        server_buf, islands_buf, w, jnp.asarray(w_s, jnp.float32))


def redistribute_flat(server_buf, n_pods: int, *, mesh=None,
                      shard_axis=None):
    """Step-3 redistribution on the bus: server [N] -> islands
    [n_pods, N] (every island restarts the next round from the server
    snapshot, §III).  With ``mesh``/``shard_axis`` set the broadcast runs
    per contiguous shard segment under shard_map
    (runtime/sharding.py::sharded_broadcast_flat) — each device copies
    only its own segment, no gather — and is bit-identical to the
    single-host broadcast at every pod count (the values are copies
    either way; tests pin it against the per-leaf oracle)."""
    if mesh is None:
        return jnp.broadcast_to(server_buf[None],
                                (n_pods,) + server_buf.shape)
    from repro.runtime.sharding import sharded_broadcast_flat
    return sharded_broadcast_flat(server_buf, n_pods, mesh, shard_axis)


def redistribute_per_leaf(server, islands):
    """Pre-download-leg reference: the per-leaf tree.map broadcast
    make_vc_round used before redistribution moved onto the flat bus.
    Retained as the bit-exactness oracle (tests/test_runtime_vc.py)."""
    return jax.tree.map(
        lambda s, isl: jnp.broadcast_to(s[None], isl.shape).astype(isl.dtype),
        server, islands)


def assimilate_islands_per_leaf(server, islands, w, w_s):
    """Pre-ShardedFlat reference: the per-leaf tree.map merge make_vc_round
    used before the assimilation moved onto the flat bus.  Retained as the
    bit-exactness oracle (tests/test_sharded_flat.py)."""
    n_pods = jax.tree.leaves(islands)[0].shape[0]

    def merge(s, isl):
        wi = w.reshape((n_pods,) + (1,) * (isl.ndim - 1)).astype(jnp.float32)
        contrib = jnp.sum(jnp.where(wi > 0.0,
                                    wi * isl.astype(jnp.float32), 0.0),
                          axis=0)
        return (w_s * s.astype(jnp.float32) + contrib).astype(s.dtype)

    return jax.tree.map(merge, server, islands)


def make_vc_round(model: Model, plan: MeshPlan, n_pods: int,
                  local_steps: int = 4, optimizer=None,
                  clip_norm: float = 1.0, pod_axis: str = "pod",
                  flat_shard_axis: Optional[str] = None,
                  use_kernel: bool = False):
    """Returns vc_round(server, islands, opts, batches, alpha, survivors)
    -> (server', islands', opts', metrics).

    islands/opts carry a leading [n_pods] dim; batches carry
    [n_pods, local_steps, ...].

    Assimilation rides the FLAT bus: the trained islands are flattened
    once into a [n_pods, padded] matrix, the server once onto the same
    layout, and Eq. 2 is ONE masked weighted reduction over contiguous
    buffers (``assimilate_flat``) instead of a per-leaf tree walk — the
    same code path as the simulator's schemes.  With ``flat_shard_axis``
    set (a mesh axis of ``plan.mesh``), the buffers are padded so every
    device owns a contiguous BLOCK-multiple segment and the reduction
    runs per shard under shard_map with no gather."""
    optimizer = optimizer or Adam(lr=3e-4)
    from repro.core import flat as F
    pad_to = F.BLOCK
    mesh = None
    if flat_shard_axis is not None:
        mesh = plan.mesh
        pad_to = F.BLOCK * int(mesh.shape[flat_shard_axis])

    def local_train(params, opt_state, steps_batch):
        """k local steps on one island (scan over steps)."""
        def step(carry, batch):
            p, o = carry
            (loss, _), grads = jax.value_and_grad(
                lambda pp: model.loss(pp, batch, plan=plan), has_aux=True)(p)
            grads, _ = clip_by_global_norm(grads, clip_norm)
            p, o = optimizer.update(grads, o, p)
            return (p, o), loss
        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), steps_batch)
        return params, opt_state, losses.mean()

    def vc_round(server, islands, opts, batches, alpha, survivors):
        # 1) island-local training, no cross-pod sync
        islands, opts, losses = jax.vmap(local_train)(islands, opts, batches)
        # 2) Eq. 2 assimilation on the flat bus: flatten at the boundary
        #    (once per round), reduce contiguous segments, zero leaf loops
        w, w_s = island_weights(n_pods, alpha, survivors)
        isl_buf, spec = F.flatten_batched(islands, pad_to=pad_to)
        s_buf = F.flatten_like(server, spec)
        out_buf = assimilate_flat(s_buf, isl_buf, w, w_s, mesh=mesh,
                                  shard_axis=flat_shard_axis,
                                  use_kernel=use_kernel)
        server = F.unflatten(F.FlatParams(out_buf, spec))
        # 3) redistribution on the bus: every island restarts from the
        #    server snapshot via a per-shard broadcast (sharded: each
        #    device copies only its own contiguous segment, no gather) —
        #    bit-identical to the retained per-leaf broadcast oracle
        isl_out = redistribute_flat(out_buf, n_pods, mesh=mesh,
                                    shard_axis=flat_shard_axis)
        islands = F.unflatten_batched(isl_out, spec)
        return server, islands, opts, {"loss": losses.mean()}

    return vc_round


def island_shardings(model: Model, plan: MeshPlan, n_pods: int,
                     optimizer, pod_axis: str = "pod"):
    """Shardings: server replicated over pod / sharded inner; islands carry a
    leading pod-sharded dim."""
    p_specs = model.param_specs()
    inner = plan.param_shardings(p_specs)

    def lift(ns: NamedSharding) -> NamedSharding:
        return NamedSharding(plan.mesh, P(pod_axis, *ns.spec))

    server_sh = inner
    island_sh = jax.tree.map(lift, inner)
    opt_specs = jax.eval_shape(optimizer.init, p_specs)
    from repro.optim import OptState
    opt_sh = OptState(step=NamedSharding(plan.mesh, P(pod_axis)),
                      m=jax.tree.map(lift, inner),
                      v=jax.tree.map(lift, inner))
    return server_sh, island_sh, opt_sh


def compressed_assimilate(server, islands, alpha, survivors, *,
                          density: float = 0.05, residuals=None,
                          transport: Optional["Transport"] = None):
    """Delta-form Eq. 2 with GLOBAL (whole-model) top-k + int8 compression
    and error feedback — what actually crosses the DCN between pods.

    Flat-bus path (core/flat.py): the server and every island are flattened
    once, each island ships ONE globally-sparsified delta buffer (k chosen
    over the whole model, not per leaf — strictly no worse mass retention
    at equal density), and the weighted Eq. 2 reduction happens on the
    contiguous buffer.  One compression + one accumulate per island instead
    of the per-leaf × per-island loop.  Returns (server', residuals') with
    the same tree-in/tree-out contract as before (residuals island-major).

    With ``transport`` set (any transfer/transport.py ``Transport`` —
    the in-memory loopback or the cross-process broker), each island's
    payload really crosses the wire: encoded to bytes (wire format v1),
    sent, received and decoded before assimilation — the transport's
    stats then hold the REAL per-round transfer sizes.  (Host-level
    path: call it eagerly, not under jit.)"""
    from repro.core import compression as C
    from repro.core import flat as F
    n = islands_leading_dim(islands)
    w, w_s = island_weights(n, alpha, survivors)

    fp = F.flatten(server)
    isl_buf, spec = F.flatten_batched(islands)
    if spec.shapes != fp.spec.shapes:
        raise ValueError("island layout does not match server layout")
    res_buf = (F.flatten_batched(residuals)[0] if residuals is not None
               else None)

    s32 = fp.buf
    out = w_s * s32
    new_res = []
    for j in range(n):
        delta = isl_buf[j] - s32
        payload, r = C.compress_flat(
            delta, density=density, logical_n=spec.n,
            residual=None if res_buf is None else res_buf[j])
        if transport is not None:
            from repro.transfer import wire
            mid = transport.send(wire.encode_sparse(
                payload, residual_norm=float(jnp.linalg.norm(r))))
            payload = wire.decode(transport.recv(mid)).payload
        deq = C.decompress_flat(payload)
        out = out + w[j] * (s32 + deq)
        new_res.append(r)
    server_out = F.unflatten(fp.with_buf(out))
    # residuals carry in f32 (like the per-leaf reference): truncating the
    # error-feedback carry to the params' storage dtype would lose it
    residuals_out = F.unflatten_batched(jnp.stack(new_res), spec,
                                        dtype=jnp.float32)
    return server_out, residuals_out


def compressed_assimilate_per_leaf(server, islands, alpha, survivors, *,
                                   density: float = 0.05, residuals=None):
    """TEST/BENCH ORACLE ONLY (retired from every runtime path): per-leaf
    top-k in a per-leaf × per-island Python loop.  Kept as the
    numerical/perf baseline for the flat path (tests/test_flat.py,
    benchmarks/kernel_bench.py::bench_flat_assimilate); compresses worse
    than the global top-k at equal density."""
    from repro.core import compression as C
    n = islands_leading_dim(islands)
    w, w_s = island_weights(n, alpha, survivors)

    def one_leaf(s, isl, res):
        s32 = s.astype(jnp.float32)
        out = w_s * s32
        new_res = []
        for j in range(n):
            delta = isl[j].astype(jnp.float32) - s32
            if res is not None:
                delta = delta + res[j]
            payload, r = C.compress_delta(delta, density=density)
            deq = C.decompress_delta(payload)
            out = out + w[j] * (s32 + deq)
            new_res.append(r)
        return out.astype(s.dtype), jnp.stack(new_res)

    flat_s, tdef = jax.tree.flatten(server)
    flat_i = jax.tree.leaves(islands)
    flat_r = (jax.tree.leaves(residuals) if residuals is not None
              else [None] * len(flat_s))
    merged, residuals_out = [], []
    for s, isl, r in zip(flat_s, flat_i, flat_r):
        m, nr = one_leaf(s, isl, r)
        merged.append(m)
        residuals_out.append(nr)
    return jax.tree.unflatten(tdef, merged), jax.tree.unflatten(tdef, residuals_out)


def islands_leading_dim(islands) -> int:
    return jax.tree.leaves(islands)[0].shape[0]
