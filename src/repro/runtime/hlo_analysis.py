"""Compiled-HLO analysis for the roofline report (DESIGN.md §7).

``compiled.cost_analysis()`` on this jaxlib counts while-loop (lax.scan)
bodies ONCE, so we parse the post-SPMD HLO text ourselves:

* per-computation op lists with shapes (local, per-device — the module is
  already partitioned),
* while-loop trip counts (scan bounds appear as integer constants in the
  loop condition),
* a call-graph multiplier pass (ENTRY x1; while bodies x trips; fusion /
  call computations inherit the caller's multiplier),
* dot FLOPs (2 * prod(result) * prod(contracting)),
* collective wire bytes with standard ring factors (all-reduce 2x result,
  all-gather result, reduce-scatter operand, all-to-all / permute result),
* an HBM-traffic proxy: operand + result bytes of top-level fusions / dots /
  parameters (fusion boundaries approximate HBM round-trips on TPU).

All numbers are PER DEVICE (post-partitioning shapes) per step.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # operand list + attributes (raw)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    by_name: Dict[str, Op] = field(default_factory=dict)


@dataclass
class HLOCost:
    dot_flops: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    hbm_bytes: float = 0.0         # essential traffic (see analyze_hlo_text)
    hbm_strict: float = 0.0        # everything incl. fusion IO (upper bound)
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def parse_computations(txt: str) -> Dict[str, Computation]:
    """Computation headers are non-indented lines ending in '{' containing
    '->'; ops are indented '  %name = TYPE opcode(...)' lines."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if cur is None:
            if (line and not line[0].isspace() and line.rstrip().endswith("{")
                    and "->" in line):
                m = _COMP_RE.match(line.replace("ENTRY ", "").lstrip())
                if m:
                    cur = Computation(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(_COMMENT_RE.sub("", line))
        if m:
            op = Op(name=m.group(1), type_str=m.group(2).strip(),
                    opcode=m.group(3), rest=m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are before the closing paren of the op call
    depth, out, cur = 1, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    arglist = "".join(cur)
    return [a.strip().lstrip("%") for a in arglist.split(",") if a.strip()]


def _while_trip(cond: Computation) -> int:
    """lax.scan conditions compare the counter against the length constant;
    take the largest integer constant in the condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    _, rdims = shape_dims(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    names = _operand_names(op.rest)
    lhs_dims: List[int] = []
    if names:
        lhs_op = comp.by_name.get(names[0])
        if lhs_op is not None:
            _, lhs_dims = shape_dims(lhs_op.type_str)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
    res = 1
    for d in rdims:
        res *= d
    return 2.0 * res * contract


def _collective_bytes(op: Op, comp: Computation) -> float:
    b_res = _effective_collective_size(op, comp)
    if op.opcode.startswith("all-reduce"):
        return 2.0 * b_res
    if op.opcode.startswith("all-gather"):
        return float(b_res)
    if op.opcode.startswith("reduce-scatter"):
        names = _operand_names(op.rest)
        if names and names[0] in comp.by_name:
            return float(shape_bytes(comp.by_name[names[0]].type_str))
        return float(b_res)
    return float(b_res)       # all-to-all, collective-permute


def _effective_collective_size(op: Op, comp: Computation) -> float:
    """Collective payload at the dtype a TPU would move: the CPU backend
    upcasts bf16 matmul outputs to f32 before the psum and converts back
    after — if every consumer of this collective is a narrowing convert (or
    a convert-prefixed fusion), count the narrow width."""
    b = float(shape_bytes(op.type_str))
    consumers = [c for c in comp.ops if op.name in _operand_names(c.rest)]
    if consumers:
        conv = [c for c in consumers
                if c.opcode == "convert" or (c.opcode == "fusion" and
                                             c.name.startswith("convert"))]
        if len(conv) == len(consumers):
            smallest = min(shape_bytes(c.type_str) for c in conv)
            if 0 < smallest < b:
                return float(smallest)
    # also follow the operand side: converted right before the collective
    names = _operand_names(op.rest)
    if names and names[0] in comp.by_name:
        src = comp.by_name[names[0]]
        if src.opcode == "convert" or (src.opcode == "fusion" and
                                       src.name.startswith("convert")):
            inner = _operand_names(src.rest)
            if inner and inner[0] in comp.by_name:
                sb = shape_bytes(comp.by_name[inner[0]].type_str)
                if 0 < sb < b:
                    return float(sb)
    return b


_HBM_OPS = ("fusion", "dot", "convolution", "custom-call", "concatenate",
            "gather", "scatter", "sort", "reduce", "transpose", "copy",
            "dynamic-update-slice", "dynamic-slice", "iota", "broadcast",
            "reduce-window", "select-and-scatter", "cholesky",
            "triangular-solve", "rng", "pad", "reverse", "slice")

# "essential" traffic: ops whose operand/result movement survives on a TPU
# (fusion-friendly elementwise / convert / copy chains are assumed folded
# into their producers by Mosaic/XLA-TPU; f32 upcast wrappers that the CPU
# backend inserts around bf16 dots are counted at their bf16 source width)
_ESSENTIAL_OPS = ("dot", "convolution", "custom-call", "concatenate",
                  "gather", "scatter", "sort", "dynamic-update-slice",
                  "dynamic-slice", "reduce-window", "slice")


def _effective_operand_bytes(on: str, comp: Computation) -> float:
    """Operand bytes at the dtype the TPU would actually stream: follow one
    level of convert/copy/bitcast (CPU inserts f32 upcasts around bf16
    dots)."""
    op = comp.by_name.get(on)
    if op is None:
        return 0.0
    b = shape_bytes(op.type_str)
    if op.opcode in ("convert", "copy", "bitcast") or (
            op.opcode == "fusion" and op.name.startswith(
                ("convert", "copy", "bitcast"))):
        srcs = _operand_names(op.rest)
        if srcs and srcs[0] in comp.by_name:
            return min(b, shape_bytes(comp.by_name[srcs[0]].type_str))
    return b


def _essential_bytes(op: Op, comp: Computation) -> float:
    if op.opcode in ("dynamic-slice", "slice"):
        return 2.0 * shape_bytes(op.type_str)
    if op.opcode == "dynamic-update-slice":
        names = _operand_names(op.rest)
        upd = (shape_bytes(comp.by_name[names[1]].type_str)
               if len(names) > 1 and names[1] in comp.by_name else 0)
        return 2.0 * upd
    total = float(shape_bytes(op.type_str))
    for on in _operand_names(op.rest)[:8]:
        total += _effective_operand_bytes(on, comp)
    return total


def analyze_hlo_text(txt: str) -> HLOCost:
    comps = parse_computations(txt)
    cost = HLOCost()

    # ---- multiplier pass over the call graph --------------------------
    mult: Dict[str, float] = defaultdict(float)
    mains = [n for n in comps if n.startswith("main")]
    if mains:
        entry = mains[0]
    else:
        referenced = set()
        for c in comps.values():
            for op in c.ops:
                for m in re.finditer(
                        r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)",
                        op.rest):
                    referenced.add(m.group(1))
        roots = [n for n in comps if n not in referenced]
        entry = roots[0] if roots else next(iter(comps))

    stack = [(entry, 1.0)]
    seen = set()
    while stack:
        name, m0 = stack.pop()
        if name not in comps:
            continue
        mult[name] += m0
        comp = comps[name]
        for op in comp.ops:
            if op.opcode == "while":
                mm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mt = _TRIP_RE.search(op.rest)          # XLA annotates the
                if mt:                                  # known trip count
                    trips = int(mt.group(1))
                elif mm and mm.group(1) in comps:
                    trips = _while_trip(comps[mm.group(1)])
                else:
                    trips = 1
                cost.while_trips[mb.group(1) if mb else name] = trips
                if mb:
                    stack.append((mb.group(1), m0 * trips))
                if mm:
                    stack.append((mm.group(1), m0 * trips))
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     op.rest):
                    stack.append((m.group(1), m0))
                # conditionals (lax.cond): every branch is charged at the
                # caller's multiplier — only one runs, so this is a
                # conservative upper bound on bytes/FLOPs, which is the
                # right direction for a perf-regression gate denominator
                mb = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if mb:
                    for b in mb.group(1).split(","):
                        stack.append((b.strip().lstrip("%"), m0))
                for m in re.finditer(
                        r"(?:true|false)_computation=%?([\w\.\-]+)", op.rest):
                    stack.append((m.group(1), m0))

    # ---- accumulate ----------------------------------------------------
    for name, comp in comps.items():
        k = mult.get(name, 0.0)
        if k == 0.0:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                cost.dot_flops += k * _dot_flops(op, comp)
            for coll in COLLECTIVES:
                if op.opcode == coll or op.opcode == coll + "-start":
                    b = _collective_bytes(op, comp)
                    cost.collective_bytes[coll] += k * b
                    cost.collective_count[coll] += int(k)
            if op.opcode in _HBM_OPS and not name.startswith(
                    ("fused", "wrapped")):
                b = _hbm_op_bytes(op, comp, comps)
                cost.hbm_strict += k * b
                if op.opcode in _ESSENTIAL_OPS:
                    cost.hbm_bytes += k * _essential_bytes(op, comp)
            for coll in COLLECTIVES:
                if op.opcode.startswith(coll):
                    cost.hbm_bytes += k * shape_bytes(op.type_str)
                    break
    return cost


def _hbm_op_bytes(op: Op, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """HBM-traffic estimate for one top-level op.

    In-place patterns (dynamic-update-slice on a scan carry, fusions that
    merely dynamic-slice out of a big carried buffer) count only the slice
    actually touched — otherwise a 24-iteration scan appears to rewrite its
    6 GiB residual stack every step."""
    res = shape_bytes(op.type_str)
    names = _operand_names(op.rest)[:12]
    operands = [(on, shape_bytes(comp.by_name[on].type_str))
                for on in names if on in comp.by_name]
    if op.opcode in ("broadcast", "iota"):
        return float(res)
    if op.opcode in ("dynamic-slice", "slice"):
        return 2.0 * res                       # read + write of the slice
    if op.opcode == "dynamic-update-slice":
        upd = operands[1][1] if len(operands) > 1 else 0
        return 2.0 * upd                       # slice-sized read + write
    if op.opcode == "fusion":
        return _fusion_bytes(op, operands, res, comps)
    return float(res + sum(b for _, b in operands))


def _fusion_bytes(op: Op, operands, res: float,
                  comps: Dict[str, Computation]) -> float:
    """Look inside the fused computation: a parameter consumed only by
    (dynamic-)slice/gather ops is read slice-by-slice, not wholesale; a
    dynamic-update-slice root writes its update, not the whole buffer."""
    m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
    fc = comps.get(m.group(1)) if m else None
    if fc is None:
        return float(res + sum(b for _, b in operands))
    # map parameter index -> parameter op name
    pnames: Dict[int, str] = {}
    for fop in fc.ops:
        if fop.opcode == "parameter":
            pm = re.match(r"(\d+)", fop.rest)
            if pm:
                pnames[int(pm.group(1))] = fop.name
    total = 0.0
    for i, (_, ob) in enumerate(operands):
        pname = pnames.get(i)
        if pname is None:
            total += ob
            continue
        consumers = [fop for fop in fc.ops
                     if pname in _operand_names(fop.rest)]
        if consumers and all(c.opcode in ("dynamic-slice", "slice", "gather",
                                          "bitcast", "dynamic-update-slice")
                             for c in consumers):
            eff = 0.0
            for c in consumers:
                if c.opcode == "dynamic-update-slice":
                    # reading the buffer only to update in place: no read
                    continue
                eff += shape_bytes(c.type_str)
            total += min(ob, eff)
        else:
            total += ob
    # write side: DUS root writes only the update slice
    root = fc.ops[-1] if fc.ops else None
    if root is not None and root.opcode == "dynamic-update-slice":
        upd_names = _operand_names(root.rest)
        upd = (shape_bytes(fc.by_name[upd_names[1]].type_str)
               if len(upd_names) > 1 and upd_names[1] in fc.by_name else 0)
        total += upd
    else:
        total += res
    return total


# ---------------------------------------------------------------------------
# roofline terms (hardware constants fixed by the task spec: TPU v5e-like)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per chip, one direction class)


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(per_device_flops: float, per_device_hbm: float,
                   per_device_coll: float) -> Roofline:
    return Roofline(
        compute_s=per_device_flops / PEAK_FLOPS,
        memory_s=per_device_hbm / HBM_BW,
        collective_s=per_device_coll / ICI_BW,
        flops=per_device_flops, hbm_bytes=per_device_hbm,
        coll_bytes=per_device_coll)


# ---------------------------------------------------------------------------
# per-kernel roofline profiles (the compression hot-path CI gate)
#
# For one jittable kernel entry point: compile, parse the optimized HLO for
# essential bytes/FLOPs, time it, and relate the HLO traffic to a
# hand-derived ANALYTIC minimum (the bytes the algorithm must move: e.g. one
# streaming read of the input for a selection pass).  Two derived numbers
# feed the gate (benchmarks/roofline_report.py):
#
#   traffic_fraction = analytic_bytes / hlo_bytes — deterministic on a
#     pinned jaxlib: a kernel change that moves extra bytes (a fused pass
#     breaking apart, a duplicated buffer) lowers the fraction and trips
#     the ratchet regardless of machine noise,
#   achieved_bw      = hlo_bytes / measured_s — the measured leg; gated
#     only by a loose floor so wall-clock noise cannot flake CI, but an
#     order-of-magnitude slowdown still fails.
# ---------------------------------------------------------------------------


@dataclass
class KernelProfile:
    name: str
    analytic_bytes: float        # hand-derived minimum traffic (bytes)
    hlo_bytes: float             # essential HBM bytes from the compiled HLO
    hlo_flops: float             # dot FLOPs from the compiled HLO
    measured_s: float            # wall-clock per call (median of iters)
    @property
    def traffic_fraction(self) -> float:
        return self.analytic_bytes / max(self.hlo_bytes, 1.0)

    @property
    def achieved_bw(self) -> float:
        return self.hlo_bytes / max(self.measured_s, 1e-12)

    def as_dict(self) -> Dict[str, float]:
        return {"analytic_bytes": self.analytic_bytes,
                "hlo_bytes": self.hlo_bytes,
                "hlo_flops": self.hlo_flops,
                "measured_s": self.measured_s,
                "traffic_fraction": self.traffic_fraction,
                "achieved_gbps": self.achieved_bw / 1e9}


def kernel_hlo_cost(fn, *args) -> HLOCost:
    """Essential-traffic analysis of one jitted kernel's optimized HLO."""
    import jax
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo_text(txt)


def profile_kernel(name: str, fn, args, analytic_bytes: float,
                   iters: int = 5) -> KernelProfile:
    """Compile + analyze + time one kernel entry point."""
    import time

    import jax
    jf = jax.jit(fn)
    cost = analyze_hlo_text(jf.lower(*args).compile().as_text())
    # strict bytes (fusion operand/result IO included): for a single kernel
    # every fusion boundary IS a memory round-trip, which is exactly what
    # the traffic gate must see — the `essential` filter is for whole-model
    # projections where elementwise chains fold into neighbouring matmuls.
    hlo_bytes = cost.hbm_strict
    jax.block_until_ready(jf(*args))          # warmup (compile + first run)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jf(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return KernelProfile(name=name, analytic_bytes=float(analytic_bytes),
                         hlo_bytes=hlo_bytes, hlo_flops=cost.dot_flops,
                         measured_s=times[len(times) // 2])
