"""Distributed train/serve step builders (jit + GSPMD).

``make_train_step`` returns a jitted (params, opt_state, batch) ->
(params, opt_state, metrics) with full in/out shardings derived from the
MeshPlan; ``make_prefill_step`` / ``make_decode_step`` build the serving
steps.  These are exactly what launch/dryrun.py lowers for every
(architecture x shape x mesh) cell.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.registry import Model, build_model
from repro.optim import Adam, clip_by_global_norm
from repro.runtime.sharding import MeshPlan


def make_train_step(model: Model, plan: MeshPlan, optimizer=None,
                    clip_norm: float = 1.0, remat: bool = True,
                    accum: int = 1):
    """accum > 1: the batch carries a leading microbatch dim
    [accum, b/accum, ...]; gradients are accumulated over a scan (bounds the
    activation working set — the standard memory/throughput knob)."""
    optimizer = optimizer or Adam(lr=3e-4)

    def grads_of(params, mbatch):
        def loss_fn(p):
            loss, metrics = model.loss(p, mbatch, plan=plan, remat=remat)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def mb_step(g_acc, mbatch):
                (loss, metrics), g = grads_of(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, (loss, metrics)

            grads, (losses, ms) = jax.lax.scan(mb_step, g0, batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out_metrics

    return train_step


def make_flat_train_step(loss_fn, optimizer, *, use_kernel: bool = False,
                         mesh=None, shard_axis: str = "pod"):
    """Train step with params AND optimizer state on the flat bus
    (core/flat.py): (FlatParams, FlatOptState, batch) ->
    (FlatParams', FlatOptState', loss).

    The gradient arrives flat for free: ``loss_fn(tree, batch)`` is
    differentiated w.r.t. the BUFFER (the unflatten happens inside
    autodiff), so d(loss)/d(buf) is already the gradient lane — no
    per-leaf gradient flattening, and the padding tail's gradient is
    exactly zero.  ``Adam.update_flat`` then updates all three lanes in
    one pass (a single Pallas launch with ``use_kernel=True``).  This is
    the step the preemption-resume harness
    (core/simulator.py::run_preemptible_training) checkpoints and
    restores as one contiguous record.

    Mesh-aware: with ``mesh`` set, the (p, g, m, v) lanes are constrained
    to contiguous per-device segments along ``shard_axis`` (lay the bus
    out with flat.flatten_sharded / ShardedTreeSpec so the length
    divides) and the fused Adam update runs PER SHARD under shard_map —
    no gather, bit-identical to the single-host flat pass."""
    from repro.core import flat as F

    def step(fp, fos, batch):
        def flat_loss(buf):
            return loss_fn(F.unflatten(fp.with_buf(buf)), batch)

        loss, gbuf = jax.value_and_grad(flat_loss)(fp.buf)
        if mesh is not None:
            from repro.runtime.sharding import flat_sharding
            gbuf = jax.lax.with_sharding_constraint(
                gbuf, flat_sharding(mesh, shard_axis))
            new_fp, new_fos = optimizer.update_flat_sharded(
                gbuf, fos, fp, mesh=mesh, axis=shard_axis,
                use_kernel=use_kernel)
        else:
            new_fp, new_fos = optimizer.update_flat(gbuf, fos, fp,
                                                    use_kernel=use_kernel)
        return new_fp, new_fos, loss

    return jax.jit(step)


def microbatch_specs(batch_specs, accum: int):
    """[b, ...] ShapeDtypeStructs -> [accum, b/accum, ...]."""
    def split(s):
        assert s.shape[0] % accum == 0, (s.shape, accum)
        return jax.ShapeDtypeStruct((accum, s.shape[0] // accum,
                                     *s.shape[1:]), s.dtype)
    return jax.tree.map(split, batch_specs)


def shardings_for_train(model: Model, plan: MeshPlan, optimizer, batch_specs,
                        accum: int = 1):
    """(in_shardings, out_shardings) for jit(train_step)."""
    p_specs = model.param_specs()
    p_sh = plan.param_shardings(p_specs)
    opt_specs = jax.eval_shape(optimizer.init, p_specs)
    o_sh = _opt_shardings(opt_specs, p_sh, plan)
    b_sh = plan.batch_shardings(batch_specs, lead_dims=1 if accum > 1 else 0)
    rep = NamedSharding(plan.mesh, P())
    m_sh = {"loss": rep, "grad_norm": rep, "ce": rep, "aux": rep}
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh)


def _opt_shardings(opt_specs, param_shardings, plan: MeshPlan):
    """m/v mirror the parameter shardings; step is replicated."""
    rep = NamedSharding(plan.mesh, P())

    def walk(spec_node, sh_node):
        return jax.tree.map(lambda s, sh: sh, spec_node, sh_node)

    from repro.optim import OptState
    return OptState(step=rep,
                    m=(walk(opt_specs.m, param_shardings)
                       if opt_specs.m is not None else None),
                    v=(walk(opt_specs.v, param_shardings)
                       if opt_specs.v is not None else None))


def make_prefill_step(model: Model, plan: MeshPlan):
    def prefill_step(params, batch):
        return model.prefill(params, batch, plan=plan)
    return prefill_step


def make_decode_step(model: Model, plan: MeshPlan):
    def decode_step(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos, plan=plan)
    return decode_step


def shardings_for_decode(model: Model, plan: MeshPlan, cache_specs,
                         batch: int):
    p_sh = plan.param_shardings(model.param_specs())
    c_sh = plan.cache_shardings(cache_specs)
    tok_sh = NamedSharding(plan.mesh, plan._fit(P(plan.data_axis), (batch,)))
    pos_sh = NamedSharding(plan.mesh, P())
    vp = padded_vocab_of(model)
    lg_sh = NamedSharding(plan.mesh,
                          plan._fit(plan.act_spec("dec_logits"), (batch, vp)))
    return (p_sh, c_sh, tok_sh, pos_sh), (lg_sh, c_sh)


def padded_vocab_of(model: Model) -> int:
    from repro.models.layers import padded_vocab
    return padded_vocab(model.cfg)


def shardings_for_prefill(model: Model, plan: MeshPlan, batch_specs, cache_specs):
    p_sh = plan.param_shardings(model.param_specs())
    b_sh = plan.batch_shardings(batch_specs)
    c_sh = plan.cache_shardings(cache_specs)
    lg_sh = NamedSharding(plan.mesh, plan.act_spec("dec_logits"))
    return (p_sh, b_sh), (lg_sh, c_sh)
