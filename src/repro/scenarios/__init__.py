"""Fleet-scale scenario registry (see registry.py) + the probe task."""
from repro.scenarios.probe import ProbeTask, make_probe_data  # noqa: F401
from repro.scenarios.registry import SCENARIOS, Scenario, get  # noqa: F401
