"""Fleet-probe task: the cheapest task that still drives the FULL protocol.

Fleet-scale simulations (10k-100k clients) measure the *system* — event
throughput, wire bytes, preemption churn, delta-handout behaviour — not
learning curves.  Real JAX training at that scale would melt the clock
for no extra information, so ``ProbeTask`` keeps the whole pipeline
(flat bus, leases, wire frames on both legs, scheme assimilation) while
replacing the client-side gradient computation with a deterministic
O(dim) numpy nudge and the validation pass with a closed-form progress
proxy.  Every byte on the wire is still real: the handout and upload
frames are encoded/decoded/CRC'd exactly like the MLP task's.

The parameter bus is ONE leaf, and ``ProbeTask`` speaks the simulator's
**flat task protocol** (``init_params_flat`` / ``client_train_flat`` /
``evaluate_flat``): the whole run stays on a numpy-backed flat bus, so
the per-event hot path never crosses the tree<->bus boundary and never
pays a JAX dispatch.  The tree-form methods remain as the reference
semantics — the flat forms are bit-identical to tree-train +
``flatten_like`` (the fleet fingerprints in benchmarks/fleet_bench.py
pin this).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.core.tasks import TaskData


def make_probe_data(n_shards: int, seed: int = 0) -> TaskData:
    """One sample per shard: the simulator's shard slicing stays O(1) and
    the arrays stay tiny at 100k+ shards (the probe ignores the values)."""
    n = max(int(n_shards), 1)
    x = np.zeros((n, 1), np.float32)
    y = np.zeros((n,), np.int32)
    return TaskData(x_train=x, y_train=y,
                    x_val=x[:1], y_val=y[:1])


class ProbeTask:
    """Single-leaf surrogate task for fleet-scale simulator runs.

    * ``client_train`` adds a seed-deterministic one-hot nudge — O(dim)
      numpy, no JAX dispatch, bit-reproducible across runs.
    * ``evaluate`` maps the parameter norm through a saturating curve, so
      scenario accuracy traces are monotone-ish in assimilated work and
      deterministic, without a validation forward pass.
    """

    def __init__(self, dim: int = 256, lr: float = 0.05):
        self.dim = int(dim)
        self.lr = float(lr)
        self.batch = 1                        # simulator sizes steps off this

    def init_params(self, key):
        del key                               # deterministic zero start
        return {"w": jnp.zeros((self.dim,), jnp.float32)}

    def client_train(self, params, x, y, *, steps: int, seed: int):
        del x, y
        w = np.array(params["w"], np.float32, copy=True)
        # Knuth-hash the seed into a slot + sign: cheap, collision-spread
        h = (int(seed) * 2654435761) & 0xFFFFFFFF
        idx = h % self.dim
        sign = 1.0 if (h >> 16) & 1 else -1.0
        w[idx] += self.lr * sign * float(max(1, steps))
        return {"w": w}

    def evaluate(self, params, x, y) -> float:
        del x, y
        norm = float(np.linalg.norm(np.asarray(params["w"])))
        return 1.0 - math.exp(-0.25 * norm)

    # -- flat task protocol (core/simulator.py) -----------------------------
    # Same math as the tree forms above, directly on the flat bus: the
    # buffers these return are byte-identical to tree-train+flatten_like
    # (the bus padding is zeros and stays zeros), so a simulator run is
    # bit-identical whichever path it takes — just without per-event JAX
    # dispatch.

    def init_params_flat(self, key, n_shards: int = 1) -> F.FlatParams:
        del key
        tree = {"w": np.zeros((self.dim,), np.float32)}
        spec = (F.sharded_tree_spec(tree, n_shards) if n_shards > 1
                else F.tree_spec(tree))
        return F.FlatParams(np.zeros((spec.padded,), np.float32), spec)

    def client_train_flat(self, base: F.FlatParams, x, y,
                          *, steps: int, seed: int) -> np.ndarray:
        del x, y
        buf = np.array(base.buf, np.float32, copy=True)
        h = (int(seed) * 2654435761) & 0xFFFFFFFF
        idx = h % self.dim
        sign = 1.0 if (h >> 16) & 1 else -1.0
        buf[base.spec.offsets[0] + idx] += self.lr * sign * float(max(1, steps))
        return buf

    def evaluate_flat(self, fp: F.FlatParams, x, y) -> float:
        del x, y
        off = fp.spec.offsets[0]
        w = np.asarray(fp.buf)[off:off + self.dim]
        return 1.0 - math.exp(-0.25 * float(np.linalg.norm(w)))
