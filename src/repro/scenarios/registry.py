"""Fleet-scale scenario registry: named, fixed (cfg, fleet, scheme)
bundles the benchmarks, the CI smoke gate, the profiler, and the pinned
fleet regression cases all run THE SAME WAY.

Every scenario is deterministic in its seed.  The fleet-size scenarios
(``fleet_1k/10k/100k``) use the ``ProbeTask`` surrogate (real protocol +
wire bytes, O(dim) client compute) so the measurement is the event loop,
not JAX; the behaviour scenarios (``az_reclaim``, ``spot_price``,
``diurnal``, ``tiered``) open the preemption-model space stubbed by
core/preemption.py — ``az_reclaim`` runs a SHARDED parameter bus so the
thundering-herd mass re-download exercises the version-vector delta
ledger end to end.

Run one from the CLI::

    PYTHONPATH=src python -m repro.scenarios.registry --scenario fleet_1k
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.preemption import (PAPER_FLEET, CorrelatedReclaimModel,
                                   DiurnalChurnModel, LatencyModel,
                                   PreemptionModel, SpotPricePreemption,
                                   make_fleet)
from repro.core.simulator import SimConfig, SimResult, run_simulation
from repro.scenarios.probe import ProbeTask, make_probe_data


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    cfg_kwargs: dict
    # builds the fleet off cfg (None = the simulator's default path)
    fleet_fn: Optional[Callable] = None
    vc_beta: float = 0.95                # VC-ASGD averaging weight
    # ProbeTask constructor kwargs (e.g. a wider bus for the handout-
    # serving scenarios, so updates spread across several chunks)
    task_kwargs: Optional[dict] = None

    def config(self, **overrides) -> SimConfig:
        """Build the SimConfig; ``overrides`` lets a benchmark re-run
        the SAME scenario with one knob turned (e.g. handout_dtype)."""
        return SimConfig(fleet_fn=self.fleet_fn,
                         **{**self.cfg_kwargs, **overrides})

    def run(self, **overrides) -> SimResult:
        from repro.core.baselines import VCASGD
        cfg = self.config(**overrides)
        task = ProbeTask(**(self.task_kwargs or {}))
        data = make_probe_data(cfg.n_shards, seed=cfg.seed)
        return run_simulation(task, data, VCASGD(self.vc_beta), cfg)


# ---- fleet builders (cfg -> list[ClientModel]) ------------------------------

def _az_reclaim_fleet(cfg: SimConfig):
    model = CorrelatedReclaimModel(
        mean_lifetime_s=cfg.mean_lifetime_s,
        restart_delay_s=cfg.restart_delay_s,
        enabled=cfg.preemptible,
        az_reclaim_interval_s=4 * 3600.0, n_az=3, reclaim_seed=cfg.seed)
    return make_fleet(cfg.n_clients, seed=cfg.seed, preemption=model,
                      n_az=3)


def _spot_price_fleet(cfg: SimConfig):
    model = SpotPricePreemption(
        mean_lifetime_s=cfg.mean_lifetime_s,
        restart_delay_s=cfg.restart_delay_s,
        enabled=cfg.preemptible,
        bid=0.95, n_az=3, price_seed=cfg.seed)
    return make_fleet(cfg.n_clients, seed=cfg.seed, preemption=model,
                      n_az=3)


def _diurnal_fleet(cfg: SimConfig):
    model = DiurnalChurnModel(
        mean_lifetime_s=cfg.mean_lifetime_s,
        restart_delay_s=cfg.restart_delay_s,
        enabled=cfg.preemptible, n_regions=4)
    return make_fleet(cfg.n_clients, seed=cfg.seed, preemption=model,
                      n_az=4)


def _tiered_fleet(cfg: SimConfig):
    # fast/medium/slow compute+bandwidth mix (weights sum to 1)
    tiers = [(PAPER_FLEET[3], 0.2),      # c5a.4xlarge: 2.3x speed
             (PAPER_FLEET[4], 0.5),      # m5.2xlarge: reference
             (PAPER_FLEET[2], 0.3)]      # c5a.2xlarge: 1.2x, 2 Gbps
    model = PreemptionModel(mean_lifetime_s=cfg.mean_lifetime_s,
                            restart_delay_s=cfg.restart_delay_s,
                            enabled=cfg.preemptible)
    return make_fleet(cfg.n_clients, seed=cfg.seed, preemption=model,
                      tiers=tiers)


# ---- the registry -----------------------------------------------------------
# NOTE: fleet_1k / fleet_10k are ALSO the pre-PR baseline measurement
# configs embedded in results/BENCH_fleet.json — changing them invalidates
# the recorded pre/post comparison.

SCENARIOS: Dict[str, Scenario] = {}


def _reg(s: Scenario) -> Scenario:
    SCENARIOS[s.name] = s
    return s


_reg(Scenario(
    "fleet_smoke",
    "tiny fleet scenario for the CI gate (seconds)",
    dict(n_param_servers=2, n_clients=200, tasks_per_client=1,
         n_shards=400, max_epochs=1, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=7)))

_reg(Scenario(
    "fleet_1k",
    "1k clients x 2 epochs, exponential churn, probe task",
    dict(n_param_servers=4, n_clients=1000, tasks_per_client=1,
         n_shards=2000, max_epochs=2, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=7)))

_reg(Scenario(
    "fleet_10k",
    "10k clients x 1 epoch, exponential churn, probe task",
    dict(n_param_servers=8, n_clients=10000, tasks_per_client=1,
         n_shards=12000, max_epochs=1, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.02, seed=7)))

_reg(Scenario(
    "fleet_smoke_tier",
    "fleet_smoke behind 4 edge aggregators (2-level CI smoke)",
    dict(n_param_servers=2, n_clients=200, tasks_per_client=1,
         n_shards=400, max_epochs=1, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=7, aggregators=4)))

_reg(Scenario(
    "fleet_10k_tier",
    "fleet_10k behind 32 edge aggregators: clients lease from their edge, "
    "the hub sees ONE merged KIND_AGG frame per flush window (~312 "
    "clients' results) — the 2-level fan-in the ROADMAP scale item asks "
    "for; compare hub wire counters against flat fleet_10k",
    dict(n_param_servers=8, n_clients=10000, tasks_per_client=1,
         n_shards=12000, max_epochs=1, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.02, seed=7, aggregators=32)))

_reg(Scenario(
    "fleet_100k",
    "100k clients x 3 epochs, exponential churn, eval every 64th result",
    dict(n_param_servers=16, n_clients=100000, tasks_per_client=1,
         n_shards=100000, max_epochs=3, local_steps=1,
         timeout_s=3600.0, preemptible=True, mean_lifetime_s=14400.0,
         restart_delay_s=120.0, subtask_compute_s=300.0,
         server_proc_s=0.005, seed=7, eval_stride=64)))

# ---- content-addressed handout serving (read-heavy scenarios) --------------
# A modest trainer fleet keeps the bus moving; the measurement is the
# SERVING leg: N read-only subscribers pulling through the coordinator's
# frame cache (protocol/handout.py).  The probe bus is widened to 64k
# params (8 chunks of one BLOCK each) so updates spread across several
# chunks instead of always landing in chunk 0.  Headline numbers:
# bytes-served / unique-bytes-encoded (dedup) and p99 pull latency.

_SERVE_TASK = dict(dim=65536)
_SERVE_BASE = dict(n_param_servers=2, n_clients=200, tasks_per_client=1,
                   n_shards=400, max_epochs=2, local_steps=1,
                   timeout_s=1800.0, preemptible=True,
                   mean_lifetime_s=5400.0, restart_delay_s=120.0,
                   subtask_compute_s=120.0, server_proc_s=0.05, seed=7,
                   bus_shards=8)

_reg(Scenario(
    "handout_smoke",
    "tiny serving scenario for the CI gate and the --check dedup floor: "
    "400 flash-crowd subscribers over a 50-trainer fleet (seconds)",
    dict(_SERVE_BASE, n_clients=50, n_shards=100, max_epochs=1,
         subscribers=400, sub_lag="flash", sub_interval_s=120.0,
         sub_jitter_s=20.0),
    task_kwargs=_SERVE_TASK))

_reg(Scenario(
    "handout_flash_10k",
    "10k subscribers re-pulling in 30s flash crowds every 240s while 200 "
    "trainers move the bus: one encode per changed chunk serves the "
    "whole crowd (the >=50x dedup acceptance scenario)",
    dict(_SERVE_BASE, subscribers=10000, sub_lag="flash",
         sub_interval_s=240.0, sub_jitter_s=30.0),
    task_kwargs=_SERVE_TASK))

_reg(Scenario(
    "handout_lagged_10k",
    "10k subscribers at heavy-tailed (lognormal) re-pull lag, mean 300s: "
    "staggered reads, varied staleness per pull",
    dict(_SERVE_BASE, subscribers=10000, sub_lag="lognormal",
         sub_interval_s=300.0),
    task_kwargs=_SERVE_TASK))

_reg(Scenario(
    "handout_flash_100k",
    "100k flash-crowd subscribers, one epoch (bench --full scale)",
    dict(_SERVE_BASE, max_epochs=1, subscribers=100000, sub_lag="flash",
         sub_interval_s=240.0, sub_jitter_s=60.0, sub_frontends=16),
    task_kwargs=_SERVE_TASK))

_reg(Scenario(
    "handout_flash_1m",
    "1M flash-crowd subscribers, one epoch: the cache stays bounded at "
    "n_chunks x keep_rounds frames while serving ~8M frames (--full)",
    dict(_SERVE_BASE, n_clients=100, n_shards=200, max_epochs=1,
         subscribers=1000000, sub_lag="flash", sub_interval_s=300.0,
         sub_jitter_s=120.0, sub_frontends=64),
    task_kwargs=_SERVE_TASK))


_reg(Scenario(
    "az_reclaim",
    "correlated AZ mass reclaims over a SHARDED bus: the thundering herd "
    "of full re-downloads goes through the version-vector delta ledger",
    dict(n_param_servers=4, n_clients=600, tasks_per_client=1,
         n_shards=1200, max_epochs=2, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=7200.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=11, bus_shards=8),
    fleet_fn=_az_reclaim_fleet))

_reg(Scenario(
    "spot_price",
    "spot-market preemption: per-AZ mean-reverting price vs a fixed bid",
    dict(n_param_servers=4, n_clients=600, tasks_per_client=1,
         n_shards=1200, max_epochs=2, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=180.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=11),
    fleet_fn=_spot_price_fleet))

_reg(Scenario(
    "diurnal",
    "volunteer churn with a 24h sinusoidal departure hazard per region",
    dict(n_param_servers=4, n_clients=600, tasks_per_client=1,
         n_shards=1200, max_epochs=2, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=10800.0,
         restart_delay_s=300.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=11),
    fleet_fn=_diurnal_fleet))

_reg(Scenario(
    "tiered",
    "heterogeneous compute/bandwidth tiers (20% fast / 50% ref / 30% slow)",
    dict(n_param_servers=4, n_clients=600, tasks_per_client=1,
         n_shards=1200, max_epochs=2, local_steps=1,
         timeout_s=1800.0, preemptible=True, mean_lifetime_s=5400.0,
         restart_delay_s=120.0, subtask_compute_s=120.0,
         server_proc_s=0.05, seed=11),
    fleet_fn=_tiered_fleet))


def get(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have: "
                       f"{', '.join(sorted(SCENARIOS))}") from None


def main(argv=None) -> int:
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", required=True,
                    help="one of: " + ", ".join(sorted(SCENARIOS)))
    ap.add_argument("--json", action="store_true",
                    help="emit the result summary as json")
    args = ap.parse_args(argv)
    sc = get(args.scenario)
    t0 = time.perf_counter()
    res = sc.run()
    wall = time.perf_counter() - t0
    summary = {
        "scenario": sc.name,
        "bench_wall_s": round(wall, 3),
        "events_processed": res.events_processed,
        "events_per_sec": round(res.events_processed / max(wall, 1e-9), 1),
        "sim_wall_time_s": res.wall_time_s,
        "epochs_done": res.epochs_done,
        "results_assimilated": res.results_assimilated,
        "preemptions": res.preemptions,
        "reassignments": res.reassignments,
        "final_accuracy": res.final_accuracy,
        "wire_bytes_sent": int(res.wire.bytes_sent),
        "handout_frames": res.handout_frames,
        "handout_bytes": int(res.handout_bytes),
    }
    if res.aggregators:
        summary.update({
            "aggregators": res.aggregators,
            "agg_flushes": res.agg_flushes,
            "upstream_agg_frames": res.wire_agg_frames,
            "edge_bytes_sent": int(res.edge_wire.bytes_sent),
        })
    if res.subscribers:
        summary.update({
            "subscribers": res.subscribers,
            "sub_pulls": res.sub_pulls,
            "sub_bytes_served": res.sub_bytes_served,
            "unique_bytes_encoded": res.handout_unique_bytes_encoded,
            "handout_dedup_ratio": round(res.handout_dedup_ratio, 1),
            "sub_latency_p99_s": round(res.sub_latency_p99_s, 4),
        })
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        for k, v in summary.items():
            print(f"{k:>22}: {v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
