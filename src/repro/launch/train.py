"""End-to-end VC-ASGD trainer.

Runs the paper's full loop at any scale the host provides: a mesh of
(pod, data, model), per-pod client islands doing local steps, Eq. 2
assimilation between rounds, timeout-free fault handling (an island that
fails a round is simply masked out of the assimilation), checkpoint /
restart of the server copy, and the epoch-varying alpha schedule.

CPU example (2 islands, reduced model):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --rounds 20 --local-steps 4 --islands 2
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.core.vc_asgd import var_alpha, const_alpha
from repro.data import make_batch_for
from repro.launch.mesh import make_test_mesh
from repro.models.registry import build_model
from repro.optim import Adam
from repro.runtime.sharding import MeshPlan
from repro.runtime.vc_runtime import island_shardings, make_vc_round


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--islands", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--alpha", default="var",
                    help="'var' (paper schedule) or a float")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1,2",
                    help="data,model mesh inside each island")
    ap.add_argument("--ckpt-dir", default="/tmp/vcjax_ckpt")
    ap.add_argument("--preempt-round", type=int, default=-1,
                    help="simulate island-0 preemption at this round")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    n_pods = args.islands
    dm = tuple(int(x) for x in args.mesh.split(","))
    n_dev = len(jax.devices())
    assert n_pods * dm[0] * dm[1] <= n_dev, \
        f"need {n_pods * dm[0] * dm[1]} devices, have {n_dev}"
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((n_pods, dm[0], dm[1]), ("pod", "data", "model"))
    plan = MeshPlan.build(cfg, mesh, data_axis="data")
    optimizer = Adam(lr=args.lr)
    alpha_fn = var_alpha() if args.alpha == "var" else \
        const_alpha(float(args.alpha))

    vc_round = make_vc_round(model, plan, n_pods, args.local_steps, optimizer)
    server_sh, island_sh, opt_sh = island_shardings(model, plan, n_pods,
                                                    optimizer)
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    step_fn = jax.jit(vc_round,
                      in_shardings=(server_sh, island_sh, opt_sh, None, rep, rep),
                      out_shardings=(server_sh, island_sh, opt_sh,
                                     {"loss": rep}))

    ckpt = CheckpointManager(args.ckpt_dir)
    key = jax.random.PRNGKey(args.seed)

    def init_server():
        return model.init(key)

    with mesh:
        server, extra, start_round = ckpt.restore_or_init(
            jax.eval_shape(init_server) if ckpt.latest_step() else None,
            init_server)
        islands = jax.tree.map(
            lambda s: jnp.broadcast_to(s[None], (n_pods, *s.shape)), server)
        opts = jax.vmap(optimizer.init)(islands)

        print(f"[train] {cfg.describe()}")
        print(f"[train] islands={n_pods} mesh={dict(mesh.shape)} "
              f"resume_round={start_round}")
        for rnd in range(start_round, args.rounds):
            t0 = time.time()
            batches = _round_batches(cfg, n_pods, args.local_steps,
                                     args.batch, args.seq,
                                     seed=args.seed * 7919 + rnd)
            survivors = np.ones((n_pods,), bool)
            if rnd == args.preempt_round:
                survivors[0] = False      # island 0 preempted this round
                print(f"[train] round {rnd}: island 0 PREEMPTED "
                      f"(masked out of assimilation)")
            alpha = jnp.asarray(alpha_fn(rnd + 1), jnp.float32)
            server, islands, opts, metrics = step_fn(
                server, islands, opts, batches, alpha,
                jnp.asarray(survivors))
            loss = float(metrics["loss"])
            print(f"[train] round {rnd:3d} alpha={float(alpha):.3f} "
                  f"loss={loss:.4f} ({time.time() - t0:.1f}s)")
            ckpt.save(rnd + 1, server, {"round": rnd + 1})
        ckpt.wait()
    print("[train] done; server checkpoint at", args.ckpt_dir)
    return 0


def _round_batches(cfg, n_pods, local_steps, batch, seq, seed):
    bs = []
    for p in range(n_pods):
        steps = [make_batch_for(cfg, batch, seq, seed=seed * 31 + p * 7 + s)
                 for s in range(local_steps)]
        bs.append(jax.tree.map(lambda *x: jnp.stack(x), *steps))
    return jax.tree.map(lambda *x: jnp.stack(x), *bs)


if __name__ == "__main__":
    raise SystemExit(main())
