"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract memory / cost / collective analysis.

MUST set the device-count flag before ANY other import (jax locks the
device count on first init).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config                    # noqa: E402
from repro.configs.shapes import (SHAPES, cell_applicable,     # noqa: E402
                                  input_specs, tune_for_shape)
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.registry import build_model                  # noqa: E402
from repro.optim import Adam                                   # noqa: E402
from repro.runtime import hlo_analysis as H                    # noqa: E402
from repro.runtime.sharding import MeshPlan                    # noqa: E402
from repro.runtime.train import (make_decode_step,             # noqa: E402
                                 make_prefill_step, make_train_step,
                                 microbatch_specs, shardings_for_decode,
                                 shardings_for_prefill, shardings_for_train)

TRAIN_ACCUM = {  # microbatch count per arch for train_4k (memory knob)
    "default": 2, "qwen2.5-14b": 4, "mixtral-8x7b": 4, "jamba-v0.1-52b": 4,
}

RESULTS = Path(__file__).resolve().parents[3] / "results"
RESULTS.mkdir(exist_ok=True)


def lower_cell(arch: str, cell_name: str, multi_pod: bool,
               attn_mode_override=None, extra_tag: str = "",
               moe_ep: bool = False, accum_override=None,
               zero_dp: bool = False, remat="full"):
    """Lower + compile one (arch, shape, mesh) cell. Returns a result dict."""
    cfg = tune_for_shape(get_config(arch), SHAPES[cell_name])
    cell = SHAPES[cell_name]
    skip = cell_applicable(cfg, cell)
    if skip:
        return {"arch": arch, "cell": cell_name, "multi_pod": multi_pod,
                "status": "skip", "reason": skip}
    if cell.kind in ("prefill", "decode"):
        # serving: bf16 weights, replicated over data / TP over model —
        # FSDP-sharded serve params would all-gather weights every step
        cfg = cfg.replace(param_dtype="bfloat16")

    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axis = ("pod", "data") if multi_pod else "data"
    moe_ep = moe_ep and cfg.moe is not None and cell.kind != "decode"
    if moe_ep:
        from repro.runtime.sharding import ep_tune
        dp = int(np.prod([mesh.shape[a] for a in
                          (data_axis if isinstance(data_axis, tuple)
                           else (data_axis,))]))
        cfg = ep_tune(cfg, dp)
    plan = MeshPlan.build(
        cfg, mesh, data_axis=data_axis, attn_mode=attn_mode_override,
        decode_batch=cell.global_batch if cell.kind == "decode" else None,
        moe_ep=moe_ep, zero_dp=zero_dp)
    if cell.kind in ("prefill", "decode"):
        plan.fsdp = False
    model = build_model(cfg)
    optimizer = Adam(lr=3e-4)
    t0 = time.time()

    with mesh:
        if cell.kind == "train":
            accum = accum_override or TRAIN_ACCUM.get(arch,
                                                      TRAIN_ACCUM["default"])
            batch = input_specs(cfg, cell, plan)
            if accum > 1:
                batch = microbatch_specs(batch, accum)
            remat_mode = True if remat == "full" else remat
            step = make_train_step(model, plan, optimizer, accum=accum,
                                   remat=remat_mode)
            p_specs = model.param_specs()
            o_specs = jax.eval_shape(optimizer.init, p_specs)
            ins, outs = shardings_for_train(model, plan, optimizer, batch,
                                            accum=accum)
            lowered = jax.jit(step, in_shardings=ins, out_shardings=outs,
                              donate_argnums=(0, 1)  # params/opt update in place
                              ).lower(p_specs, o_specs, batch)
        elif cell.kind == "prefill":
            batch = input_specs(cfg, cell, plan)
            step = make_prefill_step(model, plan)
            p_specs = model.param_specs()
            cache_specs = jax.eval_shape(
                lambda p, b: model.prefill(p, b, plan=plan)[1], p_specs, batch)
            ins, outs = shardings_for_prefill(model, plan, batch, cache_specs)
            lowered = jax.jit(step, in_shardings=ins,
                              out_shardings=outs).lower(p_specs, batch)
        else:  # decode
            specs = input_specs(cfg, cell, plan)
            step = make_decode_step(model, plan)
            p_specs = model.param_specs()
            ins, outs = shardings_for_decode(model, plan, specs["caches"],
                                             cell.global_batch)
            lowered = jax.jit(step, in_shardings=ins, out_shardings=outs,
                              donate_argnums=(1,)    # cache updated in place
                              ).lower(p_specs, specs["caches"],
                                      specs["token"], specs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    hc = H.analyze_hlo_text(txt)
    n_dev = int(np.prod(list(mesh.shape.values())))

    res = {
        "arch": arch, "cell": cell_name, "multi_pod": multi_pod,
        "attn_mode": plan.attn_mode, "cache_mode": plan.cache_mode,
        "status": "ok", "tag": extra_tag,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes),
        },
        "xla_cost": {"flops": ca.get("flops", 0.0),
                     "bytes_accessed": ca.get("bytes accessed", 0.0),
                     "transcendentals": ca.get("transcendentals", 0.0)},
        "hlo": {
            "dot_flops": hc.dot_flops,
            "hbm_bytes": hc.hbm_bytes,
            "collective_bytes": dict(hc.collective_bytes),
            "collective_count": dict(hc.collective_count),
            "total_collective_bytes": hc.total_collective_bytes,
            "while_trips": hc.while_trips,
        },
    }
    rt = H.roofline_terms(hc.dot_flops, hc.hbm_bytes, hc.total_collective_bytes)
    res["roofline"] = {
        "compute_s": rt.compute_s, "memory_s": rt.memory_s,
        "collective_s": rt.collective_s, "dominant": rt.dominant,
        "bound_s": rt.bound_s,
    }
    return res


def lower_vc_round(arch: str, multi_pod: bool = True, local_steps: int = 4):
    """Lower the paper-technique VC round (island local steps + Eq.2
    assimilation + redistribution) on the multi-pod mesh."""
    from repro.runtime.vc_runtime import (island_shardings, make_vc_round)
    cfg = tune_for_shape(get_config(arch), SHAPES["train_4k"])
    cell = SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_pods = mesh.shape.get("pod", 1)
    plan = MeshPlan.build(cfg, mesh, data_axis="data")
    model = build_model(cfg)
    optimizer = Adam(lr=3e-4)
    vc_round = make_vc_round(model, plan, n_pods, local_steps, optimizer)

    per_island_batch = cell.global_batch // n_pods
    batch1 = input_specs(cfg, cell, plan)
    batches = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            (n_pods, local_steps, per_island_batch, *s.shape[1:]), s.dtype),
        batch1)

    p_specs = model.param_specs()
    islands = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_pods, *s.shape), s.dtype), p_specs)
    opts = jax.eval_shape(lambda p: jax.vmap(optimizer.init)(p), islands)
    server_sh, island_sh, opt_sh = island_shardings(model, plan, n_pods,
                                                    optimizer)
    b_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P("pod", None, "data",
                                        *([None] * (len(s.shape) - 3)))),
        batches)
    rep = NamedSharding(mesh, P())
    surv_sh = rep

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            vc_round,
            in_shardings=(server_sh, island_sh, opt_sh, b_sh, rep, surv_sh),
            out_shardings=(server_sh, island_sh, opt_sh, {"loss": rep}),
        ).lower(p_specs, islands, opts, batches,
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((n_pods,), jnp.bool_))
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hc = H.analyze_hlo_text(compiled.as_text())
    return {
        "arch": arch, "cell": f"vc_round_x{local_steps}",
        "multi_pod": multi_pod, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "mem": {"peak_per_device": mem.argument_size_in_bytes
                + mem.temp_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "argument_bytes": mem.argument_size_in_bytes},
        "hlo": {"dot_flops": hc.dot_flops, "hbm_bytes": hc.hbm_bytes,
                "total_collective_bytes": hc.total_collective_bytes,
                "collective_bytes": dict(hc.collective_bytes)},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--vc-round", action="store_true",
                    help="also lower the VC-ASGD island round per arch")
    ap.add_argument("--attn-mode", default=None,
                    help="override planner attention mode (perf experiments)")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE dispatch (perf experiments)")
    ap.add_argument("--zero-dp", action="store_true",
                    help="pure-DP ZeRO plan: model axis folded into data")
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    cells = list(SHAPES) if args.cell == "all" else [args.cell]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    results = {}
    if args.resume and out_path.exists():
        results = json.loads(out_path.read_text())

    for arch in archs:
        for cell in cells:
            for mp in meshes:
                key = f"{arch}|{cell}|{'multi' if mp else 'single'}" + \
                    (f"|{args.tag}" if args.tag else "")
                if args.resume and key in results and \
                        results[key].get("status") in ("ok", "skip"):
                    continue
                t0 = time.time()
                try:
                    res = lower_cell(arch, cell, mp,
                                     attn_mode_override=args.attn_mode,
                                     extra_tag=args.tag, moe_ep=args.moe_ep,
                                     accum_override=args.accum,
                                     zero_dp=args.zero_dp, remat=args.remat)
                except Exception as e:  # noqa: BLE001
                    res = {"arch": arch, "cell": cell, "multi_pod": mp,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                res["wall_s"] = round(time.time() - t0, 1)
                results[key] = res
                out_path.write_text(json.dumps(results, indent=1))
                status = res["status"]
                extra = (res.get("reason") or res.get("error", ""))[:90]
                mem = res.get("mem", {}).get("peak_per_device", 0) / 2 ** 30
                dom = res.get("roofline", {}).get("dominant", "-")
                print(f"[{status:5s}] {key:45s} {res['wall_s']:7.1f}s "
                      f"peak={mem:6.2f}GiB dom={dom} {extra}", flush=True)
        if args.vc_round:
            key = f"{arch}|vc_round|multi"
            if not (args.resume and key in results
                    and results[key].get("status") == "ok"):
                try:
                    res = lower_vc_round(arch)
                except Exception as e:  # noqa: BLE001
                    res = {"arch": arch, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = res
                out_path.write_text(json.dumps(results, indent=1))
                print(f"[{res['status']:5s}] {key}", flush=True)

    ok = sum(1 for r in results.values() if r["status"] == "ok")
    skip = sum(1 for r in results.values() if r["status"] == "skip")
    err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndry-run complete: {ok} ok / {skip} skip / {err} error "
          f"-> {out_path}")
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
