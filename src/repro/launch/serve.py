"""Serving driver: batched prefill + decode loop with the two-tier cache
(periodic compaction), usable at reduced scale on CPU.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import make_batch_for
from repro.launch.mesh import make_test_mesh
from repro.models.layers import RECENT_RING, compact_cache, DecodeCache
from repro.models.registry import build_model
from repro.runtime.sharding import MeshPlan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="2,2")
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    dm = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_test_mesh(dm, ("data", "model"))
    plan = MeshPlan.build(cfg, mesh, decode_batch=args.batch)
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        params = model.init(key)
        batch = make_batch_for(cfg, args.batch, args.prompt_len, args.seed)
        t0 = time.time()
        prefill = jax.jit(lambda p, b: model.prefill(p, b, plan=plan))
        lg, caches = prefill(params, batch)
        jax.block_until_ready(lg)
        t_prefill = time.time() - t0
        print(f"[serve] {cfg.arch}: prefill {args.batch}x{args.prompt_len} "
              f"in {t_prefill:.2f}s")

        decode = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i,
                                                              plan=plan))
        tok = jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)
        prefix = cfg.vision.n_patches if cfg.vision is not None else 0
        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.gen):
            pos = jnp.asarray(args.prompt_len + prefix + i, jnp.int32)
            lg, caches = decode(params, caches, tok, pos)
            tok = jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)
            out_tokens.append(tok)
            if (i + 1) % RECENT_RING == 0 and not cfg.is_enc_dec:
                caches = _compact_all(caches, pos)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"[serve] generated {args.gen} tokens/seq in {dt:.2f}s "
              f"({args.gen * args.batch / dt:.1f} tok/s)")
        toks = np.stack([np.asarray(t) for t in out_tokens], 1)
        print("[serve] sample continuations:")
        for row in toks[: min(4, args.batch)]:
            print("   ", row[:16].tolist())
    return 0


def _compact_all(caches, pos):
    """Fold recent rings into the old tier for every attention layer."""
    def walk(node):
        if isinstance(node, DecodeCache):
            return jax.vmap(lambda c: compact_cache(c, pos))(node) \
                if node.k_old.ndim == 6 else compact_cache(node, pos)
        if isinstance(node, tuple) and not hasattr(node, "_fields"):
            return tuple(walk(c) for c in node)
        return node
    return walk(caches)


if __name__ == "__main__":
    raise SystemExit(main())
