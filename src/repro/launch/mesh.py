"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: newer jax wants explicit
    ``axis_types=(AxisType.Auto, ...)``; older jax (<=0.4.x) has neither
    the kwarg nor ``jax.sharding.AxisType``.  Callers (runtime + tests)
    must route mesh creation through here."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return compat_make_mesh(shape, axes)


def make_pod_mesh(n_pods: int, axis: str = "pod"):
    """1-D mesh for the sharded FlatParams bus (core/flat.py
    ShardedTreeSpec): each of the ``n_pods`` devices owns one contiguous
    BLOCK-padded segment of the flat buffer, so the flat kernels run
    per-shard under shard_map with no gather (runtime/sharding.py)."""
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    return compat_make_mesh((n_pods,), (axis,))
