"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
