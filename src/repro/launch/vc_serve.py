"""VC coordinator runtime: the SAME protocol object the simulator drives,
run against the wall clock with payload bytes crossing a REAL OS process
boundary (transfer/transport.py::ProcessTransport).

This is the proof that the Lease/Coordinator API is not simulator-shaped:
``core/simulator.py`` and this loop differ ONLY in where time comes from
and where clients run — issue/submit/deliver/assimilate, the residual
ledger, the wire framing (BOTH legs: per-shard handout frames on the
download leg, dense/sparse result frames on the upload leg) and the
checkpoint hooks are byte-for-byte the same code.

Resume is exact: a restarted server picks up at the checkpointed round
and uid (persisted in the checkpoint ``extra``), so lease rounds, wire
headers and checkpoint steps are monotone across kills — step k+1 never
overwrites steps 1..k (tools/ci_gate.sh runs a kill-and-resume pass).

``--tier N`` inserts N edge aggregators between the clients and the hub —
a REAL 2-level round: every aggregator runs over its OWN ProcessTransport
(client payloads cross one process boundary to the edge, ONE merged
``KIND_AGG`` frame per aggregator crosses another to the hub).  With one
aggregator the run is bit-identical to flat — rounds are synchronous, so
the hub never moves inside a window and adopts the merge exactly
(tests/test_aggregator.py asserts it).

  PYTHONPATH=src python -m repro.launch.vc_serve --rounds 4 --clients 3
  PYTHONPATH=src python -m repro.launch.vc_serve --smoke   # fast-gate size
  PYTHONPATH=src python -m repro.launch.vc_serve --smoke --tier
"""
from __future__ import annotations

import argparse
import contextlib
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor

import jax

from repro.checkpoint import CheckpointManager
from repro.core import flat as F
from repro.core.baselines import CompressedVCASGD, VCASGD
from repro.core.tasks import MLPTask, make_classification_data
from repro.protocol import Aggregator, Coordinator, HandoutService, as_tree
from repro.transfer import wire
from repro.transfer.transport import ProcessTransport


def _check(cond: bool, what: str) -> None:
    """End-of-run invariant check that survives ``python -O`` (a bare
    assert is compiled away, which is exactly when a silent protocol leak
    would go unnoticed in production)."""
    if not cond:
        raise SystemExit(f"[vc-serve] INVARIANT VIOLATED: {what}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the server bus into N contiguous "
                         "segments: handouts ship as per-shard delta "
                         "frames (a client re-fetches only segments that "
                         "changed since its last handout)")
    ap.add_argument("--density", type=float, default=None,
                    help="compress payloads to this top-k density "
                         "(sparse wire frames)")
    ap.add_argument("--tier", type=int, nargs="?", const=1, default=0,
                    help="insert N edge aggregators (default 1 when the "
                         "flag is given bare): clients lease from their "
                         "aggregator, each aggregator submits ONE merged "
                         "v3 frame upstream per round over its own "
                         "process transport")
    ap.add_argument("--subscribers", type=int, default=0,
                    help="after each round, N read-only subscribers pull "
                         "the model through the content-addressed handout "
                         "cache — every served frame crosses the broker "
                         "(protocol/handout.py::HandoutService)")
    ap.add_argument("--handout-dtype", default="float32",
                    choices=["float32", "f32", "bfloat16", "bf16"],
                    help="download-leg frame dtype: bf16 halves handout "
                         "bytes (f32 masters, bf16-exact reconstruction)")
    ap.add_argument("--timeout-s", type=float, default=600.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for the fast test gate")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.rounds, args.clients, args.shards = 2, 2, 2

    task = MLPTask()
    data = make_classification_data(n_train=600 if args.smoke else 3000,
                                    n_val=150 if args.smoke else 600,
                                    seed=args.seed)
    tree0 = task.init_params(jax.random.PRNGKey(args.seed))
    params0 = (F.flatten(tree0) if args.shards <= 1
               else F.flatten_sharded(tree0, args.shards))
    scheme = (VCASGD(0.9) if args.density is None
              else CompressedVCASGD(0.9, density=args.density))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="vc_serve_")
    mgr = CheckpointManager(ckpt_dir, async_save=False)

    with contextlib.ExitStack() as stack:
        transport = stack.enter_context(ProcessTransport())
        coord = Coordinator(scheme, params0, transport=transport,
                            timeout_s=args.timeout_s,
                            handout_dtype=args.handout_dtype)
        resumed = coord.restore_checkpoint(mgr)
        # resume offsets the round counter and uid sequence: checkpoint
        # step k holds rounds 0..k-1, so a restarted server continues at
        # round k with the persisted next uid — rounds, wire headers and
        # checkpoint steps stay monotone, nothing is overwritten
        start = 0 if resumed is None else resumed
        uid = int(coord.restored_extra.get("next_uid", 0))
        if resumed is not None:
            print(f"[vc-serve] resumed server v{coord.state.version} "
                  f"from checkpoint step {resumed} "
                  f"(continuing at round {start}, uid {uid})")
        # the aggregation tier: each edge aggregator speaks the same
        # protocol downward (to its clients) and upward (to the hub),
        # over its OWN process transport
        aggs = []
        for a in range(args.tier):
            at = stack.enter_context(ProcessTransport())
            aggs.append(Aggregator(scheme, coord, agg_id=a, transport=at,
                                   timeout_s=args.timeout_s,
                                   handout_dtype=args.handout_dtype))
        # read-only subscribers: served from the hub's frame cache, every
        # frame crossing the SAME broker process the lease traffic uses
        service = (HandoutService(coord, transport=transport)
                   if args.subscribers > 0 else None)
        print(f"[vc-serve] scheme={scheme.name} clients={args.clients} "
              f"shards={args.shards} broker pid={transport.broker_pid} "
              f"(frames cross a real process boundary)"
              + (f" tier={len(aggs)} aggregators, broker pids "
                 f"{[a.transport.broker_pid for a in aggs]}" if aggs
                 else ""))
        # handout-encode prefetch: one worker thread pipelines the NEXT
        # lease's issue (handout encode + broker round-trip) under the
        # CURRENT client's training compute.  Safe because every handout
        # in a round snapshots the same server state (the fold happens
        # only at end-of-round assimilation) and issue(cid+1) touches no
        # state that submit(cid) reads — uid sequence, seeds, frames and
        # bytes are identical to the serial order, so the kill-and-resume
        # gate sees the same rounds.  The pipeline deliberately STOPS at
        # the round boundary: round R+1's first handout depends on round
        # R's assimilated params and cannot be encoded speculatively.
        pool = stack.enter_context(ThreadPoolExecutor(max_workers=1))
        for rnd in range(start, start + args.rounds):
            t0 = time.monotonic()
            for agg in aggs:
                agg.open_window(round=rnd, now=time.monotonic())

            def _issue(cid: int, u: int):
                # issue: the runtime's "store head" is the live state;
                # the handout crosses the broker as per-shard frames.
                # In tier mode the client leases from ITS aggregator,
                # whose window state is the decoded hub handout.
                srv = aggs[cid % len(aggs)] if aggs else coord
                lease = srv.issue(cid=cid, uid=u, round=rnd, shard=cid,
                                  read_version=srv.state.version,
                                  base=srv.state.params,
                                  now=time.monotonic())
                return srv, lease

            leases = []
            nxt = pool.submit(_issue, 0, uid)
            for cid in range(args.clients):
                srv, lease = nxt.result()
                if cid + 1 < args.clients:
                    # encode the next handout while THIS client trains
                    nxt = pool.submit(_issue, cid + 1, uid + cid + 1)
                # client-side REAL training from the DECODED handout
                trained = task.client_train(
                    as_tree(lease.base), data.x_train, data.y_train,
                    steps=4, seed=args.seed * 1000003 + lease.uid)
                srv.submit(lease, F.flatten_like(trained, lease.base.spec))
                leases.append((srv, lease))
            uid += args.clients
            # one straggler per round is "preempted" mid-upload: its lease
            # is dropped, its bytes wasted — assimilation shrugs it off
            if args.clients > 1 and rnd % 2 == 1:
                srv, lease = leases.pop()
                srv.drop(lease)
            for srv, lease in leases:
                payload = srv.deliver(lease)
                srv.assimilate(lease, payload,
                               server_version=srv.state.version,
                               t_arrival=time.monotonic())
            # tier flush: each aggregator ships ONE merged v3 frame (its
            # fold state + summed client weight) upstream; the hub adopts
            # it via assimilate_aggregate — bit-identical to folding the
            # window's results directly, because the hub never moved
            # inside the window (rounds are synchronous here)
            for agg in aggs:
                up = agg.flush(now=time.monotonic())
                if up is not None:
                    coord.assimilate(up, coord.deliver(up),
                                     server_version=coord.state.version,
                                     t_arrival=time.monotonic())
                agg.expire(time.monotonic())
            coord.expire(time.monotonic())
            coord.save_checkpoint(mgr, step=rnd + 1,
                                  extra={"next_uid": uid})
            acc = task.evaluate(as_tree(coord.state.params),
                                data.x_val, data.y_val)
            s = coord.wire_stats
            up_frames = coord.frames[wire.KIND_AGG]
            print(f"[vc-serve] round {rnd}: acc={acc:.3f} "
                  f"server v{coord.state.version} "
                  f"wire {s.bytes_sent / 1e6:.2f}MB sent "
                  f"(handout {coord.handout_bytes / 1e6:.2f}MB in "
                  f"{coord.handout_frames} frames, "
                  f"{s.frames_dropped} frames dropped) "
                  f"residual mass {coord.residual_mass():.2f} "
                  + (f"upstream agg frames {up_frames} " if aggs else "")
                  + f"[{time.monotonic() - t0:.2f}s]")
            # the read path: every subscriber pulls the round's model
            # through the content-addressed cache — cached frames cross
            # the REAL broker, but the encode happens at most once per
            # (round, chunk, content)
            if service is not None:
                lat = []
                for sub in range(args.subscribers):
                    ts = time.monotonic()
                    service.pull(sub, coord.state.params, round=rnd)
                    lat.append(time.monotonic() - ts)
                lat.sort()
                p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
                c = coord.handout_cache
                print(f"[vc-serve] serve: round {rnd} "
                      f"{args.subscribers} subscribers "
                      f"{service.bytes_served / 1e6:.2f}MB served "
                      f"({c.encoded_bytes / 1e6:.2f}MB unique encoded, "
                      f"dedup {c.dedup_ratio:.1f}x) "
                      f"p99 {p99 * 1e3:.2f}ms")
        s = coord.wire_stats
        _check(s.frames_sent == s.frames_recv + s.frames_dropped,
               f"hub frame conservation: {s.frames_sent} sent != "
               f"{s.frames_recv} recv + {s.frames_dropped} dropped")
        _check(coord.in_flight == 0,
               f"{coord.in_flight} hub leases still live at shutdown")
        _check(transport.in_flight == 0,
               f"{transport.in_flight} frames stranded in the hub broker")
        for agg in aggs:
            es = agg.wire_stats
            _check(es.frames_sent == es.frames_recv + es.frames_dropped,
                   f"agg {agg.agg_id} frame conservation violated")
            _check(agg.in_flight == 0 and not agg.window_open,
                   f"agg {agg.agg_id} still holds leases/window")
            _check(agg.transport.in_flight == 0,
                   f"frames stranded in agg {agg.agg_id}'s broker")
        print(f"[vc-serve] done: {coord.assimilated} results assimilated, "
              f"{coord.dropped} dropped, next uid {uid}, "
              f"checkpoints in {ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
