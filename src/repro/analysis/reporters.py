"""Text and JSON reporters for vclint reports.

The JSON shape is consumed by ``benchmarks/run.py --check`` and by the
baseline ratchet, so it is part of the tool's contract (pinned by
tests/test_vclint.py::test_json_reporter_schema):

    {
      "tool": "vclint",
      "schema_version": 1,
      "files_checked": <int>,
      "rules_run": [<rule>, ...],
      "total": <int>,
      "by_rule": {<rule>: <count>, ...},
      "violations": [{"path", "line", "rule", "message"}, ...]
    }
"""
from __future__ import annotations

import json
from typing import Dict

from repro.analysis.framework import Report

JSON_SCHEMA_VERSION = 1


def text_report(report: Report, *, verbose: bool = True) -> str:
    lines = []
    if verbose:
        for v in report.violations:
            lines.append(v.format())
    if report.violations:
        by = ", ".join(f"{k}={n}" for k, n in report.by_rule.items())
        lines.append(f"vclint: {report.total} violation"
                     f"{'s' if report.total != 1 else ''} "
                     f"({by}) in {report.files_checked} files")
    else:
        lines.append(f"vclint: clean ({report.files_checked} files, "
                     f"{len(report.rules_run)} rules)")
    return "\n".join(lines)


def json_report(report: Report) -> Dict:
    return {
        "tool": "vclint",
        "schema_version": JSON_SCHEMA_VERSION,
        "files_checked": report.files_checked,
        "rules_run": list(report.rules_run),
        "total": report.total,
        "by_rule": report.by_rule,
        "violations": [
            {"path": v.path, "line": v.line, "rule": v.rule,
             "message": v.message}
            for v in report.violations
        ],
    }


def render_json(report: Report) -> str:
    return json.dumps(json_report(report), indent=2, sort_keys=True) + "\n"
