"""wire-schema: the on-wire layout in transfer/wire.py may only change
together with a WIRE_VERSION bump.

The wire format is consumed by readers that were handed out earlier
(content-addressed handout cache, fleet subscribers): reinterpreting a
header field, renumbering a ``KIND_*`` tag, or changing the header size
at the SAME ``WIRE_VERSION`` silently corrupts every frame already in
flight.  The v2→v3 transition (CHANGES.md) established the discipline:
v3's ``_HDR3`` is a strict append-only extension of v2's ``_HDR`` and
``_PEEK`` lets readers reject unknown versions before parsing anything
else.

This rule parses the module-level constants of ``transfer/wire.py``
straight off the AST and compares them with the pinned fixture
``analysis/wire_schema.json``:

* ``WIRE_VERSION`` equal to the pin → every pinned constant (magic,
  emit version, ``KIND_*`` values, ``_HDR``/``_HDR3``/``_CRC``/
  ``_PEEK`` formats, derived header byte sizes) must match exactly;
  any drift is *reinterpretation without a version bump*.
* ``WIRE_VERSION`` different from the pin → a single violation telling
  the author to re-pin the fixture deliberately (the bump is reviewed
  via the fixture diff, never waved through).
* regardless of version: ``_HDR3`` must extend ``_HDR`` append-only,
  and no two ``KIND_*`` tags may share a value.
"""
from __future__ import annotations

import ast
import json
import struct
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Union

from repro.analysis.framework import (FileContext, Rule, Violation,
                                      call_name, register)

_SCHEMA_PATH = Path(__file__).resolve().parent.parent / "wire_schema.json"


def load_schema() -> dict:
    return json.loads(_SCHEMA_PATH.read_text())


def _module_constants(tree: ast.AST) -> Dict[str, tuple]:
    """name -> (node, value) for module-level ``NAME = <literal>`` and
    ``NAME = struct.Struct("<fmt>")`` assignments."""
    out: Dict[str, tuple] = {}
    for stmt in getattr(tree, "body", []):
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt = stmt.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        val = stmt.value
        if isinstance(val, ast.Constant):
            out[tgt.id] = (stmt, val.value)
        elif (isinstance(val, ast.Call)
              and call_name(val).rsplit(".", 1)[-1] == "Struct"
              and val.args and isinstance(val.args[0], ast.Constant)
              and isinstance(val.args[0].value, str)):
            out[tgt.id] = (stmt, ("struct", val.args[0].value))
    return out


@register
class WireSchemaRule(Rule):
    name = "wire-schema"
    doc = ("transfer/wire.py header/kind constants must match the pinned "
           "schema fixture unless WIRE_VERSION is bumped (and the fixture "
           "re-pinned)")

    def wants(self, ctx: FileContext) -> bool:
        return ctx.endswith("transfer/wire.py")

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        schema = load_schema()
        consts = _module_constants(ctx.tree)
        out: List[Violation] = []

        def node_for(name: str):
            entry = consts.get(name)
            return entry[0] if entry else 1

        def value_of(name: str):
            entry = consts.get(name)
            if entry is None:
                return None
            v = entry[1]
            return v[1] if isinstance(v, tuple) else v

        version = value_of("WIRE_VERSION")
        if version is None:
            out.append(ctx.violation(
                "wire-schema", 1,
                "WIRE_VERSION constant missing from wire module"))
            return out

        if version != schema["wire_version"]:
            out.append(ctx.violation(
                "wire-schema", node_for("WIRE_VERSION"),
                f"WIRE_VERSION changed {schema['wire_version']} -> "
                f"{version}: re-pin analysis/wire_schema.json so the new "
                f"layout is reviewed (see docs/LINT.md)"))
            # at a new version the old pins no longer apply; still run
            # the version-independent structural checks below
        else:
            pins: List[tuple] = [
                ("MAGIC", schema["magic"].encode()),
                ("_EMIT_VERSION", schema["emit_version"]),
            ]
            pins += list(schema["kinds"].items())
            for name, want in pins:
                got = value_of(name)
                if got != want:
                    out.append(ctx.violation(
                        "wire-schema", node_for(name),
                        f"{name} = {got!r} differs from pinned {want!r} "
                        f"without a WIRE_VERSION bump"))
            for name, want in schema["structs"].items():
                got = value_of(name)
                if got != want:
                    out.append(ctx.violation(
                        "wire-schema", node_for(name),
                        f"{name} format {got!r} differs from pinned "
                        f"{want!r}: header reinterpretation requires a "
                        f"WIRE_VERSION bump"))
            self._check_sizes(ctx, schema, value_of, node_for, out)

        self._structural(ctx, consts, value_of, node_for, out)
        return out

    @staticmethod
    def _check_sizes(ctx, schema, value_of, node_for, out):
        """Derived header sizes (HDR + CRC) must match the pinned byte
        counts — catches size drift even if someone renames formats."""
        for fmt_name, size_key in (("_HDR", "header_bytes"),
                                   ("_HDR3", "header_bytes_v3")):
            fmt = value_of(fmt_name)
            crc = value_of("_CRC")
            if not isinstance(fmt, str) or not isinstance(crc, str):
                continue
            try:
                got = struct.calcsize(fmt) + struct.calcsize(crc)
            except struct.error:
                out.append(ctx.violation(
                    "wire-schema", node_for(fmt_name),
                    f"{fmt_name} format {fmt!r} is not a valid struct "
                    f"format"))
                continue
            if got != schema[size_key]:
                out.append(ctx.violation(
                    "wire-schema", node_for(fmt_name),
                    f"{fmt_name}+_CRC is {got} bytes, pinned "
                    f"{schema[size_key]}: header-size change requires a "
                    f"WIRE_VERSION bump"))

    @staticmethod
    def _structural(ctx, consts, value_of, node_for, out):
        hdr, hdr3 = value_of("_HDR"), value_of("_HDR3")
        if isinstance(hdr, str) and isinstance(hdr3, str) \
                and not hdr3.startswith(hdr):
            out.append(ctx.violation(
                "wire-schema", node_for("_HDR3"),
                f"_HDR3 {hdr3!r} does not extend _HDR {hdr!r} "
                f"append-only: v3 readers must be able to parse the v2 "
                f"prefix in place"))
        seen: Dict[int, str] = {}
        for name in sorted(consts):
            if not name.startswith("KIND_"):
                continue
            v = value_of(name)
            if not isinstance(v, int):
                continue
            if v in seen:
                out.append(ctx.violation(
                    "wire-schema", node_for(name),
                    f"{name} reuses wire tag {v} already taken by "
                    f"{seen[v]}"))
            else:
                seen[v] = name
