"""kernel-triangle: every ``pallas_call`` entry point in ``kernels/``
needs (1) a named numpy/jnp oracle in ``kernels/ref.py`` and (2) a
parity test pinning kernel == oracle.

The roofline work only trusts a kernel when the triangle closes:
kernel ↔ oracle ↔ test.  A kernel without an oracle cannot be
parity-checked; an oracle without a test silently drifts.  The mapping
is explicit (names are not mechanically derivable: ``flash_attention``
parity-checks against ``ref.attention``; the fused flat ops are
exercised through wrappers in three different test files), so adding a
kernel means adding a ``TRIANGLE`` entry — an unmapped ``pallas_call``
site is itself a violation, as is a stale entry whose kernel is gone.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.framework import (FileContext, Rule, Violation,
                                      call_name, register)

# kernel entry -> its module (stale detection), oracles that must be
# defined in kernels/ref.py, and parity-test symbols that must exist
# under tests/.
TRIANGLE: Dict[str, dict] = {
    "flash_attention": {
        "module": "flash_attention", "oracles": ["attention"],
        "tests": ["test_flash_attention"]},
    "mamba_scan": {
        "module": "mamba_scan", "oracles": ["mamba_scan"],
        "tests": ["test_mamba_scan"]},
    "wkv6": {
        "module": "rwkv6_scan", "oracles": ["wkv6"],
        "tests": ["test_wkv6"]},
    "quantize_int8": {
        "module": "quantize", "oracles": ["quantize_int8"],
        "tests": ["test_quantize_roundtrip"]},
    "dequantize_int8": {
        "module": "quantize", "oracles": ["dequantize_int8"],
        "tests": ["test_quantize_roundtrip"]},
    "pack_body": {
        "module": "sparse_pack", "oracles": ["pack_body"],
        "tests": ["test_fused_encode_byte_identity_with_pre_pr_layout"]},
    "quantize_pack": {
        "module": "sparse_pack", "oracles": ["quantize_pack"],
        "tests": ["test_fused_quantize_pack_self_consistent"]},
    "threshold_sparsify": {
        "module": "topk_mask", "oracles": ["threshold_sparsify"],
        "tests": ["test_threshold_sparsify"]},
    "blocked_topk_stats": {
        "module": "topk_mask", "oracles": ["blocked_topk_stats"],
        "tests": ["test_blocked_sparsify_kept_plus_residual_bit_exact"]},
    "threshold_sparsify_exact": {
        "module": "topk_mask", "oracles": ["threshold_sparsify_exact"],
        "tests": ["test_select_topk_deterministic_k_under_ties"]},
    "_blocked_call": {
        "module": "vc_asgd_update",
        "oracles": ["vc_asgd_lerp", "vc_asgd_dc_lerp"],
        "tests": ["test_fused_lerp", "test_fused_dc_lerp"]},
    "assimilate_flat": {
        "module": "vc_asgd_update", "oracles": ["vc_asgd_lerp"],
        "tests": ["test_assimilate_flat_matches_per_leaf_oracle",
                  "test_assimilate_flat_kernel_close"]},
    "adam_update_flat": {
        "module": "vc_asgd_update", "oracles": ["adam_update"],
        "tests": ["test_fused_adam_flat"]},
    "easgd_elastic_flat": {
        "module": "vc_asgd_update", "oracles": ["easgd_elastic"],
        "tests": ["test_fused_easgd_flat"]},
}


def _pallas_entries(tree: ast.AST) -> List[ast.FunctionDef]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for call in ast.walk(fn):
            if isinstance(call, ast.Call) \
                    and call_name(call).rsplit(".", 1)[-1] == "pallas_call":
                out.append(fn)
                break
    return out


class _TestIndex:
    """Lazy, per-repo-root concatenation of tests/test_*.py sources."""

    def __init__(self):
        self._cache: Dict[Path, str] = {}

    def source(self, repo_root: Path) -> str:
        if repo_root not in self._cache:
            chunks = []
            tdir = repo_root / "tests"
            if tdir.is_dir():
                for f in sorted(tdir.glob("test_*.py")):
                    try:
                        chunks.append(f.read_text())
                    except OSError:
                        pass
            self._cache[repo_root] = "\n".join(chunks)
        return self._cache[repo_root]


@register
class KernelTriangleRule(Rule):
    name = "kernel-triangle"
    doc = ("every pallas_call entry in kernels/ needs an oracle in "
           "kernels/ref.py and a parity test under tests/ (TRIANGLE map)")

    def __init__(self):
        self._tests = _TestIndex()

    def wants(self, ctx: FileContext) -> bool:
        return (ctx.under("kernels") and not ctx.endswith("kernels/ref.py")
                and "pallas_call" in ctx.source)

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        ref_path = ctx.path.parent / "ref.py"
        ref_src = ref_path.read_text() if ref_path.is_file() else None
        entries = _pallas_entries(ctx.tree)
        defined = {fn.name for fn in ast.walk(ctx.tree)
                   if isinstance(fn, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))}
        for fn in entries:
            tri = TRIANGLE.get(fn.name)
            if tri is None:
                out.append(ctx.violation(
                    "kernel-triangle", fn,
                    f"pallas_call entry `{fn.name}` has no TRIANGLE "
                    f"entry — add its oracle + parity test and register "
                    f"them in analysis/rules/kernels.py"))
                continue
            if ref_src is None:
                out.append(ctx.violation(
                    "kernel-triangle", fn,
                    f"`{fn.name}` needs oracle(s) "
                    f"{tri['oracles']} but kernels/ref.py is missing"))
            else:
                for oracle in tri["oracles"]:
                    if f"def {oracle}(" not in ref_src:
                        out.append(ctx.violation(
                            "kernel-triangle", fn,
                            f"oracle `{oracle}` for kernel `{fn.name}` "
                            f"not defined in kernels/ref.py"))
            tsrc = self._tests.source(ctx.repo_root)
            for test in tri["tests"]:
                if f"def {test}(" not in tsrc:
                    out.append(ctx.violation(
                        "kernel-triangle", fn,
                        f"parity test `{test}` for kernel `{fn.name}` "
                        f"not found under tests/"))
        # stale map entries for THIS module
        mod = Path(ctx.relpath).stem
        for name, tri in sorted(TRIANGLE.items()):
            if tri["module"] == mod and name not in defined:
                out.append(ctx.violation(
                    "kernel-triangle", 1,
                    f"TRIANGLE maps `{name}` to module `{mod}` but no "
                    f"such function exists — remove the stale entry"))
        return out
