"""Importing this package registers every built-in vclint rule."""
from repro.analysis.rules import (kernels, layering, lease,  # noqa: F401
                                  purity, wire)
