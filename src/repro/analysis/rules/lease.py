"""lease-lifecycle: every lease a function *manages* must reach a
terminal transition on every exception path.

The protocol's exactly-once guarantee (docs/PROTOCOL.md, pinned by
tests/test_protocol.py) says a lease is live from registration until
exactly one terminal transition (``assimilate`` / ``drop`` / ``expire``
/ ``fail`` / ``_terminate`` / ``_release``) consumes it.  The dynamic
tests catch double consumption; what they can NOT catch is the lease
that never terminates because an exception skipped the transition — an
orphan that holds its reconstruction base forever (under the default
``timeout_s=inf`` nothing ever expires it).

Scope — functions that MANAGE lifecycle, not ones that merely consume
the API:

* a direct ``Lease(...)`` construction, or
* a ``.issue(...)`` / ``.open_window(...)`` result stored straight into
  ``self`` state (attribute/subscript) — i.e. the function owns a
  registry.

A plain caller (``lease = coord.issue(...)`` then hand the lease to an
event payload) is exempt: the coordinator registered the lease at issue
and its deadline sweep owns recovery.

Checks, in source order from the acquisition:

* **registered-then-risky** — once the lease is registered (stored into
  self state), any call that can raise must sit inside a ``try`` whose
  ``except``/``finally`` applies a terminal transition to the lease.
  Otherwise the exception leaves a live registered lease nothing will
  ever consume.
* **dead lease** — a constructed ``Lease(...)`` that is never
  registered, returned, escaped, or terminated at all.

Escape hatches the analysis recognizes (tracking stops, no violation):
returning/yielding the lease (caller takes ownership) and passing the
lease OBJECT to a non-``self`` callable (ownership unknown —
conservative; reading ``lease.field`` does not escape it).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple

from repro.analysis.framework import (FileContext, Rule, Violation,
                                      call_name, dotted, register)

TERMINAL_METHODS = frozenset({
    "assimilate", "drop", "expire", "fail", "_terminate", "_release",
    "drop_client",
})

# builtins that cannot meaningfully raise mid-protocol — not "risky"
_SAFE_CALLS = frozenset({
    "len", "isinstance", "getattr", "hasattr", "id", "repr", "str",
    "int", "float", "bool", "tuple", "list", "dict", "set", "range",
})

_COMPOUND = (ast.If, ast.For, ast.While, ast.With, ast.Try)


def _is_acquisition(value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    last = call_name(value).rsplit(".", 1)[-1]
    return last in ("Lease", "issue", "open_window")


def _bare_names(node: ast.AST) -> Set[str]:
    """Names an expression passes BY OBJECT: ``lease`` in ``f(lease)``
    or ``(unit, lease)``, but NOT in ``lease.deadline`` (a field read
    dereferences the object without passing it)."""
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, ast.Attribute):
        v = node.value
        return set() if isinstance(v, (ast.Name, ast.Attribute)) \
            else _bare_names(v)
    out: Set[str] = set()
    for child in ast.iter_child_nodes(node):
        out |= _bare_names(child)
    return out


def _own_statements(func) -> Iterable[ast.stmt]:
    """Statements of ``func`` excluding nested function/class bodies."""
    def rec(stmts):
        for s in stmts:
            yield s
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                yield from rec(getattr(s, field, []) or [])
            for h in getattr(s, "handlers", []) or []:
                yield from rec(h.body)
    yield from rec(func.body)


class _FuncScan:
    """Linear source-order scan of one function for one lease binding."""

    def __init__(self, ctx: FileContext, func, var: Optional[str],
                 registered: bool, site: ast.AST):
        self.ctx = ctx
        self.func = func
        self.var = var                    # local name, None if attr-bound
        self.registered = registered
        self.site = site                  # acquisition node (for lineno)
        self.done = False
        self.saw_terminal = False
        self.violations: List[Violation] = []

    def _terminal_on_var(self, node: ast.AST) -> bool:
        """A call that consumes the lease: ``x.drop(var)``,
        ``var._release(...)``, ``self._terminate(var, ...)`` ..."""
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            name = call_name(call)
            if name.rsplit(".", 1)[-1] not in TERMINAL_METHODS:
                continue
            if self.var is None:
                return True               # attr-bound: any terminal counts
            if name.split(".", 1)[0] == self.var:
                return True               # var._release(...)
            if any(self.var in _bare_names(a) for a in call.args):
                return True               # coord.drop(var)
        return False

    def _try_protects(self, stack: List[ast.Try]) -> bool:
        """Does any enclosing try have a handler/finally that reaches a
        terminal transition for this lease?"""
        for t in stack:
            if any(self._terminal_on_var(h) for h in t.handlers):
                return True
            if t.finalbody and any(self._terminal_on_var(s)
                                   for s in t.finalbody):
                return True
        return False

    def _risky_call(self, stmt: ast.stmt) -> Optional[ast.Call]:
        """First call in the statement that can raise (excluding safe
        builtins and terminal calls)."""
        for call in ast.walk(stmt):
            if not isinstance(call, ast.Call):
                continue
            name = call_name(call)
            if name in _SAFE_CALLS:
                continue
            if name.rsplit(".", 1)[-1] in TERMINAL_METHODS:
                continue
            return call
        return None

    def _is_registration(self, stmt: ast.stmt) -> bool:
        """``self.<...> = var`` or ``self.x[...] = var`` — the lease
        enters an owned registry."""
        if self.var is None or not isinstance(stmt, ast.Assign):
            return False
        if not (isinstance(stmt.value, ast.Name)
                and stmt.value.id == self.var):
            return False
        for t in stmt.targets:
            if isinstance(t, ast.Attribute):
                return True
            if isinstance(t, ast.Subscript) and dotted(t.value):
                return True
        return False

    def _escapes(self, stmt: ast.stmt) -> bool:
        """The lease object leaves this function's custody: returned,
        yielded, or passed to a non-self callable."""
        if self.var is None:
            return False
        for n in ast.walk(stmt):
            if isinstance(n, ast.Return) and n.value is not None \
                    and self.var in _bare_names(n.value):
                return True
            if isinstance(n, (ast.Yield, ast.YieldFrom)) \
                    and n.value is not None \
                    and self.var in _bare_names(n.value):
                return True
            if isinstance(n, ast.Call):
                name = call_name(n)
                if name.split(".", 1)[0] in ("self", self.var):
                    continue              # helper on the same object
                if name.rsplit(".", 1)[-1] in TERMINAL_METHODS:
                    continue
                args = list(n.args) + [kw.value for kw in n.keywords]
                if any(self.var in _bare_names(a) for a in args):
                    return True
        return False

    def run(self, after: ast.stmt) -> List[Violation]:
        """Scan statements strictly after the acquisition ``after``."""
        started = False

        def walk(stmts: List[ast.stmt], trys: List[ast.Try]):
            nonlocal started
            for stmt in stmts:
                if self.done:
                    return
                if not started:
                    if stmt is after:
                        started = True
                    elif isinstance(stmt, _COMPOUND):
                        walk(self._children(stmt), trys
                             + ([stmt] if isinstance(stmt, ast.Try) else []))
                    continue
                # -- after the acquisition --
                if self._terminal_on_var(stmt):
                    self.saw_terminal = True
                    self.done = True
                    return
                if self._is_registration(stmt):
                    self.registered = True
                    continue
                if self._escapes(stmt):
                    self.done = True
                    return
                if self.registered:
                    risky = self._risky_call(stmt)
                    if risky is not None and not self._try_protects(trys):
                        self.violations.append(self.ctx.violation(
                            "lease-lifecycle", risky,
                            f"`{call_name(risky) or 'call'}(...)` can raise "
                            f"after the lease is registered, with no except/"
                            f"finally applying a terminal transition on the "
                            f"exception path"))
                        self.done = True
                        return
                if isinstance(stmt, _COMPOUND):
                    walk(self._children(stmt), trys
                         + ([stmt] if isinstance(stmt, ast.Try) else []))

        walk(self.func.body, [])
        if (not self.done and not self.saw_terminal and not self.registered
                and self.var is not None):
            self.violations.append(self.ctx.violation(
                "lease-lifecycle", self.site,
                f"lease `{self.var}` is constructed but never registered, "
                f"returned, or terminated — it can never reach a terminal "
                f"transition"))
        return self.violations

    @staticmethod
    def _children(stmt: ast.stmt) -> List[ast.stmt]:
        out: List[ast.stmt] = []
        for field in ("body", "orelse", "finalbody"):
            out.extend(getattr(stmt, field, []) or [])
        for h in getattr(stmt, "handlers", []) or []:
            out.extend(h.body)
        return out


@register
class LeaseLifecycleRule(Rule):
    name = "lease-lifecycle"
    doc = ("functions that construct a Lease or register issued leases "
           "must reach a terminal transition on every exception path")

    def wants(self, ctx: FileContext) -> bool:
        return ("Lease(" in ctx.source or ".issue(" in ctx.source
                or ".open_window(" in ctx.source)

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for func in ast.walk(ctx.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for stmt, var, registered, site in self._acquisitions(func):
                scan = _FuncScan(ctx, func, var, registered, site)
                out.extend(scan.run(stmt))
        return out

    @staticmethod
    def _acquisitions(func) -> List[Tuple[ast.stmt, Optional[str],
                                          bool, ast.AST]]:
        """(stmt, local var or None, registered-at-binding, call node)
        for every lease acquisition the function manages — nested
        function bodies excluded (they get their own pass)."""
        out = []
        for stmt in _own_statements(func):
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            if not _is_acquisition(stmt.value):
                continue
            tgt = stmt.targets[0]
            last = call_name(stmt.value).rsplit(".", 1)[-1]
            if isinstance(tgt, ast.Name):
                # a plain `.issue()` caller does not manage the registry;
                # Lease() constructors always do
                if last == "Lease":
                    out.append((stmt, tgt.id, False, stmt.value))
            elif isinstance(tgt, (ast.Attribute, ast.Subscript)):
                # stored straight into self state: managed & registered
                out.append((stmt, None, True, stmt.value))
        return out
