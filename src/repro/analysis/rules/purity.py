"""jit-purity and scheme-purity.

**jit-purity** — bodies reachable from a trace context (``@jax.jit``,
``functools.partial(jax.jit, ...)``, a kernel handed to
``pl.pallas_call`` or ``shard_map``) execute under tracing: host syncs
(``.item()``, ``np.asarray``, ``float()`` of a traced value) force a
device round-trip per call, Python ``random``/``time`` freeze a single
trace-time value into the compiled program, and ``global``/``nonlocal``
writes leak trace-time state.  All were bugs the roofline work had to
chase dynamically; here they fail at parse time.

**scheme-purity** — ``ServerScheme`` methods are pure transition
functions over their ``SchemeState``: the coordinator owns the lease
registry and transport, checkpoints scheme state as a pytree, and
replays transitions on resume.  A scheme method that mutates ``self``
(hidden state the checkpoint never sees), writes through a
coordinator/transport/lease parameter, or performs I/O breaks resume
and the hierarchical-aggregation replays.  Configuration belongs in
``__init__``; mutable state belongs in the ``SchemeState``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from repro.analysis.framework import (FileContext, Rule, Violation,
                                      call_name, dotted, register)

_TRACE_TAILS = ("jit", "pallas_call", "shard_map")

_HOST_SYNC_CALLS = frozenset({
    "np.asarray", "np.array", "onp.asarray", "numpy.asarray",
    "np.frombuffer", "jax.device_get",
})
_TIME_CALLS = frozenset({
    "time.time", "time.perf_counter", "time.monotonic", "time.sleep",
    "time.process_time",
})


def _decorator_traced(dec: ast.AST) -> bool:
    d = dotted(dec)
    if d.rsplit(".", 1)[-1] in _TRACE_TAILS:
        return True
    if isinstance(dec, ast.Call):
        name = call_name(dec)
        if name.rsplit(".", 1)[-1] in _TRACE_TAILS:
            return True
        if name.rsplit(".", 1)[-1] == "partial":
            return any(dotted(a).rsplit(".", 1)[-1] in _TRACE_TAILS
                       for a in dec.args)
    return False


def _kernel_arg_names(tree: ast.AST) -> Set[str]:
    """Names passed as the traced callable to pallas_call/shard_map —
    directly or wrapped in functools.partial(fn, ...)."""
    names: Set[str] = set()
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        if call_name(call).rsplit(".", 1)[-1] not in ("pallas_call",
                                                      "shard_map"):
            continue
        if not call.args:
            continue
        arg = call.args[0]
        if isinstance(arg, ast.Name):
            names.add(arg.id)
        elif (isinstance(arg, ast.Call)
              and call_name(arg).rsplit(".", 1)[-1] == "partial"
              and arg.args and isinstance(arg.args[0], ast.Name)):
            names.add(arg.args[0].id)
    return names


@register
class JitPurityRule(Rule):
    name = "jit-purity"
    doc = ("no host syncs, Python random/time, or mutable-global capture "
           "inside jit/pallas_call/shard_map-traced bodies")

    def wants(self, ctx: FileContext) -> bool:
        return ("jit" in ctx.source or "pallas_call" in ctx.source
                or "shard_map" in ctx.source)

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        kernel_names = _kernel_arg_names(ctx.tree)
        out: List[Violation] = []
        for func in ast.walk(ctx.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            traced = (func.name in kernel_names
                      or any(_decorator_traced(d)
                             for d in func.decorator_list))
            if traced:
                self._scan(ctx, func, out)
        return out

    def _scan(self, ctx: FileContext, func, out: List[Violation]) -> None:
        for node in ast.walk(func):
            if isinstance(node, ast.Global):
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"`global {', '.join(node.names)}` inside traced "
                    f"`{func.name}` captures mutable module state at "
                    f"trace time"))
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item":
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"`.item()` inside traced `{func.name}` is a host "
                    f"sync per call"))
            elif name in _HOST_SYNC_CALLS:
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"`{name}(...)` inside traced `{func.name}` "
                    f"materializes on host — use jnp inside traces"))
            elif name in _TIME_CALLS:
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"`{name}()` inside traced `{func.name}` freezes a "
                    f"trace-time clock value into the compiled program"))
            elif name.split(".", 1)[0] == "random":
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"stdlib `{name}()` inside traced `{func.name}` "
                    f"freezes a trace-time sample — thread a jax.random "
                    f"key instead"))
            elif (name in ("float", "int", "bool") and len(node.args) == 1
                  and isinstance(node.args[0], (ast.Name, ast.Call))):
                out.append(ctx.violation(
                    "jit-purity", node,
                    f"`{name}(...)` of a non-literal inside traced "
                    f"`{func.name}` concretizes a traced value (host "
                    f"sync); keep it symbolic or mark the arg static"))


# ---------------------------------------------------------------------------


_IO_ROOTS = frozenset({"os", "socket", "subprocess", "shutil", "requests",
                       "urllib"})
_FOREIGN_PARAMS = frozenset({"coordinator", "coord", "transport", "hub",
                             "server", "srv", "lease"})
_INIT_METHODS = frozenset({"__init__", "__post_init__"})


def _scheme_classes(tree: ast.AST) -> List[ast.ClassDef]:
    """Classes that ARE ServerScheme or transitively inherit from it
    within this module."""
    classes = [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]
    marked: Set[str] = {c.name for c in classes if c.name == "ServerScheme"}
    marked |= {c.name for c in classes
               if any(dotted(b).rsplit(".", 1)[-1] == "ServerScheme"
                      for b in c.bases)}
    changed = True
    while changed:
        changed = False
        for c in classes:
            if c.name in marked:
                continue
            if any(dotted(b).rsplit(".", 1)[-1] in marked
                   for b in c.bases):
                marked.add(c.name)
                changed = True
    return [c for c in classes if c.name in marked]


@register
class SchemePurityRule(Rule):
    name = "scheme-purity"
    doc = ("ServerScheme methods are pure SchemeState transitions: no "
           "self-mutation outside __init__, no writes through "
           "coordinator/transport/lease parameters, no I/O")

    def wants(self, ctx: FileContext) -> bool:
        return "ServerScheme" in ctx.source

    def check(self, ctx: FileContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for cls in _scheme_classes(ctx.tree):
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name in _INIT_METHODS:
                    continue
                self._scan_method(ctx, cls, meth, out)
        return out

    def _scan_method(self, ctx, cls, meth, out) -> None:
        params = {a.arg for a in meth.args.args}
        foreign = params & _FOREIGN_PARAMS
        for node in ast.walk(meth):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    root = dotted(t if not isinstance(t, ast.Subscript)
                                  else t.value).split(".", 1)[0]
                    if isinstance(t, (ast.Attribute, ast.Subscript)):
                        if root == "self":
                            out.append(ctx.violation(
                                "scheme-purity", node,
                                f"{cls.name}.{meth.name} mutates `self` — "
                                f"scheme methods are stateless; mutable "
                                f"state belongs in the SchemeState"))
                        elif root in foreign:
                            out.append(ctx.violation(
                                "scheme-purity", node,
                                f"{cls.name}.{meth.name} writes through "
                                f"`{root}` — coordinator/transport/lease "
                                f"state is owned by the coordinator"))
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name in ("open", "input"):
                    out.append(ctx.violation(
                        "scheme-purity", node,
                        f"{cls.name}.{meth.name} performs I/O "
                        f"(`{name}`) — schemes must be replayable pure "
                        f"transitions"))
                elif name.split(".", 1)[0] in _IO_ROOTS:
                    out.append(ctx.violation(
                        "scheme-purity", node,
                        f"{cls.name}.{meth.name} calls `{name}` — "
                        f"schemes must not touch the OS/network"))
